"""End-to-end training driver: train a ~100M-param qwen1.5-family model on a
synthetic corpus for a few hundred steps with the full runtime (async
checkpointing, restart safety, watchdog).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen1.5-0.5b]

On this CPU container the default config is cut to ~20M params so a few
hundred steps finish in minutes; pass --full for the real ~100M run.
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slower on CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = get_config(args.arch)
    if args.full:
        # ~100M: 12 layers at the arch's native width.
        cfg = dataclasses.replace(
            base, n_layers=min(base.n_layers, 12), dtype="float32",
            param_dtype="float32", remat=False,
        )
    else:
        cfg = dataclasses.replace(
            base.reduced(), name=base.name + "-mini",
            d_model=256, n_heads=8, n_kv_heads=min(base.n_kv_heads, 8),
            head_dim=32, d_ff=512 if base.d_ff else 0, vocab_size=4096,
            n_layers=4, block_pattern=base.reduced().block_pattern[:4]
            if base.block_pattern else (),
        )
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size}")

    opt_cfg = adamw.AdamWConfig(lr=1e-3, weight_decay=0.01)
    lr_fn = adamw.cosine_schedule(1e-3, warmup_steps=20, total_steps=args.steps)

    def init_state():
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"params: {n/1e6:.1f}M")
        return {"params": params, "opt": adamw.init_opt_state(params, opt_cfg)}

    from repro.models.layers import MeshCtx
    ctx = MeshCtx(mesh=None)

    @jax.jit
    def train_step(state, batch):
        def loss(p):
            return M.loss_fn(p, cfg, ctx, batch)
        loss_val, grads = jax.value_and_grad(loss)(state["params"])
        new_p, new_o, metrics = adamw.adamw_update(
            state["params"], grads, state["opt"], opt_cfg, lr_fn
        )
        return {"params": new_p, "opt": new_o}, dict(metrics, loss=loss_val)

    data = Prefetcher(iter(SyntheticLM(cfg.vocab_size, args.seq_len, args.batch)))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                         ckpt_every=50, log_every=10)
    out = Trainer(tcfg, train_step, init_state, data).run()
    first, last = out["losses"][0], out["losses"][-1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {out['final_step']} steps "
          f"(checkpoints in {ckpt_dir})")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
