"""Online streaming runtime demo: execute schedules against a drifting
workload and watch the online controller adapt.

    PYTHONPATH=src python examples/runtime_demo.py

Three policies run the same rate-ramp + machine-slowdown trace:
a frozen schedule provisioned for the initial rate (the paper's
size-to-observed-load protocol), the same schedule driven by the online
controller (incremental refine-move replans behind a migration guard),
and an oracle that re-runs the full scheduler every window with free
migrations. A final section shares the cluster between several tenants
(weighted max-min fairness + the shared multi-tenant runtime).

The online run is instrumented with ``repro.obs.TraceRecorder``: the
controller's replan audit ledger drives the decision log below, and the
run's trace is exported as ``runtime_demo_trace.jsonl`` plus
``runtime_demo_trace.trace.json`` (Chrome trace-event format — open
https://ui.perfetto.dev and drag the file in to see the executor windows,
controller spans and closed-form dispatch decisions on a timeline).
"""

import numpy as np

from repro.core import (
    keyed_rolling_count_topology,
    linear_topology,
    max_stable_rate,
    paper_cluster,
    schedule,
)
from repro.core.refine import refine
from repro.obs import TraceRecorder, summary, to_chrome_trace, to_jsonl
from repro.multitenant import (
    MultiTenantRuntime,
    Tenant,
    TenantSet,
    compile_tenant_traces,
    schedule_tenants,
)
from repro.runtime_stream import (
    OnlineController,
    OracleRescheduler,
    RuntimeConfig,
    StreamExecutor,
    TraceSpec,
    machine_slowdown,
    provision_schedule,
    rate_ramp,
    skew_shift_trace,
)


def main() -> None:
    cluster = paper_cluster((1, 1, 1))
    topo = linear_topology()
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    print(f"cluster max stable rate: {full.rate:.2f} tuples/s "
          f"(throughput {full.throughput:.2f})")

    spec = TraceSpec(
        name="demo",
        n_windows=240,
        base_rate=full.rate * 0.3,
        events=(
            rate_ramp(full.rate * 1.1, start=20, end=140),
            machine_slowdown(2, 0.5, start=170),
        ),
    )
    start = provision_schedule(topo, cluster, full.rate * 0.3)
    print(f"initial schedule (provisioned for rate {full.rate * 0.3:.2f}): "
          f"instances={start.n_instances.tolist()}")

    static = StreamExecutor(start, cluster, spec).run()
    recorder = TraceRecorder(name="runtime_demo", wall_clock=True)
    ctl = OnlineController(topo, cluster, period=10, recorder=recorder)
    online = StreamExecutor(start, cluster, spec, recorder=recorder).run(
        controller=ctl
    )
    oracle = StreamExecutor(
        start, cluster, spec, config=RuntimeConfig(migration_pause=0)
    ).run(controller=OracleRescheduler(topo, cluster))

    print("\nsustained throughput (tail half of the trace):")
    print(f"  static   {static.sustained_throughput():7.2f} tuples/s")
    print(f"  online   {online.sustained_throughput():7.2f} tuples/s "
          f"({int(online.migrations.sum())} migrations)")
    print(f"  oracle   {oracle.sustained_throughput():7.2f} tuples/s "
          f"({int(oracle.migrations.sum())} migrations)")

    print("\ncontroller decisions (replan audit ledger):")
    for dec in ctl.ledger:
        print(f"  window {dec.window:3d}: {dec.message}")
    accepted = ctl.ledger.accepted
    print(f"  {len(accepted)} accepted / "
          f"{len(ctl.ledger) - len(accepted)} rejected or deferred")

    print(f"\nfinal online schedule: "
          f"instances={online.final_etg.n_instances.tolist()}")
    quarters = np.array_split(online.throughput, 4)
    means = " -> ".join(f"{q.mean():.1f}" for q in quarters)
    print(f"online throughput by quarter: {means} tuples/s")

    print("\n--- observability (repro.obs) ---")
    print(summary(recorder))
    to_jsonl(recorder, "runtime_demo_trace.jsonl")
    to_chrome_trace(recorder, "runtime_demo_trace.trace.json")
    print("trace exported: runtime_demo_trace.jsonl and "
          "runtime_demo_trace.trace.json")
    print("open https://ui.perfetto.dev and drag the .trace.json in to "
          "browse the run")

    keyed_demo(cluster)
    multitenant_demo()


def multitenant_demo() -> None:
    """Three tenants share one cluster: weighted max-min fair rates, then
    the shared runtime executes every tenant's plan against one capacity
    grid with a cross-tenant migration arbiter."""
    from repro.core import diamond_topology, star_topology

    print("\n--- multi-tenant (shared cluster, weighted max-min) ---")
    cluster = paper_cluster((2, 2, 2))
    tenants = TenantSet(
        [
            Tenant(name="alice", utg=linear_topology(), target_rate=8.0,
                   priority=2.0),
            Tenant(name="bob", utg=diamond_topology(), target_rate=8.0),
            Tenant(name="carol", utg=star_topology(), target_rate=6.0),
        ]
    )
    ms = schedule_tenants(list(tenants), cluster)
    for a in ms.allocations:
        print(f"  {a.name:6s} rate {a.rate:6.2f} / target {a.target_rate:5.1f} "
              f"(priority {a.priority:.0f}, level {a.level:.3f})")
    print(f"  {ms.rounds} water-filling rounds, "
          f"{ms.candidates_evaluated} batched candidates")

    specs = [
        TraceSpec(name=t.name, n_windows=96, base_rate=0.8 * ms.rates[i])
        for i, t in enumerate(tenants)
    ]
    mtrace = compile_tenant_traces(tenants, specs, cluster, seed=0)
    res = MultiTenantRuntime(ms, tenants, cluster, mtrace).run(
        online=True, moves_per_period=4
    )
    for name, sat in zip(res.names, res.satisfaction):
        print(f"  {name:6s} runtime satisfaction {sat:.2f}")


def keyed_demo(cluster) -> None:
    """Fields grouping with Zipf-hot keys: the even-split score
    over-reports what the schedule sustains; the skew-aware controller
    replans around the hot instances (and a mid-trace key-skew shift)."""
    print("\n--- keyed streams (fields grouping, Zipf keys) ---")
    utg = keyed_rolling_count_topology(n_keys=16, zipf_s=1.5)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    cfg = RuntimeConfig(max_queue=120.0)

    spec = skew_shift_trace(
        0.95 * max_stable_rate(etg, cluster)[0], n_windows=240, zipf_s=2.0
    )
    probe = StreamExecutor(etg, cluster, spec, seed=0, config=cfg)
    skew = probe.skew_model_at(0)
    r_even, _ = max_stable_rate(etg, cluster)
    r_skew, _ = max_stable_rate(etg, cluster, skew=skew)
    print(f"even-split R* {r_even:.2f} vs skew-aware R* {r_skew:.2f} "
          f"(hot keys cost {100 * (1 - r_skew / r_even):.0f}% capacity)")

    static = StreamExecutor(etg, cluster, spec, seed=0, config=cfg).run()
    ctl = OnlineController(utg, cluster, period=10)
    online = StreamExecutor(etg, cluster, spec, seed=0, config=cfg).run(
        controller=ctl
    )
    print(f"  static   {static.sustained_throughput():7.2f} tuples/s")
    print(f"  online   {online.sustained_throughput():7.2f} tuples/s "
          f"({int(online.migrations.sum())} migrations)")
    for window, msg in ctl.log[:6]:
        print(f"  window {window:3d}: {msg}")


if __name__ == "__main__":
    main()
