"""Full paper reproduction driver: every claim, one script.

    PYTHONPATH=src python examples/paper_repro.py

Covers: prediction accuracy (Fig. 6), throughput vs default/optimal
(Figs. 3/8), instance selection (Fig. 7), utilization (Fig. 9),
large-scale scenarios (Fig. 10 / Tables 4-5).
"""

from benchmarks import (
    bench_instances,
    bench_largescale,
    bench_prediction,
    bench_sched_speed,
    bench_throughput,
    bench_utilization,
)


def main() -> None:
    print("name,us_per_call,derived")
    print("# -- Fig. 6: CPU usage prediction --")
    bench_prediction.main()
    print("# -- Figs. 3/8: throughput comparison --")
    bench_throughput.main()
    print("# -- Fig. 7: instance-count selection --")
    bench_instances.main()
    print("# -- Fig. 9: utilization comparison --")
    bench_utilization.main()
    print("# -- Fig. 10 / Tables 4-5: large-scale simulation --")
    bench_largescale.main()
    print("# -- Sec. 3: scheduler wall-time --")
    bench_sched_speed.main()


if __name__ == "__main__":
    main()
