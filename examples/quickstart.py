"""Quickstart: schedule a stream topology on a heterogeneous cluster and
compare against Storm's default round-robin scheduler.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    linear_topology,
    max_stable_rate,
    paper_cluster,
    predict,
    round_robin_schedule,
    schedule,
    simulate,
)
from repro.core.refine import refine


def main() -> None:
    # The paper's 3-worker cluster: Pentium / Core i3 / Core i5 (Table 2/3).
    cluster = paper_cluster((1, 1, 1))
    topo = linear_topology()
    print(f"topology: {topo.name} with {topo.n_components} components")

    # Proposed scheduler (Algorithm 1 + 2).
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    rate, thpt = max_stable_rate(sched.etg, cluster)
    print(f"\nproposed: instances={sched.etg.n_instances.tolist()} "
          f"rate={rate:.2f} tuples/s throughput={thpt:.2f}")
    pred = predict(sched.etg, cluster, rate)
    print(f"machine utilization: {np.round(pred.machine_util, 1).tolist()}")

    # Beyond-paper local-search refinement.
    ref = refine(sched.etg, cluster)
    print(f"refined:  instances={ref.etg.n_instances.tolist()} "
          f"throughput={ref.throughput:.2f} ({len(ref.moves)} moves)")

    # Storm default baseline at the same instance counts.
    rr = round_robin_schedule(topo, cluster, ref.etg.n_instances)
    _, rr_thpt = max_stable_rate(rr, cluster)
    print(f"default round-robin: throughput={rr_thpt:.2f}")
    print(f"\ngain vs default: {(ref.throughput / rr_thpt - 1) * 100:.1f}% "
          f"(paper reports 7-44%)")

    # Sanity: the simulator agrees with the prediction at the stable rate.
    sim = simulate(ref.etg, cluster, ref.rate)
    print(f"simulated throughput at stable rate: {sim.throughput:.2f}")


if __name__ == "__main__":
    main()
