"""Serving example: batched prefill+decode with KV caches, fronted by the
paper's scheduler as admission/replica planner, including an elastic
failure event.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen1.5-0.5b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import MeshCtx
from repro.sched.elastic import ElasticController
from repro.sched.fleet import DevicePool, Fleet, TPU_LITE, TPU_V5E


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    # --- plan the serving deployment with the paper's scheduler ---------
    fleet = Fleet(pools=(
        DevicePool(chip=TPU_V5E, count=6, chips_per_group=8, name="v5e"),
        DevicePool(chip=TPU_LITE, count=8, chips_per_group=4, name="lite"),
    ))
    full_cfg = get_config(args.arch)
    ec = ElasticController(full_cfg, fleet, n_stages=4)
    print(ec.current.summary())

    # --- elastic event: lose two v5e groups, re-plan --------------------
    ec.fail(0, 2)
    print(f"\nafter losing 2 v5e groups -> admission "
          f"{ec.admission_rate:,.0f} tok/s")
    print(ec.current.summary())
    ec.restore(0, 2)

    # --- actually serve a reduced model on this host --------------------
    cfg = full_cfg.reduced()
    ctx = MeshCtx(mesh=None)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen_len
    caches = M.init_caches(cfg, B, P + G)

    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                           cfg.vocab_size)}
    if cfg.embedding_inputs:
        prompt = {"embeds": jax.random.normal(jax.random.PRNGKey(1),
                                              (B, P, cfg.d_model), jnp.float32)}
    if cfg.is_encoder_decoder:
        prompt["encoder_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model), jnp.float32)

    prefill = jax.jit(lambda p, b, c: M.prefill(p, cfg, ctx, b, c))
    decode = jax.jit(lambda p, b, c: M.decode_step(p, cfg, ctx, b, c))

    t0 = time.perf_counter()
    logits, caches = prefill(params, prompt, caches)
    tok = jnp.argmax(logits, -1)[:, None]
    generated = [tok]
    for _ in range(G - 1):
        step = {"tokens": tok}
        if cfg.embedding_inputs:
            step = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
        if cfg.is_encoder_decoder:
            step["encoder_embeds"] = prompt["encoder_embeds"]
        logits, caches = decode(params, step, caches)
        tok = jnp.argmax(logits, -1)[:, None]
        generated.append(tok)
    toks = jnp.concatenate(generated, axis=1).block_until_ready()
    dt = time.perf_counter() - t0
    print(f"\nserved {B} requests x {G} tokens in {dt:.2f}s "
          f"({B * G / dt:,.0f} tok/s on this host)")
    print("sample output ids:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
