"""Path-based PartitionSpec assignment for params, batches and caches.

The launch layer (``repro.launch.steps`` / ``repro.launch.dryrun``) wants
shardings without the model code knowing mesh topology. Conventions follow
``repro.launch.mesh``: ``data`` (plus optional ``pod``) carries batch/FSDP,
``model`` carries tensor parallelism.

Assignment is deliberately conservative: a dimension is sharded only when
its size divides the mesh axis size, so every spec returned here is valid
on any mesh (replication is always a safe fallback). For parameters the
*largest* divisible dimension goes to the ``model`` axis — the standard
Megatron choice for the dominant 2-D kernels and a sound (if not always
optimal) default for everything else; norm scales, biases and other
per-shard-identical state replicate by path name (``_REPLICATED_NAMES``).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec

__all__ = [
    "mesh_axes",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "shardings",
]

_TP_AXIS = "model"
_DATA_AXES = ("pod", "data")

# Path-name rules: parameters whose path contains one of these substrings
# replicate regardless of shape — small vectors whose all-gather cost
# outweighs any memory saving, or state that must be identical per shard.
_REPLICATED_NAMES = ("norm", "scale", "bias", "rope", "step", "count")


def mesh_axes(mesh: jax.sharding.Mesh, cfg: Any) -> tuple[tuple[str, ...], str]:
    """(data axes present in the mesh, tensor-parallel axis name).

    A mesh with no data axis (e.g. pure tensor parallelism) yields an empty
    tuple: batch dims then replicate, which every consumer accepts — naming
    an absent axis in a PartitionSpec would error instead.
    """
    return tuple(a for a in _DATA_AXES if a in mesh.shape), _TP_AXIS


def _axis_size(mesh: jax.sharding.Mesh, axis: str) -> int:
    return int(mesh.shape.get(axis, 1))


def _param_spec(path: str, shape: tuple[int, ...], tp: int) -> PartitionSpec:
    if tp <= 1 or not shape:
        return PartitionSpec()
    lowered = path.lower()
    if any(s in lowered for s in _REPLICATED_NAMES):
        return PartitionSpec()
    # Largest tp-divisible dimension carries the model axis; ties toward the
    # trailing (output-feature) dimension. 1-D vectors (norm scales, biases)
    # replicate unless large and divisible (e.g. sharded embedding tables
    # flattened elsewhere keep their layout).
    best = -1
    best_size = 0
    for d in range(len(shape) - 1, -1, -1):
        if shape[d] % tp == 0 and shape[d] > best_size:
            best, best_size = d, shape[d]
    if best < 0 or (len(shape) == 1 and shape[0] < 4096):
        return PartitionSpec()
    spec: list[Any] = [None] * len(shape)
    spec[best] = _TP_AXIS
    return PartitionSpec(*spec)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_specs(params: Any, mesh: jax.sharding.Mesh, cfg: Any) -> Any:
    """PartitionSpec pytree matching ``params`` (path-name aware)."""
    tp = _axis_size(mesh, _TP_AXIS)

    def assign(path, leaf):
        return _param_spec(_path_str(path), tuple(leaf.shape), tp)

    return jax.tree_util.tree_map_with_path(assign, params)


def _batched_spec(shape: tuple[int, ...], data_axes: tuple[str, ...], dsize: int) -> PartitionSpec:
    if not data_axes or not shape or shape[0] % dsize != 0:
        return PartitionSpec()
    return PartitionSpec(data_axes, *([None] * (len(shape) - 1)))


def batch_specs(batch: Any, mesh: jax.sharding.Mesh, cfg: Any) -> Any:
    """Shard the leading (batch) dimension over the data axes."""
    data_axes, _ = mesh_axes(mesh, cfg)
    dsize = 1
    for a in data_axes:
        dsize *= _axis_size(mesh, a)

    def assign(path, leaf):
        shape = tuple(leaf.shape)
        # mrope positions are (3, B, S): batch is dim 1.
        if "mrope" in _path_str(path) and len(shape) == 3:
            if data_axes and shape[1] % dsize == 0:
                return PartitionSpec(None, data_axes, None)
            return PartitionSpec()
        return _batched_spec(shape, data_axes, dsize)

    return jax.tree_util.tree_map_with_path(assign, batch)


def cache_specs(caches: Any, mesh: jax.sharding.Mesh, cfg: Any) -> Any:
    """KV/state caches are batch-major: shard dim 0 over data axes."""
    data_axes, _ = mesh_axes(mesh, cfg)
    dsize = 1
    for a in data_axes:
        dsize *= _axis_size(mesh, a)

    def assign(path, leaf):
        return _batched_spec(tuple(leaf.shape), data_axes, dsize)

    return jax.tree_util.tree_map_with_path(assign, caches)


def shardings(specs: Any, mesh: jax.sharding.Mesh) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )
