"""Distribution utilities: path-based parameter/batch partitioning."""
