"""Blocked causal FlashAttention as a Pallas TPU kernel.

Dataflow (TPU-native adaptation of the CUDA flash algorithm):

* Grid = (batch*heads, n_q_blocks, n_kv_blocks); the kv axis is the
  innermost ("arbitrary"/sequential) dimension so the online-softmax
  running state lives in VMEM scratch across kv iterations.
* Per program: q tile (block_q x D) stays resident; k/v tiles
  (block_kv x D) stream HBM -> VMEM; the MXU computes q@k^T and p@v with
  128-aligned tiles; running (m, l, acc) update in fp32 on the VPU.
* Causal + sliding-window masking by absolute positions (queries are
  right-aligned against the kv span, matching decode/prefill layouts).
* Out-of-range kv blocks are masked rather than skipped: TPU pallas grids
  execute the full rectangle, the mask zeroes their contribution (the XLA
  twin in repro.models.attention skips them statically instead — that
  asymmetry is why both exist).

Block sizes default to (128, 128): multiples of the 128-lane MXU tile, and
a (block_q + 2*block_kv) x D x 4B working set that fits v5e VMEM (~16 MB)
for every assigned head_dim (64..256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1.0e30


def _kernel(
    q_ref, k_ref, v_ref,      # VMEM tiles
    o_ref,                    # output tile
    m_ref, l_ref, acc_ref,    # scratch: running max / denom / accumulator
    *,
    block_q: int,
    block_kv: int,
    seq_q: int,
    seq_kv: int,
    causal: bool,
    window: int,
    n_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (block_q, D)
    k = k_ref[0].astype(jnp.float32)          # (block_kv, D)
    v = v_ref[0].astype(jnp.float32)
    scale = q.shape[-1] ** -0.5

    s = jax.lax.dot_general(
        q * scale, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # (block_q, block_kv)

    # absolute positions: queries right-aligned to the kv span
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + (seq_kv - seq_q)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_kv
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,   # (B, H, Sq, D)
    k: jax.Array,   # (B, H, Sk, D)
    v: jax.Array,   # (B, H, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_kv, Sk)
    n_q = -(-Sq // bq)
    n_kv = -(-Sk // bk)

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)

    kernel = functools.partial(
        _kernel,
        block_q=bq,
        block_kv=bk,
        seq_q=Sq,
        seq_kv=Sk,
        causal=causal,
        window=window,
        n_kv_blocks=n_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
