"""Jitted public wrapper for flash attention: GQA layout adaptation +
backend selection (Pallas on TPU, interpret mode on CPU for tests, the XLA
chunked path as production CPU fallback)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref

__all__ = ["flash_attention"]


def flash_attention(
    q: jax.Array,   # (B, Sq, H, D)  — model layout
    k: jax.Array,   # (B, Sk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    impl: str = "auto",
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    """GQA flash attention. Repeats KV heads to query heads and dispatches
    to the Pallas kernel (TPU), interpret-mode Pallas (tests), or the jnp
    oracle."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, window=window,
            block_q=block_q, block_kv=block_kv,
        )
    elif impl == "interpret":
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, window=window,
            block_q=block_q, block_kv=block_kv, interpret=True,
        )
    else:
        out = flash_attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)
