"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref"]

NEG_INF = -2.0e38


def flash_attention_ref(
    q: jax.Array,   # (B, H, Sq, D)
    k: jax.Array,   # (B, H, Sk, D)
    v: jax.Array,   # (B, H, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    Sq, Sk = q.shape[2], k.shape[2]
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned queries
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
