"""Jitted wrapper: single-token decode attention over a batched KV cache."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref

__all__ = ["decode_attention"]


def decode_attention(q, k, v, lengths, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return decode_attention_pallas(q, k, v, lengths)
    if impl == "interpret":
        return decode_attention_pallas(q, k, v, lengths, interpret=True)
    return decode_attention_ref(q, k, v, lengths)
