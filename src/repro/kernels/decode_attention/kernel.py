"""Single-token GQA decode attention as a Pallas TPU kernel.

Decode attention is memory-bound: one query head-group reads the whole KV
cache once. The kernel streams KV blocks HBM -> VMEM and keeps the online
softmax state in scratch; queries for all G heads of one KV group ride in
a single (G x D) tile so each KV byte is read exactly once per group (the
GQA arithmetic-intensity win).

Grid = (B, Hkv, n_kv_blocks), kv innermost/sequential. Per-row cache
lengths mask invalid tail slots (scalar-prefetched).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

NEG_INF = -1.0e30


def _kernel(
    len_ref,                    # SMEM (B,) lengths
    q_ref, k_ref, v_ref,        # VMEM tiles
    o_ref,
    m_ref, l_ref, acc_ref,
    *,
    block_kv: int,
    n_kv_blocks: int,
):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_kv, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    scale = q.shape[-1] ** -0.5

    s = jax.lax.dot_general(
        q * scale, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (G, block_kv)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < len_ref[bi]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention_pallas(
    q: jax.Array,        # (B, H, D)
    k: jax.Array,        # (B, S, Hkv, D)
    v: jax.Array,
    lengths: jax.Array,  # (B,) int32
    *,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bk = min(block_kv, S)
    n_kv = -(-S // bk)

    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_kernel, block_kv=bk, n_kv_blocks=n_kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda bi, hi, ki, lens: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda bi, hi, ki, lens: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda bi, hi, ki, lens: (bi, ki, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda bi, hi, ki, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, D)
