"""Pure-jnp oracle for single-token GQA decode attention over a KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref"]

NEG_INF = -2.0e38


def decode_attention_ref(
    q: jax.Array,        # (B, H, D) one query per batch row
    k: jax.Array,        # (B, S, Hkv, D)
    v: jax.Array,        # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) valid cache length per row
) -> jax.Array:
    B, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    valid = jnp.arange(k.shape[1])[None, :] < lengths[:, None]   # (B, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
