"""NumPy oracle for the scheduling-score kernel (pre-gathered operands).

Same math as ``core.cost_model.closed_form_rates`` but on the kernel's
input surface — task->machine ids plus already-gathered ``ev`` / ``met``
tiles — so Pallas parity tests compare against exactly what the kernel was
fed, independent of the host-side gather.
"""

from __future__ import annotations

import numpy as np

__all__ = ["sched_scoring_ref"]


def sched_scoring_ref(
    task_machine: np.ndarray,    # (B, T) int
    ev: np.ndarray,              # (B, T) e * unit_ir
    met: np.ndarray,             # (B, T)
    capacity: np.ndarray,        # (m,)
    net_var: np.ndarray | None = None,   # (B, m) cut-traffic load
    mem: np.ndarray | None = None,       # (B, T) per-task memory demand
    mem_capacity: np.ndarray | None = None,  # (m,)
) -> np.ndarray:
    """(B,) max stable rates via sequential ``np.add.at`` accumulation.

    Resource-vector extras follow ``cost_model.closed_form_rates``: the
    cut-traffic column adds to the variable coefficient; memory is a hard
    feasibility mask. All-``None`` is the scalar-CPU path, byte-identical
    to before.
    """
    task_machine = np.asarray(task_machine, dtype=np.int64)
    B, T = task_machine.shape
    m = capacity.shape[0]
    rows = np.repeat(np.arange(B), T)
    cols = task_machine.reshape(-1)
    var_w = np.zeros((B, m), dtype=np.float64)
    met_w = np.zeros((B, m), dtype=np.float64)
    np.add.at(var_w, (rows, cols), np.asarray(ev, dtype=np.float64).reshape(-1))
    np.add.at(met_w, (rows, cols), np.asarray(met, dtype=np.float64).reshape(-1))
    if net_var is not None:
        var_w = var_w + net_var
    head = capacity[None, :] - met_w
    infeasible = np.any(head < 0.0, axis=1)
    if mem is not None:
        mem_w = np.zeros((B, m), dtype=np.float64)
        np.add.at(
            mem_w, (rows, cols), np.asarray(mem, dtype=np.float64).reshape(-1)
        )
        infeasible |= np.any(mem_w > mem_capacity[None, :], axis=1)
    with np.errstate(divide="ignore", over="ignore"):
        limits = np.where(var_w > 0.0, head / np.maximum(var_w, 1e-300), np.inf)
    rates = np.min(limits, axis=1) if m else np.full(B, np.inf)
    return np.where(infeasible, 0.0, np.clip(rates, 0.0, None))
