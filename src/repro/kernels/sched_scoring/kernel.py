"""Closed-form max-stable-rate scoring as a Pallas segmented-reduce kernel.

The scorer's per-machine accumulators are a segmented reduction: each
candidate row scatters T per-task loads onto m machines, then the binding
machine sets R* = min_w (cap_w - met_w) / var_w (paper eq. 5 linearity).
Scatter is serial on most backends, so the kernel goes scatter-free: a
(block_b, m, block_t) one-hot membership compare reduced over the
innermost task axis — the same contraction ``core.sim_jax._msr_kernel``
asks XLA to fuse, here staged explicitly so the accumulators never leave
VMEM.

Grid = (n_b_blocks, n_t_blocks), task axis innermost/sequential. Both
per-machine accumulators live in VMEM scratch across the task sweep; the
final task block computes head/limits/feasibility and writes the (B,)
rates. Inputs arrive pre-gathered (see ``ops.closed_form_rates_sched``):
the kernel is skew-agnostic because skew only changes the ``ev`` values,
never the reduction structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sched_scoring_pallas", "sched_scoring_pallas_resources"]


def _kernel(
    tm_ref,                      # (block_b, block_t) int32 task -> machine
    ev_ref,                      # (block_b, block_t) e * unit_ir
    met_ref,                     # (block_b, block_t) base load
    cap_ref,                     # (1, m) capacities
    o_ref,                       # (block_b, 1) rates out
    var_ref, met_w_ref,          # VMEM (block_b, m) accumulators
    *,
    n_t_blocks: int,
):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def init():
        var_ref[...] = jnp.zeros_like(var_ref)
        met_w_ref[...] = jnp.zeros_like(met_w_ref)

    tm = tm_ref[...]
    ev = ev_ref[...]
    met = met_ref[...]
    bb, bt = tm.shape
    m = var_ref.shape[1]
    # Segmented reduce without scatter: membership one-hot over machines,
    # summed over the innermost task axis. Padded task slots carry tm == m
    # and match no machine.
    wid = jax.lax.broadcasted_iota(jnp.int32, (bb, m, bt), 1)
    onehot = tm[:, None, :] == wid
    var_ref[...] += jnp.sum(jnp.where(onehot, ev[:, None, :], 0.0), axis=-1)
    met_w_ref[...] += jnp.sum(jnp.where(onehot, met[:, None, :], 0.0), axis=-1)

    @pl.when(ti == n_t_blocks - 1)
    def finalize():
        var_w = var_ref[...]
        met_w = met_w_ref[...]
        head = cap_ref[0][None, :] - met_w
        infeasible = jnp.any(head < 0.0, axis=1)
        limits = jnp.where(
            var_w > 0.0, head / jnp.maximum(var_w, 1e-300), jnp.inf
        )
        rates = jnp.clip(jnp.min(limits, axis=1), 0.0, None)
        o_ref[...] = jnp.where(infeasible, 0.0, rates)[:, None].astype(o_ref.dtype)


def _kernel_resources(
    tm_ref,                      # (block_b, block_t) int32 task -> machine
    ev_ref,                      # (block_b, block_t) e * unit_ir
    met_ref,                     # (block_b, block_t) base load
    mem_ref,                     # (block_b, block_t) per-task memory demand
    cap_ref,                     # (1, m) capacities
    net_ref,                     # (block_b, m) cut-traffic variable load
    memcap_ref,                  # (1, m) memory capacities
    o_ref,                       # (block_b, 1) rates out
    var_ref, met_w_ref, mem_w_ref,   # VMEM (block_b, m) accumulators
    *,
    n_t_blocks: int,
):
    """Resource-vector variant of ``_kernel``: one more segmented reduce
    (the memory column) plus the cut-traffic term folded into the variable
    coefficient at finalize. Same grid/blocking; the scalar-CPU kernel is
    untouched so default scoring never pays for the extra operands."""
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def init():
        var_ref[...] = jnp.zeros_like(var_ref)
        met_w_ref[...] = jnp.zeros_like(met_w_ref)
        mem_w_ref[...] = jnp.zeros_like(mem_w_ref)

    tm = tm_ref[...]
    ev = ev_ref[...]
    met = met_ref[...]
    mem = mem_ref[...]
    bb, bt = tm.shape
    m = var_ref.shape[1]
    wid = jax.lax.broadcasted_iota(jnp.int32, (bb, m, bt), 1)
    onehot = tm[:, None, :] == wid
    var_ref[...] += jnp.sum(jnp.where(onehot, ev[:, None, :], 0.0), axis=-1)
    met_w_ref[...] += jnp.sum(jnp.where(onehot, met[:, None, :], 0.0), axis=-1)
    mem_w_ref[...] += jnp.sum(jnp.where(onehot, mem[:, None, :], 0.0), axis=-1)

    @pl.when(ti == n_t_blocks - 1)
    def finalize():
        var_w = var_ref[...] + net_ref[...]
        met_w = met_w_ref[...]
        head = cap_ref[0][None, :] - met_w
        infeasible = jnp.any(head < 0.0, axis=1) | jnp.any(
            mem_w_ref[...] > memcap_ref[0][None, :], axis=1
        )
        limits = jnp.where(
            var_w > 0.0, head / jnp.maximum(var_w, 1e-300), jnp.inf
        )
        rates = jnp.clip(jnp.min(limits, axis=1), 0.0, None)
        o_ref[...] = jnp.where(infeasible, 0.0, rates)[:, None].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_t", "interpret")
)
def sched_scoring_pallas(
    task_machine: jax.Array,     # (B, T) int
    ev: jax.Array,               # (B, T) e * unit_ir, float
    met: jax.Array,              # (B, T) float
    capacity: jax.Array,         # (m,) float
    *,
    block_b: int = 256,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(B,) max stable rates; B == 0 must be handled by the caller."""
    B, T = task_machine.shape
    m = capacity.shape[0]
    bb = min(block_b, B)
    bt = min(block_t, T)
    n_b = -(-B // bb)
    n_t = -(-T // bt)
    pad_b = n_b * bb - B
    pad_t = n_t * bt - T
    tm = task_machine.astype(jnp.int32)
    if pad_b or pad_t:
        # Pad tasks with machine id m (matches no one-hot lane); padded
        # rows reduce to var_w == 0 and are sliced away below.
        tm = jnp.pad(tm, ((0, pad_b), (0, pad_t)), constant_values=m)
        ev = jnp.pad(ev, ((0, pad_b), (0, pad_t)))
        met = jnp.pad(met, ((0, pad_b), (0, pad_t)))
    kernel = functools.partial(_kernel, n_t_blocks=n_t)
    out = pl.pallas_call(
        kernel,
        grid=(n_b, n_t),
        in_specs=[
            pl.BlockSpec((bb, bt), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((bb, bt), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((bb, bt), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((1, m), lambda bi, ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda bi, ti: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((n_b * bb, 1), ev.dtype),
        scratch_shapes=[
            pltpu.VMEM((bb, m), ev.dtype),
            pltpu.VMEM((bb, m), ev.dtype),
        ],
        interpret=interpret,
    )(tm, ev, met, capacity.reshape(1, m))
    return out[:B, 0]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_t", "interpret")
)
def sched_scoring_pallas_resources(
    task_machine: jax.Array,     # (B, T) int
    ev: jax.Array,               # (B, T) e * unit_ir, float
    met: jax.Array,              # (B, T) float
    mem: jax.Array,              # (B, T) per-task memory demand, float
    capacity: jax.Array,         # (m,) float
    net_var: jax.Array,          # (B, m) cut-traffic variable load, float
    mem_capacity: jax.Array,     # (m,) float
    *,
    block_b: int = 256,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Resource-vector twin of ``sched_scoring_pallas``.

    Adds the memory feasibility mask (a third segmented reduce over the
    pre-broadcast (B, T) memory column) and the network penalty column
    (``net_var`` enters the variable coefficient at finalize, indexed by
    the batch block only — absent resource types are zeros / +inf).
    (B,) max stable rates; B == 0 must be handled by the caller.
    """
    B, T = task_machine.shape
    m = capacity.shape[0]
    bb = min(block_b, B)
    bt = min(block_t, T)
    n_b = -(-B // bb)
    n_t = -(-T // bt)
    pad_b = n_b * bb - B
    pad_t = n_t * bt - T
    tm = task_machine.astype(jnp.int32)
    if pad_b or pad_t:
        # Pad tasks with machine id m (matches no one-hot lane); padded
        # rows reduce to var_w == mem_w == 0 and are sliced away below.
        tm = jnp.pad(tm, ((0, pad_b), (0, pad_t)), constant_values=m)
        ev = jnp.pad(ev, ((0, pad_b), (0, pad_t)))
        met = jnp.pad(met, ((0, pad_b), (0, pad_t)))
        mem = jnp.pad(mem, ((0, pad_b), (0, pad_t)))
        net_var = jnp.pad(net_var, ((0, pad_b), (0, 0)))
    kernel = functools.partial(_kernel_resources, n_t_blocks=n_t)
    out = pl.pallas_call(
        kernel,
        grid=(n_b, n_t),
        in_specs=[
            pl.BlockSpec((bb, bt), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((bb, bt), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((bb, bt), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((bb, bt), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((1, m), lambda bi, ti: (0, 0)),
            pl.BlockSpec((bb, m), lambda bi, ti: (bi, 0)),
            pl.BlockSpec((1, m), lambda bi, ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda bi, ti: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((n_b * bb, 1), ev.dtype),
        scratch_shapes=[
            pltpu.VMEM((bb, m), ev.dtype),
            pltpu.VMEM((bb, m), ev.dtype),
            pltpu.VMEM((bb, m), ev.dtype),
        ],
        interpret=interpret,
    )(
        tm, ev, met, mem,
        capacity.reshape(1, m), net_var, mem_capacity.reshape(1, m),
    )
    return out[:B, 0]
