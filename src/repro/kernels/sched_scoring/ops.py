"""Dispatch + host-side gather for the scheduling-score Pallas kernel.

``closed_form_rates_sched`` is drop-in compatible with
``core.sim_jax.closed_form_rates_jax``: same (task_machine, comp, unit_ir,
e_cm, met_cm, capacity) surface covering all three scoring regimes —
shared (T,) maps, per-row (B, T) maps, and skew rows (which only differ in
the ``unit_ir`` values). The component->machine profile gather and the
throughput reduction happen on the host; the kernel sees pre-gathered
(B, T) tiles.
"""

from __future__ import annotations

import numpy as np

from repro.kernels.sched_scoring.ref import sched_scoring_ref

__all__ = ["closed_form_rates_sched"]


def closed_form_rates_sched(
    task_machine: np.ndarray,
    comp: np.ndarray,
    unit_ir: np.ndarray,
    e_cm: np.ndarray,
    met_cm: np.ndarray,
    capacity: np.ndarray,
    impl: str = "auto",
    net_var: np.ndarray | None = None,
    mem: np.ndarray | None = None,
    mem_capacity: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """(rates, throughputs) over B candidate rows.

    Args:
      task_machine: (B, T) machine index per task.
      comp / unit_ir: (T,) shared or (B, T) per-row task maps.
      e_cm / met_cm: (n_components, n_machines) profile slices.
      impl: ``"pallas"`` (compiled), ``"interpret"`` (Pallas interpreter —
        CPU-testable), ``"ref"`` (NumPy oracle), or ``"auto"`` (pallas on
        TPU, ref elsewhere).
      net_var / mem / mem_capacity: resource-vector extras with the
        ``cost_model.closed_form_rates`` semantics — (B, m) cut-traffic
        variable load, (T,)/(B, T) per-task memory demand, (m,) memory
        capacity. All ``None`` (the default) runs the scalar-CPU kernel
        unchanged; any present extra routes to the resource variant with
        zeros / +inf filling the absent type.
    """
    task_machine = np.asarray(task_machine, dtype=np.int64)
    per_row = comp.ndim == 2
    cmap = comp if per_row else comp[None, :]
    e = e_cm[cmap, task_machine]                       # (B, T)
    met = met_cm[cmap, task_machine]
    ev = e * (unit_ir if per_row else unit_ir[None, :])
    B, T = task_machine.shape
    if B == 0:
        return np.zeros(0), np.zeros(0)
    has_resources = (
        net_var is not None or mem is not None or mem_capacity is not None
    )
    if impl == "auto":
        import jax

        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "interpret"):
        from jax.experimental import enable_x64

        from repro.kernels.sched_scoring.kernel import (
            sched_scoring_pallas,
            sched_scoring_pallas_resources,
        )

        with enable_x64():
            if has_resources:
                m = capacity.shape[0]
                net_b = (
                    net_var
                    if net_var is not None
                    else np.zeros((B, m), dtype=np.float64)
                )
                mem_bt = (
                    np.broadcast_to(
                        mem if mem.ndim == 2 else mem[None, :], (B, T)
                    ).astype(np.float64, copy=False)
                    if mem is not None
                    else np.zeros((B, T), dtype=np.float64)
                )
                mem_cap = (
                    mem_capacity
                    if mem_capacity is not None
                    else np.full(m, np.inf, dtype=np.float64)
                )
                rates = np.asarray(
                    sched_scoring_pallas_resources(
                        task_machine, ev, met, mem_bt, capacity,
                        net_b, mem_cap,
                        interpret=impl == "interpret",
                    )
                )
            else:
                rates = np.asarray(
                    sched_scoring_pallas(
                        task_machine, ev, met, capacity,
                        interpret=impl == "interpret",
                    )
                )
    elif impl == "ref":
        mem_bt = None
        if mem is not None:
            mem_bt = np.broadcast_to(
                mem if mem.ndim == 2 else mem[None, :], (B, T)
            )
        rates = sched_scoring_ref(
            task_machine, ev, met, capacity,
            net_var=net_var, mem=mem_bt, mem_capacity=mem_capacity,
        )
    else:
        raise ValueError(f"unknown impl {impl!r}")
    thpt = rates * (unit_ir.sum(axis=1) if per_row else unit_ir.sum())
    return rates, thpt
