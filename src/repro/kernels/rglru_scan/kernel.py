"""RG-LRU linear-recurrence scan as a Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t, parallel over (batch, width), sequential over
time — the RecurrentGemma hot loop. TPU adaptation of the original
sequential CUDA scan:

* Grid = (n_batch_blocks, n_width_blocks, n_seq_blocks); the seq axis is
  innermost/sequential, carrying h in VMEM scratch across seq blocks.
* Per program: (block_b, block_s, block_w) tiles of a and b stream into
  VMEM; inside a tile the recurrence runs as an unrolled fori_loop over
  block_s steps of (block_b x block_w) element-wise VPU ops — the classic
  "parallel over channels, sequential over time" layout (vs. the
  associative-scan formulation used on the XLA path, which trades 2x work
  for log-depth).
* Width blocks of 128 match the VPU lane count; block_s bounds the VMEM
  working set (2 tiles x block_b x block_s x 128 x 4B).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan_pallas"]


def _kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, block_s: int, n_seq_blocks: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(t, h):
        h = a_ref[:, t, :].astype(jnp.float32) * h + b_ref[:, t, :].astype(jnp.float32)
        o_ref[:, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_s", "block_w", "interpret")
)
def rglru_scan_pallas(
    a: jax.Array,    # (B, S, W) f32
    b: jax.Array,    # (B, S, W) f32
    h0: jax.Array,   # (B, W) f32
    *,
    block_b: int = 8,
    block_s: int = 256,
    block_w: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, W = a.shape
    bb = min(block_b, B)
    bs = min(block_s, S)
    bw = min(block_w, W)
    grid = (-(-B // bb), -(-W // bw), -(-S // bs))

    kernel = functools.partial(_kernel, block_s=bs, n_seq_blocks=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((bb, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((bb, bw), lambda bi, wi, si: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((bb, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bw), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
