"""Jitted wrapper for the RG-LRU scan: backend dispatch + gate fusion entry.

``rglru_scan(a, b, h0)`` returns the full hidden sequence; models call it
with the gated inputs they computed (see repro.models.rglru for the gate
math this kernel accelerates).
"""

from __future__ import annotations

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref

__all__ = ["rglru_scan"]


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array, impl: str = "auto") -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return rglru_scan_pallas(a, b, h0)
    if impl == "interpret":
        return rglru_scan_pallas(a, b, h0, interpret=True)
    return rglru_scan_ref(a, b, h0)
