"""Pure-jnp oracle for the RG-LRU scan kernel: h_t = a_t*h_{t-1} + b_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rglru_scan_ref"]


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """Sequential reference. a/b: (B, S, W) f32; h0: (B, W). Returns (B, S, W)."""

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2), b.transpose(1, 0, 2))
    )
    return hs.transpose(1, 0, 2)
