"""Mini HLO-text analyzer: matmul FLOPs and collective bytes with
while-loop trip-count multiplication.

XLA's built-in ``compiled.cost_analysis()`` visits a while-loop body once
— a model scanned over L layers reports ~1/L of its real FLOPs, and every
collective inside the scan is similarly undercounted. The dry-run needs
honest roofline terms, so this walker:

* splits the HLO text into computations,
* counts ``dot`` FLOPs (2 x numel(result) x prod(contracting dims)) from
  operand/result shapes,
* sums collective payload bytes (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute, sync and async forms),
* recurses through fusion/call/while/conditional edges, multiplying while
  bodies by their trip count (parsed from the loop-condition constant — the
  lax.scan lowering pattern),
* also accumulates per-instruction result bytes for a coarse HBM-traffic
  estimate ("touched bytes"; an upper bound under perfect fusion).

This is a structural analyzer, not a simulator: good enough for roofline
terms, not for wall-clock prediction.
"""

from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

__all__ = ["analyze_hlo", "HloCosts"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(\(.*\))?\s*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$"
)
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations|update_computation|"
    r"comparator|called_computations)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?"
)


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(shape_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    rest: str


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.instrs: list[_Instr] = []
        self.shapes: dict[str, str] = {}  # operand name -> shape string


@dataclasses.dataclass
class HloCosts:
    matmul_flops: float = 0.0
    collective_bytes: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    touched_bytes: float = 0.0

    def add(self, other: "HloCosts", mult: float = 1.0) -> None:
        self.matmul_flops += other.matmul_flops * mult
        self.collective_bytes += other.collective_bytes * mult
        self.touched_bytes += other.touched_bytes * mult
        for k, v in other.by_kind.items():
            self.by_kind[k] = self.by_kind.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v * mult


def _parse_computations(text: str) -> tuple[dict[str, _Computation], str]:
    comps: dict[str, _Computation] = {}
    entry = None
    current: _Computation | None = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if current is None:
            m = _COMP_HEADER_RE.match(stripped.strip())
            if m and "{" in stripped:
                current = _Computation(m.group(1))
                if stripped.strip().startswith("ENTRY"):
                    entry = current.name
                # parameter shapes
                if m.group(2):
                    for pname, pshape in _PARAM_RE.findall(m.group(2)):
                        current.shapes[pname] = pshape
            continue
        if stripped.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(stripped)
        if m:
            ins = _Instr(*m.groups())
            current.instrs.append(ins)
            current.shapes[ins.name] = ins.shape
    if current is not None:
        comps[current.name] = current
    return comps, entry


def _dot_flops(instr: _Instr, comp: _Computation) -> float:
    # contracting dims from lhs
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if not mc:
        return 2.0 * _numel(instr.shape)  # dot with no info: fall back
    cdims = [int(x) for x in mc.group(1).split(",") if x]
    # First operand: newer HLO text inlines the shape ("dot(f32[a,b]{1,0}
    # %lhs, ...)"); older text has bare names resolved via comp.shapes.
    lhs_shape = None
    mo = re.match(
        r"\s*([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*%?[\w\.\-]+", instr.rest
    )
    if mo:
        lhs_shape = mo.group(1)
    else:
        mo = re.match(r"\s*%?([\w\.\-]+)", instr.rest)
        if mo and mo.group(1) in comp.shapes:
            lhs_shape = comp.shapes[mo.group(1)]
    contract = 1
    if lhs_shape:
        dims = _shape_dims(lhs_shape)
        if dims:
            _, lhs_dims = dims[0]
            for c in cdims:
                if c < len(lhs_dims):
                    contract *= lhs_dims[c]
    return 2.0 * _numel(instr.shape) * contract


def _trip_count(cond: _Computation) -> int:
    # lax.scan lowers to: compare(iter, constant(N)), direction=LT
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.shape + " constant(" + ins.rest)
            # constant value appears in rest as e.g. "42)" — parse digits
        m2 = re.match(r"\s*(\d+)\)", ins.rest)
        if ins.op == "constant" and m2:
            best = max(best, int(m2.group(1)))
    return best


def _called_names(instr: _Instr) -> dict[str, list[str]]:
    out: dict[str, list[str]] = {}
    for m in re.finditer(
        r"(calls|body|condition|to_apply|branch_computations|update_computation|comparator)="
        r"(\{[^}]*\}|%?[\w\.\-]+)",
        instr.rest,
    ):
        key, val = m.groups()
        names = re.findall(r"%?([\w\.\-]+)", val)
        out[key] = [n for n in names if n]
    return out


def analyze_hlo(text: str) -> HloCosts:
    comps, entry = _parse_computations(text)
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else None
    memo: dict[str, HloCosts] = {}

    def cost_of(name: str, stack: frozenset) -> HloCosts:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return HloCosts()
        comp = comps[name]
        stack = stack | {name}
        total = HloCosts()
        for ins in comp.instrs:
            total.touched_bytes += _shape_bytes(ins.shape)
            if ins.op == "dot":
                total.matmul_flops += _dot_flops(ins, comp)
            elif ins.op == "convolution":
                # output numel x kernel numel x 2 (rough)
                total.matmul_flops += 2.0 * _numel(ins.shape)
            base_kind = None
            for k in _COLLECTIVES:
                if ins.op == k or ins.op == k + "-start":
                    base_kind = k
                    break
            if base_kind is not None:
                b = _shape_bytes(ins.shape)
                if ins.op.endswith("-start") and ins.shape.startswith("("):
                    # async start shape is a tuple (operand, result, ...): halve
                    b = b / 2.0
                total.collective_bytes += b
                total.by_kind[base_kind] = total.by_kind.get(base_kind, 0.0) + b
                total.collective_counts[base_kind] = (
                    total.collective_counts.get(base_kind, 0.0) + 1
                )
            calls = _called_names(ins)
            if ins.op == "while":
                body = calls.get("body", [None])[0]
                cond = calls.get("condition", [None])[0]
                # XLA annotates loops with known trip counts; prefer that.
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.rest)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    total.add(cost_of(body, stack), mult=trips)
                if cond in comps:
                    total.add(cost_of(cond, stack), mult=trips)
            elif ins.op == "conditional":
                branches = calls.get("branch_computations", [])
                if branches:
                    sub = [cost_of(b, stack) for b in branches]
                    # take the max-flops branch (pessimistic)
                    total.add(max(sub, key=lambda c: c.matmul_flops))
            else:
                for key in ("calls", "to_apply", "update_computation", "comparator"):
                    for cname in calls.get(key, []):
                        total.add(cost_of(cname, stack))
        memo[name] = total
        return total

    if entry is None:
        return HloCosts()
    return cost_of(entry, frozenset())
