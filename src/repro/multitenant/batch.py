"""Tenant-batched closed-form scoring: tenants become rows.

The single-tenant engines score B candidate placements of *one* topology
per kernel call. Multi-tenant search wants to score candidates belonging
to *different* tenants — each against its own residual capacity — in one
``(B, T, m)`` closed-form evaluation, reusing the per-row task-map support
(``cost_model.per_row_task_maps`` / the ``per_row`` ``_msr_kernel``
variant) that already lets rows differ structurally.

Two ingredients make different tenants batch into one call:

* a **met fold** — tenant s's committed load is linear in its allocated
  rate R_s, so each of its tasks contributes the fixed quantity
  ``met_cm[c, w] + e_cm[c, w] * unit_ir_task * R_s`` to its machine
  (skew-aware: the per-task unit IR comes from
  ``SkewModel.per_task_unit_ir`` when the tenant has a key-share model).
  Folding those per-task loads onto their incumbent machines (one
  canonical-order ``bincount``) prices the whole fleet as one fixed
  (m,) frozen-load vector F, and tenant t's residual capacity is
  ``cluster.capacity - (F - F_t_own)``.

* **per-row capacity** — ``closed_form_rates`` and the jitted
  ``_msr_kernel`` accept a (B, m) capacity matrix, so each candidate row
  scores against *its* tenant's residual. Rows stay compact: width is
  max tenant task count (co-tenants live in the capacity row, not in
  frozen columns), padded with a zero profile row for shorter tenants.

The closed form then returns exactly tenant t's residual R* and
throughput per row. Rows dispatch through the same ``backend="auto"``
crossover policy as the single-tenant path.

Floats differ from the explicit residual-capacity subtraction only in
summation association (~1e-15 relative); ``tests/test_multitenant_golden``
pins parity at 1e-12 with identical argmax.
"""

from __future__ import annotations

import numpy as np

from repro.core import cost_model
from repro.core.schedule_state import ScheduleState

from repro.multitenant.state import MultiTenantState

__all__ = ["TenantBatchScorer"]


class TenantBatchScorer:
    """Score count-preserving candidate rows for many tenants in one call.

    Snapshots the multi-tenant state's committed rates at construction
    (the met fold bakes them into the frozen-load vector) — rebuild the
    scorer after rates or placements change. Candidate rows must keep
    each tenant's instance counts (RELOCATE/SWAP-style sweeps); growth
    moves go through the per-tenant refine path on residual clusters.
    """

    def __init__(self, mt: MultiTenantState, backend: str = "auto"):
        self.mt = mt
        self.backend = backend
        self.candidates_evaluated = 0

        states = mt.states
        self._has_skew = any(st.skew is not None for st in states)

        # Blocks concatenate in canonical (name) order — NOT submission
        # order — so the frozen-load bincount sums every tenant's tasks in
        # one canonical sequence and scores are bit-identical under
        # submission-order permutations. Per-tenant spans map a tenant
        # *index* to its rows/columns.
        order = mt.tenant_set.canonical_order()
        self._comp_span: dict[int, tuple[int, int]] = {}
        self._task_span: dict[int, tuple[int, int]] = {}
        n_all = 0
        t_all = 0
        for t in order:
            st = states[t]
            self._comp_span[t] = (n_all, n_all + st.utg.n_components)
            self._task_span[t] = (t_all, t_all + int(st.n_instances.sum()))
            n_all += st.utg.n_components
            t_all += int(st.n_instances.sum())
        self.n_tasks = t_all
        self.t_max = max(hi - lo for lo, hi in self._task_span.values())

        m = mt.cluster.n_machines
        e_act = np.concatenate([states[t].e_cm for t in order], axis=0)
        met_act = np.concatenate([states[t].met_cm for t in order], axis=0)
        # One zero profile row pads short tenants' columns: a padding task
        # parks on machine 0 with e = met = unit = 0 and contributes
        # nothing to either accumulator.
        self.pad_comp = n_all
        self.e_table = np.concatenate([e_act, np.zeros((1, m))], axis=0)
        self.met_table = np.concatenate([met_act, np.zeros((1, m))], axis=0)

        # Concatenated incumbent row, per-task active maps, and the met
        # fold: each task's committed load on its incumbent machine.
        self._has_network = mt.cluster.has_network
        self._has_memory = mt.cluster.has_memory
        base_row = np.concatenate([states[t].task_machine() for t in order])
        active_comp = np.empty(t_all, dtype=np.int64)
        active_unit = np.empty(t_all, dtype=np.float64)
        task_load = np.empty(t_all, dtype=np.float64)
        # Local (tenant-topology) task maps for score-time network pricing,
        # and the per-task memory column: memory demand is rate-independent,
        # so it needs no fold — pad columns carry 0 and contribute nothing.
        self._local_comp: dict[int, np.ndarray] = {}
        self._active_mem = (
            np.empty(t_all, dtype=np.float64) if self._has_memory else None
        )
        net_own = np.zeros((len(states), m), dtype=np.float64)
        for t in order:
            st = states[t]
            lo, hi = self._task_span[t]
            comp_t = np.repeat(np.arange(st.utg.n_components), st.n_instances)
            if st.skew is not None:
                unit_t = st.skew.per_task_unit_ir(st.n_instances)
            else:
                unit_t = (st.cir_unit / st.n_instances)[comp_t]
            active_comp[lo:hi] = self._comp_span[t][0] + comp_t
            active_unit[lo:hi] = unit_t
            self._local_comp[t] = comp_t
            if self._has_memory:
                self._active_mem[lo:hi] = st.mem_c[comp_t]
            if self._has_network:
                # Tenant t's committed cut-traffic CPU load at its rate —
                # part of the met fold (also linear in R_t, machine-indexed
                # rather than task-indexed, so it adds after the bincount).
                net_own[t] = float(mt.rates[t]) * st.net_load
            rate_t = float(mt.rates[t])
            w = base_row[lo:hi]
            task_load[lo:hi] = (
                st.met_cm[comp_t, w] + st.e_cm[comp_t, w] * unit_t * rate_t
            )

        self.base_row = base_row
        self.active_comp = active_comp
        self.active_unit = active_unit
        # Fleet frozen load F (canonical-order bincount, plus each tenant's
        # committed network load), then per-tenant residual capacity:
        # cluster capacity minus everyone *else*.
        frozen = np.bincount(base_row, weights=task_load, minlength=m)
        if self._has_network:
            for t in order:
                frozen = frozen + net_own[t]
        self._resid_cap = np.empty((len(states), m), dtype=np.float64)
        for t in order:
            lo, hi = self._task_span[t]
            own = np.bincount(
                base_row[lo:hi], weights=task_load[lo:hi], minlength=m
            )
            if self._has_network:
                own = own + net_own[t]
            self._resid_cap[t] = mt.cluster.capacity - (frozen - own)
        # Residual memory capacity per tenant: neighbours' rate-independent
        # working sets come straight off each machine's memory budget.
        self._resid_mem: np.ndarray | None = None
        if self._has_memory:
            frozen_mem = np.zeros(m, dtype=np.float64)
            for t in order:
                frozen_mem = frozen_mem + states[t].mem_load
            self._resid_mem = np.empty((len(states), m), dtype=np.float64)
            for t in order:
                self._resid_mem[t] = mt.cluster.mem_capacity - (
                    frozen_mem - states[t].mem_load
                )

    # ----------------------------------------------------------- scoring

    def score(
        self, sweeps: "list[tuple[int, np.ndarray]]"
    ) -> "list[tuple[np.ndarray, np.ndarray]]":
        """Score candidate sweeps for several tenants in one kernel call.

        Args:
          sweeps: list of ``(tenant_index, rows)`` where ``rows`` is a
            (B_t, T_t) array of candidate placements for that tenant's
            column block (T_t = tenant's task count). B_t = 0 sweeps are
            allowed and return empty scores.

        Returns:
          One ``(rates, throughputs)`` pair per sweep, in order — each
          tenant's residual closed-form scores for its rows.
        """
        sizes = []
        for t, rows in sweeps:
            rows = np.asarray(rows, dtype=np.int64)
            lo, hi = self._task_span[t]
            if rows.ndim != 2 or rows.shape[1] != hi - lo:
                raise ValueError(
                    f"tenant {t} sweep must be (B, {hi - lo}), got {rows.shape}"
                )
            sizes.append(rows.shape[0])
        b_total = int(sum(sizes))
        if b_total == 0:
            empty = np.zeros(0, dtype=np.float64)
            return [(empty.copy(), empty.copy()) for _ in sweeps]

        cluster = self.mt.cluster
        m = cluster.n_machines
        tm = np.zeros((b_total, self.t_max), dtype=np.int64)
        comp = np.full((b_total, self.t_max), self.pad_comp, dtype=np.int64)
        unit = np.zeros((b_total, self.t_max), dtype=np.float64)
        cap = np.empty((b_total, m), dtype=np.float64)
        # Resource-vector columns: each tenant's candidate rows price their
        # *own* topology's cut traffic (cross-tenant traffic does not exist
        # — tenants are separate topologies) against the shared distance
        # matrix, and their memory against the tenant's residual memory.
        net = (
            np.empty((b_total, m), dtype=np.float64)
            if self._has_network
            else None
        )
        mem = (
            np.zeros((b_total, self.t_max), dtype=np.float64)
            if self._has_memory
            else None
        )
        memcap = (
            np.empty((b_total, m), dtype=np.float64)
            if self._has_memory
            else None
        )
        row0 = 0
        for (t, rows), b_t in zip(sweeps, sizes):
            if b_t == 0:
                continue
            lo, hi = self._task_span[t]
            w = hi - lo
            sl = slice(row0, row0 + b_t)
            rows_arr = np.asarray(rows, dtype=np.int64)
            tm[sl, :w] = rows_arr
            comp[sl, :w] = self.active_comp[lo:hi]
            unit[sl, :w] = self.active_unit[lo:hi]
            cap[sl] = self._resid_cap[t]
            if self._has_network:
                st = self.mt.states[t]
                net[sl] = cost_model.network_unit_load(
                    rows_arr,
                    self._local_comp[t],
                    self.active_unit[lo:hi],
                    st.utg.alpha,
                    st.cir_unit,
                    st.utg.edges,
                    cluster.distance,
                    cluster.net_penalty,
                )
            if self._has_memory:
                mem[sl, :w] = self._active_mem[lo:hi]
                memcap[sl] = self._resid_mem[t]
            row0 += b_t

        rates, thpt = self._dispatch(
            tm, comp, unit, cap, net_var=net, mem=mem, mem_capacity=memcap
        )
        self.candidates_evaluated += b_total
        out: list[tuple[np.ndarray, np.ndarray]] = []
        row0 = 0
        for b_t in sizes:
            out.append((rates[row0 : row0 + b_t], thpt[row0 : row0 + b_t]))
            row0 += b_t
        return out

    def residual_rates(self) -> np.ndarray:
        """(N,) residual closed-form R* of every tenant's incumbent row —
        all tenants scored as rows of one batched call."""
        sweeps = []
        for t in range(len(self.mt.states)):
            lo, hi = self._task_span[t]
            sweeps.append((t, self.base_row[lo:hi][None, :]))
        scored = self.score(sweeps)
        return np.array([float(r[0]) for r, _ in scored], dtype=np.float64)

    def _dispatch(
        self,
        tm: np.ndarray,
        comp: np.ndarray,
        unit: np.ndarray,
        capacity: np.ndarray,
        net_var: np.ndarray | None = None,
        mem: np.ndarray | None = None,
        mem_capacity: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        from repro.core.simulator import resolve_closed_form_backend

        resolved = resolve_closed_form_backend(
            self.backend,
            tm.size,
            regime="skew" if self._has_skew else "per_row",
            n_machines=capacity.shape[-1],
            site="tenant_batch",
        )
        if resolved == "jax":
            from repro.core.sim_jax import closed_form_rates_jax

            return closed_form_rates_jax(
                tm, comp, unit, self.e_table, self.met_table, capacity,
                net_var=net_var, mem=mem, mem_capacity=mem_capacity,
            )
        e = self.e_table[comp, tm]
        met = self.met_table[comp, tm]
        return cost_model.closed_form_rates(
            tm, e, met, unit, capacity,
            net_var=net_var, mem=mem, mem_capacity=mem_capacity,
        )

    # ------------------------------------------------- reference (tests)

    def reference_scores(
        self, tenant: int, rows: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-tenant NumPy reference: explicit residual-capacity scoring.

        Builds a fresh single-tenant state on ``residual_cluster(tenant)``
        and scores ``rows`` through the stock NumPy path — the loop the
        parity tests compare the batched scoring against.
        """
        mt = self.mt
        st = mt.states[tenant]
        solo = ScheduleState.from_etg(
            st.to_etg(), mt.residual_cluster(tenant), skew=st.skew
        )
        return solo.score_task_machine_batch(
            np.asarray(rows, dtype=np.int64), backend="numpy"
        )
