"""Tenant descriptors for multi-tenant scheduling.

A *tenant* is one user topology submitted to the shared heterogeneous
cluster together with its service contract: a target input rate (the
tuple/s the tenant paid for) and a priority weight. All tenants share the
cluster's profile table — a tenant's ``component_types`` index into the
profile the cluster was built with, exactly as in the single-tenant path.

``TenantSet`` is the canonical container: it enforces unique tenant names
and defines the *canonical order* (sorted by name) that every allocation
loop processes tenants in, which is what makes the fairness allocation
invariant under permutations of the input list (tested in
``tests/test_multitenant_properties.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

from repro.core import cost_model
from repro.core.graph import UserGraph

__all__ = ["Tenant", "TenantSet"]


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One user topology plus its service contract.

    Attributes:
      name: unique tenant identifier (canonical ordering key).
      utg: the tenant's user topology graph.
      target_rate: contracted topology input rate R_target (tuples/s), > 0.
        Fairness is expressed on the satisfaction ratio ``R / R_target``.
      priority: weight applied to the satisfaction ratio; a priority-2
        tenant reaches the same fairness level at half the satisfaction
        of a priority-1 tenant (weighted max-min, Ghaderi et al.).
      skew: optional per-instance key-share model for keyed groupings
        (``cost_model.SkewModel``); must be built on ``utg``.
    """

    name: str
    utg: UserGraph
    target_rate: float
    priority: float = 1.0
    skew: "cost_model.SkewModel | None" = None

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if not self.target_rate > 0.0:
            raise ValueError(f"target_rate must be > 0, got {self.target_rate}")
        if not self.priority > 0.0:
            raise ValueError(f"priority must be > 0, got {self.priority}")
        if self.skew is not None and self.skew.utg is not self.utg:
            raise ValueError(f"tenant {self.name!r}: skew model built for a different topology")

    @property
    def level_scale(self) -> float:
        """Denominator mapping a rate to its fairness level:
        ``level = R / (target_rate * priority)``."""
        return self.target_rate * self.priority


class TenantSet:
    """Validated, order-preserving collection of tenants.

    Keeps the tenants in submission order (results are reported in that
    order) while exposing ``canonical_order`` — indices sorted by tenant
    name — which the water-filling loop uses for every tie-break so the
    allocation does not depend on submission order.
    """

    __slots__ = ("tenants",)

    def __init__(self, tenants: Sequence[Tenant]):
        tenants = tuple(tenants)
        if not tenants:
            raise ValueError("TenantSet needs at least one tenant")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate tenant names: {dupes}")
        self.tenants = tenants

    def __len__(self) -> int:
        return len(self.tenants)

    def __iter__(self) -> Iterator[Tenant]:
        return iter(self.tenants)

    def __getitem__(self, i: int) -> Tenant:
        return self.tenants[i]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.tenants)

    def canonical_order(self) -> list[int]:
        """Indices into the submission order, sorted by tenant name."""
        return sorted(range(len(self.tenants)), key=lambda i: self.tenants[i].name)

    def index_of(self, name: str) -> int:
        for i, t in enumerate(self.tenants):
            if t.name == name:
                return i
        raise KeyError(name)
