"""Multi-tenant scheduling on one shared heterogeneous cluster.

N user topologies, each with a contracted target rate and priority, share
the machines. Per-tenant ``ScheduleState``s share one machine-load vector
(exact cross-tenant interference pricing via the linear load model), a
water-filling loop allocates weighted max-min fair rates, and candidate
sweeps of *different* tenants batch into single closed-form kernel calls
(tenants become rows). See ``docs/architecture.md`` (multi-tenant
section) for the derivation and guarantees.
"""

from repro.multitenant.batch import TenantBatchScorer
from repro.multitenant.fairness import (
    MultiTenantSchedule,
    TenantAllocation,
    fair_shares,
    fair_slice_floors,
    schedule_tenants,
)
from repro.multitenant.runtime import (
    MultiTenantRuntime,
    MultiTenantRuntimeResult,
    MultiTenantTrace,
    ReplanArbiter,
    compile_tenant_traces,
)
from repro.multitenant.state import MultiTenantState
from repro.multitenant.tenants import Tenant, TenantSet

__all__ = [
    "Tenant",
    "TenantSet",
    "MultiTenantState",
    "TenantBatchScorer",
    "TenantAllocation",
    "MultiTenantSchedule",
    "fair_shares",
    "fair_slice_floors",
    "schedule_tenants",
    "MultiTenantTrace",
    "compile_tenant_traces",
    "ReplanArbiter",
    "MultiTenantRuntime",
    "MultiTenantRuntimeResult",
]
