"""Multi-tenant extension of the streaming runtime.

Each tenant runs its own trace-driven event loop, but all loops share one
cluster: tenant t's executor sees the shared capacity grid minus every
co-tenant's *planned* load (linear model at the co-tenant's allocated
rate, demand-capped by its offered trace), via
``StreamExecutor(background_load=...)``. Controller observations therefore
carry residual capacities, so a tenant's replans are priced against the
head room that is actually its to use — a failure or skew replan cannot
claim capacity a neighbour's allocation owns.

Cross-tenant replan arbitration is a shared ``ReplanArbiter`` ledger:
every tenant's ``OnlineController`` is wrapped so its migrations draw from
a fixed per-tenant budget per control period. One tenant thrashing through
drift events exhausts only its own budget; the others keep replanning.

``compile_tenant_traces`` compiles one ``TraceSpec`` per tenant onto a
single shared capacity grid (machine slowdowns and failures are cluster
events — every tenant must see the same machines).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.profiles import Cluster
from repro.core.schedule_state import ScheduleState
from repro.obs.ledger import ReplanDecision
from repro.obs.trace import NULL_RECORDER

from repro.runtime_stream.controller import OnlineController
from repro.runtime_stream.executor import (
    RuntimeConfig,
    RuntimeResult,
    StreamExecutor,
    placement_migrations,
)
from repro.runtime_stream.traces import CompiledTrace, TraceSpec

from repro.multitenant.fairness import MultiTenantSchedule
from repro.multitenant.tenants import TenantSet

__all__ = [
    "MultiTenantTrace",
    "compile_tenant_traces",
    "ReplanArbiter",
    "TenantArbiterLedger",
    "MultiTenantRuntime",
    "MultiTenantRuntimeResult",
]


@dataclasses.dataclass(frozen=True)
class MultiTenantTrace:
    """Per-tenant compiled traces on one shared capacity grid."""

    names: tuple[str, ...]
    traces: tuple[CompiledTrace, ...]
    capacity: np.ndarray  # (W, m) — shared by every tenant

    @property
    def n_windows(self) -> int:
        return int(self.capacity.shape[0])

    @property
    def window_s(self) -> float:
        return float(self.traces[0].window_s)

    def trace_for(self, name: str) -> CompiledTrace:
        return self.traces[self.names.index(name)]


def compile_tenant_traces(
    tenants: TenantSet,
    specs: "list[TraceSpec]",
    cluster: Cluster,
    seed: int = 0,
    capacity_spec: "TraceSpec | None" = None,
) -> MultiTenantTrace:
    """Compile one spec per tenant onto a single shared capacity grid.

    Each tenant's spec compiles with an independent child seed (so rate
    noise / keyed realizations decorrelate across tenants) and with its
    own topology (keyed edges). Capacity events — slowdowns, failures —
    live in ``capacity_spec`` (default: the nominal flat grid): machines
    are shared, so every tenant must observe the same capacity trajectory;
    per-tenant capacity events in ``specs`` are rejected.
    """
    if len(specs) != len(tenants):
        raise ValueError("one TraceSpec per tenant required")
    horizon = {(s.n_windows, getattr(s, "window_s", None)) for s in specs}
    if len({s.n_windows for s in specs}) != 1:
        raise ValueError("tenant traces must share one horizon (n_windows)")
    del horizon

    if capacity_spec is None:
        cap_grid = np.broadcast_to(
            cluster.capacity, (specs[0].n_windows, cluster.n_machines)
        ).astype(np.float64)
    else:
        if capacity_spec.n_windows != specs[0].n_windows:
            raise ValueError("capacity_spec horizon must match tenant specs")
        cap_grid = capacity_spec.compile(cluster, seed).capacity

    traces = []
    for i, (tenant, spec) in enumerate(zip(tenants, specs)):
        child_seed = int(np.random.SeedSequence([seed, i]).generate_state(1)[0])
        compiled = spec.compile(cluster, child_seed, utg=tenant.utg)
        if not np.array_equal(
            compiled.capacity,
            np.broadcast_to(cluster.capacity, compiled.capacity.shape),
        ):
            raise ValueError(
                f"tenant {tenant.name!r} spec carries capacity events — put "
                "machine slowdowns/failures in capacity_spec (shared machines)"
            )
        traces.append(dataclasses.replace(compiled, capacity=cap_grid.copy()))
    return MultiTenantTrace(
        names=tuple(t.name for t in tenants),
        traces=tuple(traces),
        capacity=cap_grid,
    )


@dataclasses.dataclass(frozen=True)
class TenantArbiterLedger:
    """One tenant's view of the shared ``ReplanArbiter`` ledger.

    ``budget_remaining`` lists, per control period the tenant actually
    requested admission in, the moves left of its ``moves_per_period``
    budget after all admissions in that period.
    """

    name: str
    grants: int
    denials: int
    moves_admitted: int
    moves_denied: int
    moves_per_period: int
    budget_remaining: tuple[tuple[int, int], ...]  # (period index, moves left)


class ReplanArbiter:
    """Shared migration-budget ledger across tenants' controllers.

    Each tenant may migrate at most ``moves_per_period`` instances per
    control period. Budgets are strictly per tenant, so no admission by
    one tenant can ever reduce another's — the starvation guard is by
    construction, not by scheduling order.
    """

    def __init__(self, moves_per_period: int = 8, recorder=None):
        self.moves_per_period = int(moves_per_period)
        self.recorder = NULL_RECORDER if recorder is None else recorder
        self._used: dict[tuple[str, int], int] = {}
        self.log: list[tuple[str, int, int, bool]] = []  # (tenant, window, moves, admitted)

    def admit(self, tenant: str, window: int, period: int, moves: int) -> bool:
        bucket = (tenant, window // max(period, 1))
        used = self._used.get(bucket, 0)
        ok = used + moves <= self.moves_per_period
        if ok:
            self._used[bucket] = used + moves
        self.log.append((tenant, int(window), int(moves), ok))
        rec = self.recorder
        if rec.enabled:
            rec.metrics.counter(
                "arbiter.grants" if ok else "arbiter.denials"
            ).add(1)
            rec.event(
                "arbiter_grant" if ok else "arbiter_denial",
                cat="arbiter",
                tenant=tenant,
                moves=int(moves),
                remaining=self.moves_per_period - self._used.get(bucket, used),
            )
        return ok

    def tenant_summary(self, tenant: str) -> TenantArbiterLedger:
        """Roll this tenant's ledger rows up into a ``TenantArbiterLedger``."""
        grants = denials = admitted = denied = 0
        for name, _w, moves, ok in self.log:
            if name != tenant:
                continue
            if ok:
                grants += 1
                admitted += moves
            else:
                denials += 1
                denied += moves
        remaining = tuple(
            (period, self.moves_per_period - used)
            for (name, period), used in sorted(self._used.items())
            if name == tenant
        )
        return TenantArbiterLedger(
            name=tenant,
            grants=grants,
            denials=denials,
            moves_admitted=admitted,
            moves_denied=denied,
            moves_per_period=self.moves_per_period,
            budget_remaining=remaining,
        )


class _ArbitratedController:
    """Wrap one tenant's controller so its replans draw from the arbiter."""

    def __init__(self, name: str, inner: OnlineController, arbiter: ReplanArbiter):
        self.name = name
        self.inner = inner
        self.arbiter = arbiter

    @property
    def period(self) -> int:
        return self.inner.period

    def update(self, obs):
        plan = self.inner.update(obs)
        if plan is None:
            return None
        moves = placement_migrations(obs.etg, plan)
        if self.arbiter.admit(self.name, obs.window, self.period, moves):
            return plan
        # The inner controller just accepted a replan (outcome="replan" in
        # its ledger) that the arbiter now denies: record the denial as a
        # structured "deferred" decision — its legacy entry reproduces the
        # historical in-band (window, "deferred:arbiter", moves) 3-tuple.
        last = self.inner.ledger[-1] if self.inner.ledger else None
        self.inner._decide(
            ReplanDecision(
                window=obs.window,
                trigger=last.trigger if last is not None else "arbiter",
                outcome="deferred",
                moves=int(moves),
                candidate_moves=last.candidate_moves if last is not None else (),
            )
        )
        return None


@dataclasses.dataclass(frozen=True)
class MultiTenantRuntimeResult:
    """Per-tenant runtime results plus the cross-tenant summary."""

    names: tuple[str, ...]
    results: tuple[RuntimeResult, ...]
    satisfaction: np.ndarray  # (N,) tail admitted rate / target rate
    arbiter_log: tuple[tuple[str, int, int, bool], ...]
    # Per-tenant arbiter roll-ups (grants, denials, budget remaining per
    # period), aligned with ``names``; empty when run offline.
    arbiter: tuple[TenantArbiterLedger, ...] = ()

    def result_for(self, name: str) -> RuntimeResult:
        return self.results[self.names.index(name)]

    def arbiter_for(self, name: str) -> TenantArbiterLedger:
        return self.arbiter[self.names.index(name)]


class MultiTenantRuntime:
    """Run every tenant's stream on the shared cluster, priced residually.

    Args:
      plan: the fairness allocation (``schedule_tenants`` output).
      tenants: the tenant set the plan was computed for.
      cluster: the shared cluster.
      mtrace: per-tenant traces on one capacity grid
        (``compile_tenant_traces``).
      config: event-loop constants (shared by every tenant's executor).
    """

    def __init__(
        self,
        plan: MultiTenantSchedule,
        tenants: TenantSet,
        cluster: Cluster,
        mtrace: MultiTenantTrace,
        config: RuntimeConfig | None = None,
    ):
        if tuple(t.name for t in tenants) != tuple(a.name for a in plan.allocations):
            raise ValueError("plan allocations must align with the tenant set")
        if mtrace.names != tuple(t.name for t in tenants):
            raise ValueError("mtrace tenants must align with the tenant set")
        self.plan = plan
        self.tenants = tenants
        self.cluster = cluster
        self.mtrace = mtrace
        self.config = config or RuntimeConfig()

    def planned_loads(self) -> np.ndarray:
        """(N, W, m) per-tenant planned machine load per window.

        Linear model at the tenant's allocated rate, demand-capped by its
        offered trace: ``met + min(offered_w, R_alloc) * var``. This is the
        load a co-tenant's executor must assume is spoken for (even-split
        coefficients; realized key skew shifts within a machine's share).
        """
        W = self.mtrace.n_windows
        m = self.cluster.n_machines
        out = np.zeros((len(self.tenants), W, m), dtype=np.float64)
        for i, alloc in enumerate(self.plan.allocations):
            st = ScheduleState.from_etg(alloc.etg, self.cluster)
            eff = np.minimum(self.mtrace.traces[i].rates, alloc.rate)  # (W,)
            out[i] = st.met_load[None, :] + eff[:, None] * st.var_load[None, :]
        return out

    def run(
        self,
        online: bool = True,
        moves_per_period: int = 8,
        controller_kwargs: "dict | None" = None,
        recorder=None,
    ) -> MultiTenantRuntimeResult:
        """Execute all tenants' windows; returns per-tenant results.

        With ``online=True`` each tenant gets an ``OnlineController`` on
        its residual capacity view, wrapped by one shared ``ReplanArbiter``
        so drift replans cannot starve co-tenants of migration bandwidth.

        A ``repro.obs.TraceRecorder`` passed as ``recorder`` is shared by
        every tenant's executor, controller and the arbiter: each tenant's
        run nests under a ``tenant:<name>`` span, and the per-tenant
        arbiter roll-ups land on the result's ``arbiter`` field either
        way.
        """
        rec = NULL_RECORDER if recorder is None else recorder
        loads = self.planned_loads()
        total = loads.sum(axis=0)  # (W, m)
        arbiter = ReplanArbiter(moves_per_period, recorder=rec)
        results = []
        sat = np.zeros(len(self.tenants), dtype=np.float64)
        for i, (tenant, alloc) in enumerate(zip(self.tenants, self.plan.allocations)):
            bg = total - loads[i]
            executor = StreamExecutor(
                alloc.etg,
                self.cluster,
                self.mtrace.traces[i],
                config=self.config,
                background_load=bg,
                recorder=rec if rec.enabled else None,
            )
            controller = None
            if online:
                inner = OnlineController(
                    tenant.utg,
                    self.cluster,
                    recorder=rec if rec.enabled else None,
                    **(controller_kwargs or {}),
                )
                controller = _ArbitratedController(tenant.name, inner, arbiter)
            with rec.span(f"tenant:{tenant.name}", cat="tenant"):
                res = executor.run(controller=controller)
            results.append(res)
            start = res.n_windows // 2
            sat[i] = float(res.admitted[start:].mean()) / tenant.target_rate
        return MultiTenantRuntimeResult(
            names=self.mtrace.names,
            results=tuple(results),
            satisfaction=sat,
            arbiter_log=tuple(arbiter.log),
            arbiter=tuple(
                arbiter.tenant_summary(name) for name in self.mtrace.names
            ),
        )
