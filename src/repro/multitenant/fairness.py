"""Weighted max-min fair rate allocation across tenants (water filling).

The objective follows Ghaderi et al. (PAPERS.md): maximize the minimum
*fairness level* ``u_t = (R_t / R_target_t) / priority_t`` across tenants,
then the next minimum, and so on (leximin). The driver is a round-robin
water-filling loop over the existing closed-form machinery:

* **fair-slice warm start** — every tenant is first scheduled solo on its
  proportional capacity slice ``f_t = priority_t * target_t / sum_s
  priority_s * target_s`` (the weighted fair share). The slices partition
  the capacity, so the ensemble of accepted warm-start placements is
  feasible on the shared cluster and each tenant opens at its fair-slice
  solo rate — the *solo-no-regression* guarantee holds by construction,
  because committed rates only ever increase from here. A slice can be
  too thin to host even one instance per component (MET is lumpy: an
  instance's fixed overhead cannot be fractionally spread, so at large N
  a 1%-of-each-machine slice may not fit it anywhere); such a tenant's
  fair-slice solo rate is exactly 0, and it instead *defers* to a minimal
  placement on the ensemble residual at rate 0 — no-regression stays
  trivially true and the water loop serves these level-0 tenants first.
  Accepted tenants then re-slice the MET-reduced capacity (fixpoint, at
  most N iterations), so the ensemble stays feasible by construction;
* each round picks the active tenant with the lowest level (canonical
  name-order tie-break) and raises its rate toward the closed-form
  residual R* — the exact maximum the shared cluster supports given every
  other tenant's committed load (priced through the shared-load view in
  ``MultiTenantState``);
* a tenant blocked at its residual R* spends one of its bounded
  ``structure_attempts`` on *structural* moves: a single-tenant
  ``refine`` pass on its residual cluster (RELOCATE / SWAP / GROW —
  other tenants' committed loads are baked into the residual capacity,
  so no move can evict a neighbour below its share), then a guarded
  **cross-tenant relocation** that shifts another tenant's instance off
  the blocked tenant's binding machine, batch-scored through
  ``TenantBatchScorer`` and accepted only if *every* tenant's committed
  rate stays feasible;
* a tenant blocked with no structural escape (or out of attempts) is
  deactivated with its rate committed. Committed rates never degrade
  afterwards: every later raise is capped by a residual that already
  prices the committed load, and every relocation re-checks all tenants
  before applying.

Levels fill in near-lockstep (``level_step`` bounds how far one tenant may
overshoot the pack), approximating leximin while reusing the single-tenant
engines unchanged. ``N == 1`` short-circuits to the stock
``schedule() + refine()`` pipeline and is bit-identical to it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.first_assignment import first_assignment
from repro.core.graph import ExecutionGraph
from repro.core.maximize_throughput import schedule
from repro.core.profiles import Cluster
from repro.core.refine import refine
from repro.core.schedule_state import ScheduleState

from repro.multitenant.batch import TenantBatchScorer
from repro.multitenant.state import MultiTenantState
from repro.multitenant.tenants import Tenant, TenantSet

__all__ = [
    "TenantAllocation",
    "MultiTenantSchedule",
    "fair_shares",
    "fair_slice_floors",
    "schedule_tenants",
]

# Relative slack when checking a committed rate is still feasible after a
# structural move (absorbs last-ulp drift of the residual closed form).
_COMMIT_SLACK = 1e-9

# Relative back-off applied to warm-start rates. A solo refine rate makes
# its binding machine's load touch the slice capacity *exactly*, so N
# tenants' warm loads would sum to capacity up to accumulated rounding —
# and any machine landing a few ulps over collapses closed-form residuals
# to zero. Backing each warm rate off by 1e-9 leaves ~1e-7 absolute head
# room per machine, orders of magnitude above the accumulation error,
# while costing a relative 1e-9 of rate (recoverable by the water loop).
_WARM_BACKOFF = 1e-9


@dataclasses.dataclass(frozen=True)
class TenantAllocation:
    """One tenant's share of the shared cluster."""

    name: str
    etg: ExecutionGraph
    rate: float
    target_rate: float
    priority: float

    @property
    def satisfaction(self) -> float:
        """Allocated over contracted rate, ``R / R_target``."""
        return self.rate / self.target_rate

    @property
    def level(self) -> float:
        """Weighted fairness level ``satisfaction / priority``."""
        return self.satisfaction / self.priority


@dataclasses.dataclass(frozen=True)
class MultiTenantSchedule:
    """Fairness allocation for a tenant set (reported in submission order)."""

    allocations: tuple[TenantAllocation, ...]
    rounds: int
    candidates_evaluated: int
    log: tuple[str, ...]

    def allocation(self, name: str) -> TenantAllocation:
        for a in self.allocations:
            if a.name == name:
                return a
        raise KeyError(name)

    @property
    def rates(self) -> np.ndarray:
        return np.array([a.rate for a in self.allocations], dtype=np.float64)

    @property
    def levels(self) -> np.ndarray:
        return np.array([a.level for a in self.allocations], dtype=np.float64)

    @property
    def min_level(self) -> float:
        return float(self.levels.min())


def fair_shares(tenants: "TenantSet | list[Tenant]") -> np.ndarray:
    """(N,) weighted fair capacity share per tenant (submission order):
    ``f_t = priority_t * target_t / sum_s priority_s * target_s``.

    The denominator sums in canonical (name) order so shares — and
    everything warm-started from them — are bit-identical under tenant
    submission-order permutations.
    """
    tset = tenants if isinstance(tenants, TenantSet) else TenantSet(tenants)
    scales = np.array([t.level_scale for t in tset], dtype=np.float64)
    denom = 0.0
    for i in tset.canonical_order():
        denom += float(scales[i])
    return scales / denom


def fair_slice_floors(
    tenants: "TenantSet | list[Tenant]",
    cluster: Cluster,
    *,
    warm_refine_rounds: int = 200,
    backend: str = "auto",
    solo_rate_epsilon: float = 0.5,
) -> np.ndarray:
    """(N,) guaranteed rate floor per tenant (submission order).

    This is exactly the warm-start baseline ``schedule_tenants`` opens
    from: each tenant's solo rate on its fair slice of the MET-reduced
    working capacity (0.0 for tenants whose slice cannot host their
    rate-0 load — see ``_warm_start``). The water loop only raises rates,
    so ``schedule_tenants(...)`` with the same budgets allocates every
    tenant at least this floor — the solo-no-regression guarantee, in a
    form benchmarks and tests can recompute independently.
    """
    tset = tenants if isinstance(tenants, TenantSet) else TenantSet(tenants)
    _, rates = _warm_start(
        tset,
        cluster,
        tset.canonical_order(),
        warm_refine_rounds=warm_refine_rounds,
        backend=backend,
        solo_rate_epsilon=solo_rate_epsilon,
    )
    return rates


def schedule_tenants(
    tenants: "TenantSet | list[Tenant]",
    cluster: Cluster,
    *,
    warm_start: bool = True,
    warm_refine_rounds: int = 200,
    level_step: float = 0.25,
    rate_tol: float = 1e-6,
    refine_moves: int = 2,
    structure_attempts: int = 4,
    cross_tenant_moves: bool = True,
    max_rounds: int = 10_000,
    backend: str = "auto",
    solo_rate_epsilon: float = 0.5,
    validate: bool = False,
) -> MultiTenantSchedule:
    """Weighted max-min fair schedule of N tenants on one shared cluster.

    Args:
      tenants: the tenant set (or a plain list; names must be unique).
      cluster: the shared heterogeneous cluster.
      warm_start: open every tenant at its fair-slice solo schedule (the
        solo-no-regression guarantee); disable only for experiments.
      warm_refine_rounds: refine budget for each warm-start solo run
        (200 = the single-tenant default; lower it for large fleets).
      level_step: how far (in fairness-level units) the lowest tenant may
        raise past the pack when every active tenant is level; smaller
        values track leximin tighter at more rounds.
      rate_tol: minimum rate progress per raise; also the blocked test.
      refine_moves: ``max_rounds`` handed to the per-tenant residual
        ``refine`` pass when a tenant is blocked (0 disables it).
      structure_attempts: structural-escape budget per tenant (each
        blocked round spends one on refine + cross-tenant relocation);
        bounds worst-case run time on saturated clusters.
      cross_tenant_moves: enable the guarded cross-tenant relocation.
      max_rounds: hard backstop on water-filling rounds.
      backend: scoring backend for batched paths (``"auto"`` dispatches
        per regime exactly as the single-tenant engines).
      solo_rate_epsilon: ``rate_epsilon`` for every solo ``schedule()``
        call (warm starts and the N == 1 fast path).
      validate: re-check the shared-load invariant after every round
        (O(N·m) per round; property tests turn this on).

    Returns:
      ``MultiTenantSchedule`` with per-tenant allocations in submission
      order, the round count, and the number of candidate rows scored
      through the tenant-batched path.
    """
    tset = tenants if isinstance(tenants, TenantSet) else TenantSet(tenants)

    if len(tset) == 1:
        return _solo_schedule(tset, cluster, backend, solo_rate_epsilon)

    canonical = tset.canonical_order()
    scales = np.array([t.level_scale for t in tset], dtype=np.float64)

    if warm_start:
        states, rates = _warm_start(
            tset,
            cluster,
            canonical,
            warm_refine_rounds=warm_refine_rounds,
            backend=backend,
            solo_rate_epsilon=solo_rate_epsilon,
        )
        mt = MultiTenantState(tset, cluster, states, rates=rates)
        met_total = np.zeros(cluster.n_machines, dtype=np.float64)
        for i in canonical:
            met_total += states[i].met_load
        if np.any(met_total > cluster.capacity * (1.0 + _COMMIT_SLACK)):
            worst = float((met_total - cluster.capacity).max())
            raise ValueError(
                "cluster cannot host tenant set: fixed MET load alone "
                f"exceeds capacity (worst machine overshoot {worst:.3g} "
                "points) — add machines or reduce the fleet"
            )
    else:
        mt = MultiTenantState.first_assignment(tset, cluster)

    active = [True] * len(tset)
    attempts = [structure_attempts] * len(tset)
    log: list[str] = []
    candidates = 0
    rounds = 0
    cap = cluster.capacity
    # Incrementally maintained total machine load: a rate raise is an O(m)
    # delta; structural moves trigger a full refresh.
    total = mt.total_load()

    while any(active) and rounds < max_rounds:
        rounds += 1
        levels = mt.rates / scales
        # min() keeps the first minimum, and we iterate in canonical name
        # order — so level ties break canonically, independent of
        # submission order.
        t = min((i for i in canonical if active[i]), key=lambda i: levels[i])
        st_t = mt.states[t]
        head = cap - (total - mt.load_of(t)) - st_t.met_load
        var = st_t.var_load
        # Same masking as MultiTenantState.residual_rstar: machines the
        # tenant doesn't touch can't constrain it (ulp-negative residuals
        # on fully packed machines are the co-tenants' business).
        if np.any((head < 0.0) & ((st_t.met_load > 0.0) | (var > 0.0))):
            r_star = 0.0
        else:
            with np.errstate(divide="ignore"):
                lims = np.where(var > 0.0, head / np.maximum(var, 1e-300), np.inf)
            r_star = float(max(np.min(lims), 0.0))

        higher = [
            levels[s]
            for s in range(len(tset))
            if active[s] and levels[s] > levels[t] + 1e-12
        ]
        goal_level = min(higher) if higher else levels[t] + level_step
        new_rate = min(r_star, goal_level * scales[t])

        if new_rate > mt.rates[t] + rate_tol:
            total += (new_rate - float(mt.rates[t])) * var
            mt.rates[t] = new_rate
            continue

        # Blocked at residual R*: structural escapes while budget lasts.
        improved = False
        if attempts[t] > 0:
            attempts[t] -= 1
            if refine_moves > 0:
                improved = _refine_on_residual(
                    mt, tset, t, r_star, refine_moves, rate_tol, backend
                )
                if improved:
                    log.append(f"round {rounds}: refine improved tenant {tset[t].name}")
            if not improved and cross_tenant_moves:
                improved, scored = _cross_tenant_relocate(
                    mt, tset, t, r_star, rate_tol, backend
                )
                candidates += scored
                if improved:
                    log.append(f"round {rounds}: cross-tenant move for {tset[t].name}")
        if improved:
            total = mt.total_load()
        else:
            # Take any sub-tolerance head room left, then commit.
            commit = max(float(mt.rates[t]), float(new_rate))
            total += (commit - float(mt.rates[t])) * var
            mt.rates[t] = commit
            active[t] = False
            log.append(
                f"round {rounds}: tenant {tset[t].name} committed at "
                f"rate {mt.rates[t]:.6g} (level {levels[t]:.4g})"
            )
        if validate and not mt.feasible(slack=1e-9):
            over = float((mt.total_load() - cap).max())
            raise AssertionError(
                f"round {rounds} (tenant {tset[t].name}): shared-load "
                f"invariant violated by {over:.3e}"
            )

    # Final verification: the shared-load invariant (total linear load
    # within capacity) plus one tenant-batched sweep scoring every
    # tenant's incumbent row — the batched path must agree that each
    # committed rate fits its residual wherever the closed form is not on
    # its infeasibility cliff (a fully packed machine a few ulps over
    # collapses residual R* to 0; the direct invariant is the robust
    # check there).
    if not mt.feasible(slack=1e-9):
        over = mt.total_load() - cluster.capacity
        raise AssertionError(
            f"shared-load invariant violated: worst overshoot {over.max():.3e}"
        )
    scorer = TenantBatchScorer(mt, backend=backend)
    resid = scorer.residual_rates()
    candidates += scorer.candidates_evaluated
    for i in range(len(tset)):
        if resid[i] > 0.0 and mt.rates[i] > resid[i] * (1.0 + _COMMIT_SLACK) + rate_tol:
            raise AssertionError(
                f"tenant {tset[i].name}: committed rate {mt.rates[i]} exceeds "
                f"residual R* {resid[i]}"
            )

    allocations = tuple(
        TenantAllocation(
            name=tset[i].name,
            etg=mt.states[i].to_etg(),
            rate=float(mt.rates[i]),
            target_rate=tset[i].target_rate,
            priority=tset[i].priority,
        )
        for i in range(len(tset))
    )
    return MultiTenantSchedule(
        allocations=allocations,
        rounds=rounds,
        candidates_evaluated=candidates,
        log=tuple(log),
    )


def _warm_start(
    tset: TenantSet,
    cluster: Cluster,
    canonical: "list[int]",
    *,
    warm_refine_rounds: int,
    backend: str,
    solo_rate_epsilon: float,
) -> "tuple[list[ScheduleState], np.ndarray]":
    """Fair-slice warm start with MET-aware deferral, to a fixpoint.

    Each tenant schedules solo on its share of the *working* capacity. A
    tenant whose slice cannot host even its rate-0 load (MET is lumpy — a
    sub-MET slice fits no instance anywhere) is **deferred**: it gets a
    minimal placement on the ensemble residual at rate 0, and its fixed
    MET is subtracted from the working capacity the remaining tenants
    slice up. Accepted tenants whose warm load no longer fits the shrunk
    slice re-run; the loop repeats until no new tenant defers (the
    deferred set grows monotonically, so at most N iterations).

    On exit the ensemble is feasible by construction: accepted loads sum
    to at most the working capacity (slices partition it) and the working
    capacity already excludes every deferred MET. When deferral occurs the
    solo-no-regression guarantee is stated against the MET-reduced
    capacity — the deferred tenants' own fair-slice baselines are exactly
    0, so theirs holds trivially.
    """
    shares = fair_shares(tset)
    n = len(tset)
    m = cluster.n_machines
    work_cap = cluster.capacity.astype(np.float64).copy()
    states: list[ScheduleState | None] = [None] * n
    rates = np.zeros(n, dtype=np.float64)
    deferred: set[int] = set()

    # Cheap deferral pre-check: component c of tenant i can never be
    # placed inside a slice whose capacity is below met[c, w] on every
    # machine — skip the wasted solo run and defer straight away.
    met_tables = [
        cluster.met_for(tset[i].utg.component_types) for i in range(n)
    ]

    while True:
        load_sum = np.zeros(m, dtype=np.float64)
        new_deferred: list[int] = []
        for i in canonical:
            if i in deferred:
                continue
            tenant = tset[i]
            slice_cap = work_cap * shares[i]
            if bool(np.any(np.all(met_tables[i] > slice_cap + 1e-9, axis=1))):
                new_deferred.append(i)
                continue
            st = states[i]
            if st is not None:
                # Prior iteration's warm placement still fits the shrunk
                # slice — keep it (deterministic, and saves a solo run).
                warm_load = st.met_load + rates[i] * st.var_load
                if np.all(warm_load <= slice_cap + 1e-9):
                    load_sum += warm_load
                    continue
            sliced = cluster.with_capacity(slice_cap)
            sched = schedule(tenant.utg, sliced, r0=1.0, rate_epsilon=solo_rate_epsilon)
            ref = refine(
                sched.etg,
                sliced,
                max_rounds=warm_refine_rounds,
                backend=backend,
                skew=tenant.skew,
            )
            st = ScheduleState.from_etg(ref.etg, cluster, skew=tenant.skew)
            rate = ref.rate * (1.0 - _WARM_BACKOFF)
            warm_load = st.met_load + rate * st.var_load
            if np.all(warm_load <= slice_cap + 1e-9):
                states[i] = st
                rates[i] = rate
                load_sum += warm_load
            else:
                new_deferred.append(i)
        if not new_deferred:
            break
        for i in new_deferred:
            tenant = tset[i]
            residual = work_cap - load_sum
            etg = first_assignment(tenant.utg, cluster.with_capacity(residual), r0=1.0)
            st = ScheduleState.from_etg(etg, cluster, skew=tenant.skew)
            states[i] = st
            rates[i] = 0.0
            deferred.add(i)
            work_cap = work_cap - st.met_load

    return [st for st in states], rates  # type: ignore[return-value]


def _solo_schedule(
    tset: TenantSet, cluster: Cluster, backend: str, rate_epsilon: float
) -> MultiTenantSchedule:
    """N == 1: the stock single-tenant pipeline, bit-identical."""
    tenant = tset[0]
    sched = schedule(tenant.utg, cluster, r0=1.0, rate_epsilon=rate_epsilon)
    ref = refine(sched.etg, cluster, backend=backend, skew=tenant.skew)
    alloc = TenantAllocation(
        name=tenant.name,
        etg=ref.etg,
        rate=float(ref.rate),
        target_rate=tenant.target_rate,
        priority=tenant.priority,
    )
    return MultiTenantSchedule(
        allocations=(alloc,), rounds=0, candidates_evaluated=0, log=()
    )


def _refine_on_residual(
    mt: MultiTenantState,
    tset: TenantSet,
    t: int,
    r_star: float,
    refine_moves: int,
    rate_tol: float,
    backend: str,
) -> bool:
    """Single-tenant refine pass on tenant ``t``'s residual cluster.

    The residual capacity already subtracts every other tenant's committed
    load, so any placement refine admits is feasible for the ensemble by
    construction. Accepted only on strict rate improvement.
    """
    own_load = mt.load_of(t)
    residual = np.maximum(mt.residual_capacity(t), own_load)
    ref = refine(
        mt.states[t].to_etg(),
        mt.cluster.with_capacity(residual),
        max_rounds=refine_moves,
        backend=backend,
        skew=tset[t].skew,
    )
    if ref.rate > r_star + rate_tol:
        mt.replace_state(
            t, ScheduleState.from_etg(ref.etg, mt.cluster, skew=tset[t].skew)
        )
        return True
    return False


def _cross_tenant_relocate(
    mt: MultiTenantState,
    tset: TenantSet,
    t: int,
    r_star: float,
    rate_tol: float,
    backend: str,
    max_tries: int = 8,
) -> tuple[bool, int]:
    """Move another tenant's instance off tenant ``t``'s binding machine.

    Enumerates one candidate per (tenant s != t, component with instances
    on the binding machine, destination machine); every candidate's
    donor-feasibility guard is batch-scored in ONE ``TenantBatchScorer``
    call (rows of different tenants in one kernel launch). Candidates that
    keep the donor at its committed rate are ranked by tenant ``t``'s
    closed-form improvement; the best is applied only if a full post-check
    shows every tenant's committed rate still fits its residual — one
    tenant's escape can never push another below its share.

    Returns (applied, candidate_rows_scored).
    """
    st_t = mt.states[t]
    head = mt.residual_capacity(t) - st_t.met_load
    var = st_t.var_load
    with np.errstate(divide="ignore", invalid="ignore"):
        limits = np.where(var > 0.0, head / np.maximum(var, 1e-300), np.inf)
    w_star = int(np.argmin(limits))

    # Enumerate donor candidates in canonical order (determinism).
    scorer = TenantBatchScorer(mt, backend=backend)
    sweeps: list[tuple[int, np.ndarray]] = []
    meta: list[tuple[int, int, int, int]] = []  # (s, comp, k, dest)
    m = mt.cluster.n_machines
    for s in tset.canonical_order():
        if s == t:
            continue
        st_s = mt.states[s]
        base_s = st_s.task_machine()
        offs = st_s.component_offsets()
        rows_s = []
        for c in range(st_s.utg.n_components):
            if st_s.comp_counts[c, w_star] <= 0:
                continue
            k = st_s.assignment[c].index(w_star)
            col = int(offs[c]) + k
            for dest in range(m):
                if dest == w_star:
                    continue
                row = base_s.copy()
                row[col] = dest
                rows_s.append(row)
                meta.append((s, c, k, dest))
        if rows_s:
            sweeps.append((s, np.stack(rows_s)))
    if not meta:
        return False, 0

    scored = scorer.score(sweeps)
    donor_rates = np.concatenate([r for r, _ in scored])
    n_scored = int(donor_rates.shape[0])

    # Rank guard-passing candidates by t's closed-form gain.
    gains: list[tuple[float, int]] = []
    for idx, (s, c, k, dest) in enumerate(meta):
        if donor_rates[idx] < mt.rates[s] * (1.0 - _COMMIT_SLACK) - rate_tol:
            continue
        st_s = mt.states[s]
        unit = _instance_unit_ir(st_s, c, k)
        load_src = st_s.met_cm[c, w_star] + st_s.e_cm[c, w_star] * unit * mt.rates[s]
        load_dst = st_s.met_cm[c, dest] + st_s.e_cm[c, dest] * unit * mt.rates[s]
        delta = np.zeros(m)
        delta[w_star] = load_src
        delta[dest] = -load_dst
        with np.errstate(divide="ignore", invalid="ignore"):
            lims = np.where(var > 0.0, (head + delta) / np.maximum(var, 1e-300), np.inf)
        gains.append((float(np.min(lims)), idx))
    gains.sort(key=lambda g: (-g[0], g[1]))

    for gain, idx in gains[:max_tries]:
        if gain <= r_star + rate_tol:
            break
        s, c, k, dest = meta[idx]
        st_s = mt.states[s]
        st_s.relocate_instance(c, k, dest)
        if all(
            mt.residual_rstar(v) >= mt.rates[v] * (1.0 - _COMMIT_SLACK) - rate_tol
            for v in range(len(tset))
        ):
            return True, n_scored
        st_s.relocate_instance(c, k, w_star)  # revert
    return False, n_scored


def _instance_unit_ir(st: ScheduleState, c: int, k: int) -> float:
    """Unit-rate input rate of instance (c, k) — skew-aware."""
    if st.skew is not None:
        frac = st.skew.instance_fractions(c, int(st.n_instances[c]))
        if frac is not None:
            return float(st.cir_unit[c] * frac[k])
    return float(st.cir_unit[c] / int(st.n_instances[c]))
