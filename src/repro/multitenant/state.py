"""Shared-load view over per-tenant schedule states.

Every tenant keeps its own ``ScheduleState`` (built on the *full* shared
cluster, so profile slices and machine indices are cluster-global), and the
multi-tenant state owns one rate vector. Because eq. 5/6 are linear in the
topology input rate, tenant t's exact machine load is

    load_t(w) = met_load_t(w) + R_t * var_load_t(w)

with the same cached coefficients the single-tenant closed form uses
(skew-aware when the tenant has a key-share model). Cross-tenant
interference is therefore priced exactly: the capacity left for tenant t is
``cap - sum_{s != t} load_s``, and t's residual maximum stable rate is the
usual closed form against that residual head room.
"""

from __future__ import annotations

import numpy as np

from repro.core.first_assignment import first_assignment
from repro.core.schedule_state import ScheduleState
from repro.core.profiles import Cluster

from repro.multitenant.tenants import Tenant, TenantSet

__all__ = ["MultiTenantState"]


class MultiTenantState:
    """N per-tenant ``ScheduleState``s sharing one machine-load vector."""

    __slots__ = ("tenant_set", "cluster", "states", "rates")

    def __init__(
        self,
        tenant_set: TenantSet,
        cluster: Cluster,
        states: list[ScheduleState],
        rates: np.ndarray | None = None,
    ):
        if len(states) != len(tenant_set):
            raise ValueError("one ScheduleState per tenant required")
        for st in states:
            if st.cluster.n_machines != cluster.n_machines:
                raise ValueError("tenant state built for a different cluster width")
        self.tenant_set = tenant_set
        self.cluster = cluster
        self.states = states
        self.rates = (
            np.zeros(len(states), dtype=np.float64)
            if rates is None
            else np.asarray(rates, dtype=np.float64).copy()
        )

    @classmethod
    def first_assignment(
        cls, tenant_set: TenantSet, cluster: Cluster, r0: float = 1.0
    ) -> "MultiTenantState":
        """Minimal one-instance-per-component placement for every tenant.

        Tenants are placed in canonical (name) order; each placement sees
        the residual capacity left by the fixed (MET) load of the tenants
        placed before it, so early placements steer later ones away from
        machines that are already claimed — the multi-tenant analogue of
        Algorithm 1's load accounting.
        """
        states: list[ScheduleState | None] = [None] * len(tenant_set)
        residual = cluster.capacity.astype(np.float64).copy()
        mem_resid = (
            cluster.mem_capacity.astype(np.float64).copy()
            if cluster.has_memory
            else None
        )
        for i in cls._canonical(tenant_set):
            tenant = tenant_set[i]
            view = cluster.with_capacity(residual, mem_capacity=mem_resid)
            etg = first_assignment(tenant.utg, view, r0)
            st = ScheduleState.from_etg(etg, cluster, skew=tenant.skew)
            states[i] = st
            residual = residual - st.met_load
            if mem_resid is not None:
                mem_resid = mem_resid - st.mem_load
        return cls(tenant_set, cluster, [st for st in states if st is not None])

    @staticmethod
    def _canonical(tenant_set: TenantSet) -> list[int]:
        return tenant_set.canonical_order()

    # ------------------------------------------------------- load algebra

    def load_of(self, t: int) -> np.ndarray:
        """(m,) exact machine load of tenant ``t`` at its current rate.

        On network-modelled clusters the tenant's cut-traffic load (also
        linear in its rate) is part of the variable coefficient, so
        cross-tenant interference prices network CPU exactly too.
        """
        st = self.states[t]
        var = st.var_load
        if self.cluster.has_network:
            var = var + st.net_load
        return st.met_load + float(self.rates[t]) * var

    def total_load(self) -> np.ndarray:
        """(m,) summed machine load of all tenants.

        Accumulated in canonical (name) order, not submission order —
        float addition is not associative, and every permutation-invariance
        guarantee downstream rests on cross-tenant reductions summing in
        one canonical sequence.
        """
        total = np.zeros(self.cluster.n_machines, dtype=np.float64)
        for t in self._canonical(self.tenant_set):
            total += self.load_of(t)
        return total

    def residual_capacity(self, t: int) -> np.ndarray:
        """(m,) capacity left for tenant ``t`` by everyone else's load."""
        return self.cluster.capacity - (self.total_load() - self.load_of(t))

    def total_mem_load(self) -> np.ndarray:
        """(m,) summed memory load of all tenants (canonical order; memory
        demands are rate-independent, so no rate fold is needed)."""
        total = np.zeros(self.cluster.n_machines, dtype=np.float64)
        for t in self._canonical(self.tenant_set):
            total += self.states[t].mem_load
        return total

    def residual_mem_capacity(self, t: int) -> np.ndarray:
        """(m,) memory capacity left for tenant ``t`` by everyone else."""
        return self.cluster.mem_capacity - (
            self.total_mem_load() - self.states[t].mem_load
        )

    def residual_cluster(self, t: int) -> Cluster:
        """Cluster view whose capacity is tenant ``t``'s residual head room.

        Feeding this to single-tenant ``refine``/``schedule`` makes their
        moves respect every other tenant's committed allocation by
        construction — a candidate that would evict a neighbour below its
        share simply scores as infeasible. On memory-modelled clusters the
        residual memory capacity is carried the same way (neighbours'
        rate-independent working sets are subtracted); the distance matrix
        and penalty pass through unchanged.
        """
        mem = self.residual_mem_capacity(t) if self.cluster.has_memory else None
        return self.cluster.with_capacity(
            self.residual_capacity(t), mem_capacity=mem
        )

    def residual_rstar(self, t: int) -> float:
        """Closed-form max stable rate of tenant ``t`` on its residual.

        Only machines where the tenant actually has load constrain it: a
        machine the tenant doesn't touch whose residual dips a few ulps
        below zero (co-tenants summing to exactly capacity) must not
        collapse the rate to 0.
        """
        st = self.states[t]
        if self.cluster.has_memory and np.any(
            st.mem_load > self.residual_mem_capacity(t)
        ):
            return 0.0
        head = self.residual_capacity(t) - st.met_load
        var = st.var_load
        if self.cluster.has_network:
            var = var + st.net_load
        if np.any((head < 0.0) & ((st.met_load > 0.0) | (var > 0.0))):
            return 0.0
        with np.errstate(divide="ignore"):
            limits = np.where(var > 0.0, head / np.maximum(var, 1e-300), np.inf)
        return float(max(np.min(limits), 0.0))

    def feasible(self, slack: float = 1e-9) -> bool:
        """Shared-load invariant: total load within capacity (+``slack``).

        On memory-modelled clusters the fleet's summed working sets must
        also fit each machine's memory (same relative slack — this is an
        invariant check over float sums, not an admission rule).
        """
        cap = self.cluster.capacity
        if not np.all(self.total_load() <= cap + slack * np.maximum(cap, 1.0)):
            return False
        if self.cluster.has_memory:
            mcap = self.cluster.mem_capacity
            if not np.all(
                self.total_mem_load() <= mcap + slack * np.maximum(mcap, 1.0)
            ):
                return False
        return True

    def replace_state(self, t: int, state: ScheduleState) -> None:
        """Swap tenant ``t``'s placement (e.g. after a refine round)."""
        if state.cluster.n_machines != self.cluster.n_machines:
            raise ValueError("replacement state built for a different cluster width")
        self.states[t] = state

    def levels(self) -> np.ndarray:
        """(N,) fairness level of each tenant: ``R_t / (target_t * prio_t)``."""
        scales = np.array([t.level_scale for t in self.tenant_set], dtype=np.float64)
        return self.rates / scales
