"""Batched policy evaluation: B traces × P policies in one ``lax.scan``.

Vectorizes the ``StreamExecutor`` window step the same way
``sim_jax.simulate_batch_jax`` vectorizes the §6.3 simulator: the loop
state is ``(backlog (B,P,T), prev_out (B,P,n), throttle (B,P))``, the scan
consumes the stacked per-window trace arrays, and the topology recurrence
unrolls over the (few) components with the structure baked in statically.
Per-machine scatter/gather run as one-hot einsum contractions against a
precomputed (P, T, m) placement tensor. Fields-grouped edges route through
per-key share grids — dense (W, B, N) expansions of each realization
segment's hash→instance map — threaded through the scan as per-window
inputs, so keyed runs stay bit-compatible with the Python loop.

Everything runs in float64 (``jax.experimental.enable_x64``): the window
step is the exact formula sequence of ``StreamExecutor.run`` (no
controller, no migrations — this is the *static-policy* sweep evaluator),
so the backends agree to ~1e-9 over hundreds of windows; the NumPy backend
loops the reference executor over every (trace, policy) pair and is the
fallback whenever JAX is unavailable.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import ExecutionGraph
from repro.core.profiles import Cluster
from repro.core.simulator import _jax_available

from repro.runtime_stream.executor import RuntimeConfig, StreamExecutor
from repro.runtime_stream.traces import CompiledTrace

__all__ = ["PolicyEvalResult", "evaluate_policies_batch"]


@dataclasses.dataclass(frozen=True)
class PolicyEvalResult:
    """Windowed metrics for every (trace b, policy p) pair.

    Shapes: (B, P, W) unless noted. ``sustained`` is the mean throughput
    of the trailing half of the horizon, matching
    ``RuntimeResult.sustained_throughput()``.
    """

    throughput: np.ndarray
    admitted: np.ndarray
    dropped: np.ndarray
    queue_total: np.ndarray
    throttle: np.ndarray
    machine_util_mean: np.ndarray  # (B, P, m) mean over windows
    sustained: np.ndarray          # (B, P)
    window_s: float = 1.0          # trace dt, for the derived latency view

    def latency(self) -> np.ndarray:
        """(B, P, W) Little's-law end-to-end latency estimate per window —
        the same derived view as ``RuntimeResult.latency`` (queued tuples
        over drain rate, capped at the horizon), so batch sweeps and the
        Python executor report one latency definition."""
        horizon = self.throughput.shape[-1] * self.window_s
        with np.errstate(divide="ignore", invalid="ignore"):
            lat = np.where(
                self.queue_total > 0.0,
                self.queue_total / np.maximum(self.throughput, 1e-300),
                0.0,
            )
        return np.minimum(lat, horizon)

    def latency_slo_frac(self, slo_s: float, tail_frac: float = 0.5) -> np.ndarray:
        """(B, P) fraction of trailing-``tail_frac`` windows within the
        latency SLO — mirrors ``RuntimeResult.latency_slo_frac``."""
        W = self.throughput.shape[-1]
        start = int(W * (1.0 - tail_frac))
        return (self.latency()[..., start:] <= slo_s).mean(axis=-1)


def _validate(
    etg: ExecutionGraph,
    cluster: Cluster,
    traces: list[CompiledTrace],
    policies: np.ndarray,
) -> np.ndarray:
    policies = np.asarray(policies, dtype=np.int64)
    T = etg.total_tasks
    if policies.ndim != 2 or policies.shape[1] != T:
        raise ValueError("policies must be (P, T) task->machine rows")
    if policies.size and (
        policies.min() < 0 or policies.max() >= cluster.n_machines
    ):
        # Negative indices would wrap silently through the profile gathers
        # and the one-hot scatter, yielding plausible-looking wrong metrics.
        raise ValueError("policy machine indices must lie in [0, n_machines)")
    if not traces:
        raise ValueError("need at least one trace")
    W = traces[0].n_windows
    want_edges = {g.edge for g in etg.utg.groupings}
    for tr in traces:
        if tr.n_windows != W or tr.window_s != traces[0].window_s:
            raise ValueError("traces must share n_windows and window_s")
        if tr.capacity.shape[1] != cluster.n_machines:
            raise ValueError("trace capacity grid does not match the cluster")
        if {kt.edge for kt in tr.keyed} != want_edges:
            raise ValueError(
                "trace keyed edges do not match the topology's fields "
                "groupings — compile every trace with utg=etg.utg"
            )
    return policies


def _edge_share_grid(tr, edge: tuple[int, int], n_inst: int) -> np.ndarray:
    """(W, n_inst) per-window instance shares of one fields edge (dense
    realization-segment expansion of the hash→instance map)."""
    kt = next(k for k in tr.keyed if k.edge == edge)
    per_seg = np.stack([r.shares(n_inst) for _, r in kt.segments])
    return per_seg[kt.segment_indices(tr.n_windows)]


def evaluate_policies_batch(
    etg: ExecutionGraph,
    cluster: Cluster,
    traces: list[CompiledTrace],
    policies: np.ndarray,
    config: RuntimeConfig | None = None,
    backend: str = "auto",
    external_load: np.ndarray | None = None,
) -> PolicyEvalResult:
    """Run every trace against every static placement in one sweep.

    Args:
      etg: supplies the topology and instance counts (its own assignment
        is ignored — placements come in as ``policies`` rows, like
        ``simulate_batch``).
      cluster: the cluster; each trace's capacity grid modulates it.
      traces: B compiled traces sharing one horizon (W windows, same dt).
      policies: (P, T) machine index per task per candidate placement.
      config: event-loop constants (must match the Python executor's for
        parity comparisons).
      backend: ``"numpy"`` (reference: the Python executor per pair),
        ``"jax"`` (one jitted ``lax.scan``, ~1e-9 agreement), or
        ``"auto"`` (JAX when importable, NumPy otherwise).
      external_load: optional (W, m) or (m,) load held by co-tenants of
        the shared machines, subtracted (clipped at zero) from every
        trace's capacity grid before evaluation — the tenant dimension of
        the batch evaluator, matching ``StreamExecutor(background_load=)``.
    """
    if backend not in ("auto", "numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    config = config or RuntimeConfig()
    if external_load is not None and traces:
        import dataclasses as _dc

        bg = np.asarray(external_load, dtype=np.float64)
        shape = traces[0].capacity.shape
        if bg.ndim == 1:
            bg = np.broadcast_to(bg, shape)
        if bg.shape != shape:
            raise ValueError(
                f"external_load must be (m,) or match the (W, m) capacity grid {shape}"
            )
        traces = [
            _dc.replace(tr, capacity=np.clip(tr.capacity - bg, 0.0, None))
            for tr in traces
        ]
    policies = _validate(etg, cluster, traces, policies)
    if backend == "auto":
        backend = "jax" if _jax_available() else "numpy"
    if backend == "jax" and not _jax_available():
        backend = "numpy"
    if backend == "numpy":
        return _evaluate_numpy(etg, cluster, traces, policies, config)
    return _evaluate_jax(etg, cluster, traces, policies, config)


def _policy_etg(etg: ExecutionGraph, row: np.ndarray) -> ExecutionGraph:
    comp = etg.task_component()
    return ExecutionGraph(
        utg=etg.utg,
        n_instances=etg.n_instances.copy(),
        assignment=[row[comp == c] for c in range(etg.utg.n_components)],
    )


def _evaluate_numpy(etg, cluster, traces, policies, config) -> PolicyEvalResult:
    """Reference backend: the executor, once per (trace, policy) pair."""
    B, P, W = len(traces), policies.shape[0], traces[0].n_windows
    m = cluster.n_machines
    out = {
        k: np.zeros((B, P, W))
        for k in ("throughput", "admitted", "dropped", "queue_total", "throttle")
    }
    util = np.zeros((B, P, m))
    sustained = np.zeros((B, P))
    for b, tr in enumerate(traces):
        for p in range(P):
            res = StreamExecutor(
                _policy_etg(etg, policies[p]), cluster, tr, config=config
            ).run()
            out["throughput"][b, p] = res.throughput
            out["admitted"][b, p] = res.admitted
            out["dropped"][b, p] = res.dropped
            out["queue_total"][b, p] = res.queue_total
            out["throttle"][b, p] = res.throttle
            util[b, p] = res.machine_util.mean(axis=0)
            sustained[b, p] = res.sustained_throughput()
    return PolicyEvalResult(
        machine_util_mean=util,
        sustained=sustained,
        window_s=traces[0].window_s,
        **out,
    )


def _evaluate_jax(etg, cluster, traces, policies, config) -> PolicyEvalResult:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    utg = etg.utg
    n = utg.n_components
    comp = etg.task_component()
    T = comp.shape[0]
    m = cluster.n_machines
    B, P = len(traces), policies.shape[0]
    W = traces[0].n_windows
    dt = traces[0].window_s
    topo = tuple(utg.topo_order())
    sources = frozenset(utg.sources)
    alpha = tuple(float(a) for a in utg.alpha)
    # Fields edges route per key share; only shuffle in-edges stay in the
    # even-split component recurrence. Static per-edge structure: parent,
    # the destination's task block [lo, hi), and a (W, B, N) share grid
    # threaded through the scan as per-window inputs.
    keyed_edges = tuple(g.edge for g in utg.groupings)
    parents = tuple(
        tuple(p for p in utg.parents(i) if (p, i) not in keyed_edges)
        for i in range(n)
    )
    offsets = etg.component_offsets()
    keyed_static = tuple(
        (p, int(offsets[i]), int(offsets[i + 1])) for p, i in keyed_edges
    )
    key_shares = tuple(
        np.stack(
            [_edge_share_grid(tr, (p, i), int(etg.n_instances[i])) for tr in traces],
            axis=1,
        )  # (W, B, N)
        for p, i in keyed_edges
    )

    ttypes = utg.component_types[comp]
    mtypes = cluster.machine_types[policies]             # (P, T)
    e = cluster.profile.e[ttypes[None, :], mtypes]       # (P, T)
    met = cluster.profile.met[ttypes[None, :], mtypes]
    onehot = np.zeros((P, T, m), dtype=np.float64)
    onehot[np.arange(P)[:, None], np.arange(T)[None, :], policies] = 1.0
    n_task = etg.n_instances.astype(np.float64)[comp]          # (T,)

    rates = np.stack([tr.rates for tr in traces], axis=1)          # (W, B)
    caps = np.stack([tr.capacity for tr in traces], axis=1)        # (W, B, m)

    cfg = config

    def step(carry, xs):
        backlog, prev_out, throttle = carry       # (B,P,T) (B,P,n) (B,P)
        r_t, cap, shares_t = xs                   # (B,) (B,m) tuple of (B,N)
        r_adm = r_t[:, None] * throttle           # (B,P)
        # 1. Arrivals (one hop per window): even split for spout injection
        # and shuffle edges, then each fields edge adds its keyed
        # contribution at the window's hash shares — same composition
        # order as the Python executor's arr_inst.
        arr = [None] * n
        for i in topo:
            if i in sources:
                arr[i] = r_adm
            else:
                a = jnp.zeros_like(r_adm)
                for p_ in parents[i]:
                    a = a + alpha[p_] * prev_out[:, :, p_]
                arr[i] = a
        arr_n = jnp.stack(arr, axis=2)            # (B,P,n)
        arr_task = arr_n[:, :, comp] / n_task[None, None, :]
        for (p_, lo, hi), s_e in zip(keyed_static, shares_t):
            contrib = alpha[p_] * prev_out[:, :, p_]          # (B,P)
            arr_task = arr_task.at[:, :, lo:hi].add(
                contrib[:, :, None] * s_e[:, None, :]
            )
        backlog = backlog + arr_task * dt
        over = jnp.clip(backlog - cfg.max_queue, 0.0, None)
        backlog = backlog - over
        dropped = over.sum(axis=2) / dt
        # 2. Service under proportional fair machine throttling.
        desired = backlog / dt
        var_w = jnp.einsum("bpt,ptm->bpm", e[None] * desired, onehot)
        met_w = jnp.broadcast_to(
            jnp.einsum("pt,ptm->pm", met, onehot)[None], (B, P, m)
        )
        head = jnp.maximum(cap[:, None, :] - met_w, 0.0)
        s = jnp.where(var_w > head, head / jnp.maximum(var_w, 1e-300), 1.0)
        s_task = jnp.einsum("bpm,ptm->bpt", s, onehot)
        processed = desired * s_task
        backlog = jnp.maximum(backlog - processed * dt, 0.0)
        alive_task = jnp.einsum("bm,ptm->bpt", (cap > 0.0).astype(e.dtype), onehot)
        tcu = e[None] * processed + met[None] * alive_task
        prev_out = jnp.stack(
            [processed[:, :, comp == c].sum(axis=2) for c in range(n)], axis=2
        )
        # 3. Metrics + spout back-pressure for the next window.
        util = jnp.einsum("bpt,ptm->bpm", tcu, onehot)
        q_frac = backlog.max(axis=2) / cfg.max_queue
        throttle_next = jnp.where(
            q_frac > cfg.bp_high,
            jnp.maximum(cfg.throttle_min, throttle * cfg.throttle_down),
            jnp.where(
                q_frac < cfg.bp_low,
                jnp.minimum(1.0, throttle * cfg.throttle_up),
                throttle,
            ),
        )
        metrics = (
            processed.sum(axis=2),
            r_adm,
            dropped,
            backlog.sum(axis=2),
            throttle,
            util,
        )
        return (backlog, prev_out, throttle_next), metrics

    @jax.jit
    def sweep(rates, caps, key_shares):
        carry0 = (
            jnp.zeros((B, P, T)),
            jnp.zeros((B, P, n)),
            jnp.ones((B, P)),
        )
        _, ms = jax.lax.scan(step, carry0, (rates, caps, key_shares))
        return ms

    with enable_x64():
        thpt, adm, drp, qtot, thr, util = sweep(rates, caps, key_shares)

    def wbp(x):  # (W, B, P) -> (B, P, W)
        return np.asarray(x).transpose(1, 2, 0)

    thpt = wbp(thpt)
    start = W // 2  # == RuntimeResult.sustained_throughput's tail split
    return PolicyEvalResult(
        throughput=thpt,
        admitted=wbp(adm),
        dropped=wbp(drp),
        queue_total=wbp(qtot),
        throttle=wbp(thr),
        machine_util_mean=np.asarray(util).mean(axis=0),
        sustained=thpt[:, :, start:].mean(axis=2),
        window_s=dt,
    )
