"""Online rescheduler: drift detection + incremental replanning.

``OnlineController`` watches the executor's windowed metrics and, when the
workload drifts away from the current schedule's sweet spot, re-plans
*incrementally*: instead of re-running the full scheduler from scratch it
hands the live placement to ``refine``'s delta-scored hill climb
(RELOCATE / SWAP / GROW / PAIRGROW / DROP on ``ScheduleState``), bounded to
a few moves per control period, against the cluster's *instantaneous*
capacity (``Cluster.with_capacity``). A replan is applied only when its
projected benefit clears a migration cost/benefit guard.

Drift triggers (any of):

* **capacity change** — the trace slowed or removed a machine since the
  last plan;
* **saturation** — the spout throttle is pinned below 1 or queues sit
  above the watermark (offered load exceeds what the placement sustains);
* **hot machine** — some alive machine's utilization crossed
  ``util_high`` of its capacity (the paper's over-utilization signal).

Cost/benefit guard: the projected gain is the closed-form throughput
improvement *capped by offered demand* (growing past what the trace offers
buys nothing), integrated over ``horizon_windows``; the cost is the number
of migrated/new instances times ``migration_cost`` tuples (state transfer
plus the executor's migration pause). Plans that don't clear the guard are
logged and skipped.

``provision_schedule`` builds the "honest operator" baseline the
benchmarks freeze: Algorithm 1 + just enough Algorithm-2 growth to sustain
a target rate — the paper's protocol of sizing a schedule to the currently
observed load, which is exactly what rate drift then invalidates.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cost_model
from repro.core.first_assignment import first_assignment
from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster
from repro.core.refine import refine
from repro.core.schedule_state import (
    ScheduleState,
    _grow_component_fast,
    _hottest_component,
)

__all__ = [
    "WindowObs",
    "OnlineController",
    "OracleRescheduler",
    "provision_schedule",
]


@dataclasses.dataclass(frozen=True)
class WindowObs:
    """What the executor shows a controller at a control point."""

    window: int
    window_s: float
    etg: ExecutionGraph
    capacity: np.ndarray        # (m,) instantaneous per-machine capacity
    offered_rate: float         # trace rate this window
    throttle: float             # spout back-pressure throttle in effect
    machine_util: np.ndarray    # (m,) this window's utilization
    queue_frac: float           # deepest queue / max_queue
    queue_by_component: np.ndarray  # (n,) backlog per component
    throughput: float
    # Fields-grouping view (None / 0 on all-shuffle topologies): the active
    # key realizations as a cost_model.SkewModel, and a counter that bumps
    # at every key_skew_shift boundary.
    skew: "cost_model.SkewModel | None" = None
    skew_epoch: int = 0


def provision_schedule(
    utg: UserGraph, cluster: Cluster, rate: float, margin: float = 1.05
) -> ExecutionGraph:
    """Smallest-effort schedule sustaining ``rate`` (× ``margin``).

    Algorithm 1's minimal ETG, grown with Algorithm 2's hottest-component
    rule (the incremental engine's closed-form growth step) only until the
    closed-form R* covers the target — the paper's protocol of provisioning
    for the *currently observed* rate rather than the cluster's maximum.
    Returns the best-effort schedule even if the target is unreachable.
    """
    target = float(rate) * margin
    etg = first_assignment(utg, cluster, min(target, 1.0))
    state = ScheduleState.from_etg(etg, cluster)
    # Progressive scale-up toward the target (Algorithm 2's regime: grow at
    # moderate rates, not straight at the target — a single component's
    # chunks at a far-away rate may fit on no machine even though stepped
    # growth reaches it comfortably).
    step_rate = state.max_stable_rate()
    for _ in range(10_000):
        if step_rate >= target:
            break
        step_rate = min(max(step_rate * 1.25, target / 64.0), target)
        while state.max_stable_rate() < step_rate:
            util = state.utilization(step_rate)
            over = np.flatnonzero(cluster.capacity - util < 0.0)
            if over.size == 0:
                break
            component = _hottest_component(state, int(over[0]), step_rate)
            if _grow_component_fast(state, component, step_rate) == 0:
                return state.to_etg()  # saturated below the target: best effort
    return state.to_etg()


class OnlineController:
    """Windowed drift detector + guarded incremental rescheduler.

    Args:
      utg: the running topology.
      cluster: the nominal cluster (capacities are overridden per
        observation).
      period: control period in windows.
      max_moves: refine rounds per replan (each round applies one move, so
        this bounds migrations per control period).
      util_high: hot-machine trigger as a fraction of capacity.
      queue_high: queue-fraction trigger.
      migration_cost: tuples charged per migrated/new instance in the
        guard (state transfer + restart downtime).
      horizon_windows: windows the projected gain is assumed to persist
        (the guard's amortization horizon).
      adaptive_growth: forward refine's depth-adaptive growth menu (lets a
        single replan grow a component past 4 instances when the closed
        form keeps improving — useful under fast rate ramps).
      measure_noise: when > 0, the controller observes machine utilization
        through the §6.2 measurement model instead of exactly: zero-mean
        Gaussian error with std ``measure_noise * cap_w * 4u(1-u)``
        (peaked at 50% load, truncated below the paper's observed 8% of
        capacity) is added to the drift detector's view. Only *detection*
        sees the noise — replans still score on the exact closed form,
        and the demand-capped cost/benefit guard is what keeps spurious
        triggers from churning the placement (tested no-churn at steady
        state).
      noise_seed: seed stream for the measurement noise (drawn per window,
        so runs stay deterministic).
    """

    def __init__(
        self,
        utg: UserGraph,
        cluster: Cluster,
        period: int = 10,
        max_moves: int = 4,
        util_high: float = 0.92,
        queue_high: float = 0.25,
        migration_cost: float = 25.0,
        horizon_windows: int = 60,
        adaptive_growth: bool = False,
        measure_noise: float = 0.0,
        noise_seed: int = 0,
    ):
        self.utg = utg
        self.cluster = cluster
        self.period = int(period)
        self.max_moves = int(max_moves)
        self.util_high = float(util_high)
        self.queue_high = float(queue_high)
        self.migration_cost = float(migration_cost)
        self.horizon_windows = int(horizon_windows)
        self.adaptive_growth = bool(adaptive_growth)
        self.measure_noise = float(measure_noise)
        self.noise_seed = int(noise_seed)
        self._cir_sum = float(cost_model.component_rates(utg, 1.0).sum())
        self._last_capacity: np.ndarray | None = None
        self._last_skew_epoch: int | None = None
        self.log: list[tuple[int, str]] = []

    # ------------------------------------------------------------ drift

    def _observed_util(self, obs: WindowObs) -> np.ndarray:
        """The drift detector's view of machine utilization — exact, or
        perturbed by the §6.2 measurement model when ``measure_noise`` > 0
        (seeded per window: same run, same observations)."""
        if self.measure_noise <= 0.0:
            return obs.machine_util
        cap = np.where(obs.capacity > 0.0, obs.capacity, 1.0)
        u = np.clip(obs.machine_util / cap, 0.0, 1.0)
        # §6.2 shape scaled per machine: error is a fraction of *that
        # machine's* instantaneous capacity (the paper's 100-point budget
        # and <8-point truncation as capacity fractions), so slowed-down
        # machines aren't over-noised.
        std = self.measure_noise * cap * 4.0 * u * (1.0 - u)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.noise_seed, obs.window])
        )
        bound = 0.079 * cap
        noise = np.clip(rng.normal(0.0, 1.0, size=std.shape) * std, -bound, bound)
        return np.clip(obs.machine_util + noise, 0.0, None)

    def _drifted(self, obs: WindowObs) -> str | None:
        if self._last_capacity is not None and not np.array_equal(
            obs.capacity, self._last_capacity
        ):
            return "capacity"
        if self._last_skew_epoch is not None and (
            obs.skew_epoch != self._last_skew_epoch
        ):
            # A key_skew_shift moved the hot keys: the placement was tuned
            # for the old realization even if nothing saturates yet.
            return "skew_shift"
        if obs.throttle < 1.0 or obs.queue_frac > self.queue_high:
            return "saturated"
        machine_util = self._observed_util(obs)
        alive = obs.capacity > 0.0
        if np.any(machine_util[alive] >= self.util_high * obs.capacity[alive]):
            return "hot"
        if obs.skew is not None and obs.queue_frac > 0.5 * self.queue_high:
            # Keyed blind spot: a single hot instance's queue is building
            # while every machine-average utilization still looks healthy
            # — the even-split signals above would wait for saturation.
            return "hot_instance"
        return None

    # ------------------------------------------------------- evacuation

    @staticmethod
    def _evacuate(etg: ExecutionGraph, cluster_t: Cluster, rate: float) -> ExecutionGraph:
        """Relocate every instance hosted on a capacity-0 machine.

        A hill climb scoring closed-form throughput cannot escape the
        0-throughput plateau when *several* instances sit on a dead
        machine (no single move restores feasibility), so dead machines
        are drained first: each stranded instance moves to the feasible
        alive machine with the least chunk TCU (ties toward most
        remaining head — ``_greedy_place``'s rule), and ``refine``
        polishes from there.
        """
        from repro.core.maximize_throughput import _least_tcu_machine

        state = ScheduleState.from_etg(etg, cluster_t)
        dead = cluster_t.capacity <= 0.0
        if not dead.any():
            return etg
        cir = cost_model.component_rates(etg.utg, rate)
        per_inst = cir / state.n_instances
        util = state.utilization(rate)
        for c in range(etg.utg.n_components):
            tcu_w = state.e_cm[c] * per_inst[c] + state.met_cm[c]
            for k, w in enumerate(state.assignment[c]):
                if not dead[w]:
                    continue
                # Dead machines get -inf head so the shared rule never
                # picks them; when nothing fits, least-overloaded alive.
                head = np.where(dead, -np.inf, cluster_t.capacity - util - tcu_w)
                target = _least_tcu_machine(tcu_w, head)
                if target is None:
                    target = int(np.argmax(head))
                state.relocate_instance(c, k, target)
                util[w] -= tcu_w[w]
                util[target] += tcu_w[target]
        return state.to_etg()

    # ----------------------------------------------------------- update

    def update(self, obs: WindowObs) -> ExecutionGraph | None:
        """Executor hook: returns a new placement or None to keep going."""
        from repro.runtime_stream.executor import placement_migrations

        reason = self._drifted(obs)
        self._last_capacity = obs.capacity.copy()
        self._last_skew_epoch = obs.skew_epoch
        if reason is None:
            return None
        cluster_t = self.cluster.with_capacity(obs.capacity)
        # Skew-aware scoring throughout: on keyed topologies both the
        # incumbent's worth and every replan candidate price per-instance
        # key shares, so a hot instance the even split cannot see is
        # exactly what the replan optimizes away.
        _, cur_thpt = cost_model.max_stable_rate(obs.etg, cluster_t, skew=obs.skew)
        base = self._evacuate(obs.etg, cluster_t, obs.offered_rate)
        plan = refine(
            base,
            cluster_t,
            max_rounds=self.max_moves,
            adaptive_growth=self.adaptive_growth,
            skew=obs.skew,
        )
        moved = placement_migrations(obs.etg, plan.etg)
        if moved == 0:
            self.log.append((obs.window, f"{reason}:no_move"))
            return None
        # Gain only materializes up to what the trace offers; the window
        # length comes from the observation (i.e. the executed trace), so
        # the guard's tuple arithmetic can never disagree with the run.
        demand = obs.offered_rate * self._cir_sum
        gain_rate = min(plan.throughput, demand) - min(cur_thpt, demand)
        benefit = gain_rate * self.horizon_windows * obs.window_s
        cost = moved * self.migration_cost
        if benefit <= cost:
            self.log.append(
                (obs.window, f"{reason}:skip gain={gain_rate:.2f}/s moves={moved}")
            )
            return None
        self.log.append(
            (obs.window, f"{reason}:replan gain={gain_rate:.2f}/s moves={moved}")
        )
        return plan.etg


class OracleRescheduler:
    """Upper-bound baseline: a full ``schedule()`` re-run at every window.

    No drift detection, no cost/benefit guard — the benchmark's oracle
    re-plans from scratch against every window's instantaneous capacity
    (results are cached per capacity vector: ``schedule`` is deterministic
    and rate-independent, so only capacity changes its output). Pair with
    ``RuntimeConfig(migration_pause=0)`` for the idealized free-migration
    oracle the ISSUE acceptance compares the controller against.
    """

    period = 1

    def __init__(self, utg: UserGraph, cluster: Cluster, rate_epsilon: float = 0.05):
        self.utg = utg
        self.cluster = cluster
        self.rate_epsilon = rate_epsilon
        self._cache: dict[bytes, ExecutionGraph] = {}

    def update(self, obs: WindowObs) -> ExecutionGraph | None:
        from repro.core.maximize_throughput import schedule as _schedule

        key = obs.capacity.tobytes()
        plan = self._cache.get(key)
        if plan is None:
            # Algorithm 1 assumes every machine is usable, so schedule on
            # the alive subcluster and map machine indices back.
            alive = np.flatnonzero(obs.capacity > 0.0)
            if alive.size == 0:
                return None
            sub = Cluster(
                machine_types=self.cluster.machine_types[alive],
                capacity=obs.capacity[alive],
                profile=self.cluster.profile,
            )
            sub_plan = _schedule(
                self.utg, sub, r0=1.0, rate_epsilon=self.rate_epsilon
            ).etg
            plan = ExecutionGraph(
                utg=self.utg,
                n_instances=sub_plan.n_instances.copy(),
                assignment=[alive[a] for a in sub_plan.assignment],
            )
            self._cache[key] = plan
        if plan.task_machine().tolist() == obs.etg.task_machine().tolist():
            return None
        return plan
