"""Online rescheduler: drift detection + incremental replanning.

``OnlineController`` watches the executor's windowed metrics and, when the
workload drifts away from the current schedule's sweet spot, re-plans
*incrementally*: instead of re-running the full scheduler from scratch it
hands the live placement to ``refine``'s delta-scored hill climb
(RELOCATE / SWAP / GROW / PAIRGROW / DROP on ``ScheduleState``), bounded to
a few moves per control period, against the cluster's *instantaneous*
capacity (``Cluster.with_capacity``). A replan is applied only when its
projected benefit clears a migration cost/benefit guard.

Drift triggers (any of):

* **capacity change** — the trace slowed or removed a machine since the
  last plan (reported as ``scale_out`` when a machine came *online* —
  a ``machine_addition`` column switching on);
* **drain notice** — a machine alive now is dead in the capacity
  lookahead (``WindowObs.capacity_ahead``): migrate off it *before* the
  capacity actually drops;
* **saturation** — the spout throttle is pinned below 1 or queues sit
  above the watermark (offered load exceeds what the placement sustains);
* **hot machine** — some alive machine's utilization crossed
  ``util_high`` of its capacity (the paper's over-utilization signal).

Cost/benefit guard: the projected gain is the closed-form throughput
improvement *capped by offered demand* (growing past what the trace offers
buys nothing), integrated over ``horizon_windows``, **minus the service the
migrated instances forgo while they sit in their migration pauses** (the
two-sided accounting: a replan that wins 2%/window but idles half the
pipeline for five windows is a loss at short horizons). The cost side is
*state-aware*: restarting instances charge ``migration_cost`` tuples each,
plus ``state_cost`` per keyed-state tuple they must ship
(``placement_transfer`` — hot-key instances ship more state, and their
longer transfer pauses also grow the forgone-service term through the
executor's own ``transfer_pause_windows`` formula). Plans that don't clear
the guard, or whose transfer cost exceeds ``elastic_budget``, are logged
and skipped. ``state_aware=False`` reverts to the flat
``moves × migration_cost`` pricing of earlier PRs — the state-blind
baseline the runtime benchmark compares against.

Elasticity: when the capacity grid *gains* a machine mid-trace
(``machine_addition`` — a column switching on) the drift reason is
``scale_out`` and the replan runs with the larger ``elastic_moves`` round
budget so growth chains can reach the new machine in one control period.
When the executor grants capacity notice
(``RuntimeConfig.capacity_notice`` > 0), a machine that is alive now but
dead in ``WindowObs.capacity_ahead`` triggers a ``drain``: the controller
plans against the *future* capacity (minimum of now and ahead), migrating
instances off the dying machine before its lease expires instead of
losing them with it.

``provision_schedule`` builds the "honest operator" baseline the
benchmarks freeze: Algorithm 1 + just enough Algorithm-2 growth to sustain
a target rate — the paper's protocol of sizing a schedule to the currently
observed load, which is exactly what rate drift then invalidates.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cost_model
from repro.core.first_assignment import first_assignment
from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster
from repro.core.refine import refine
from repro.obs.ledger import ReplanDecision, ReplanLedger
from repro.obs.trace import NULL_RECORDER
from repro.core.schedule_state import (
    ScheduleState,
    _grow_component_fast,
    _hottest_component,
)

__all__ = [
    "WindowObs",
    "OnlineController",
    "OracleRescheduler",
    "provision_schedule",
]


@dataclasses.dataclass(frozen=True)
class WindowObs:
    """What the executor shows a controller at a control point."""

    window: int
    window_s: float
    etg: ExecutionGraph
    capacity: np.ndarray        # (m,) instantaneous per-machine capacity
    offered_rate: float         # trace rate this window
    throttle: float             # spout back-pressure throttle in effect
    machine_util: np.ndarray    # (m,) this window's utilization
    queue_frac: float           # deepest queue / max_queue
    queue_by_component: np.ndarray  # (n,) backlog per component
    throughput: float
    # Fields-grouping view (None / 0 on all-shuffle topologies): the active
    # key realizations as a cost_model.SkewModel, and a counter that bumps
    # at every key_skew_shift boundary.
    skew: "cost_model.SkewModel | None" = None
    skew_epoch: int = 0
    # Runtime config the executor runs under (pause/transfer-rate knobs the
    # guard needs to price migration downtime); None keeps legacy callers
    # working with flat defaults.
    config: "object | None" = None
    # (m,) capacity ``RuntimeConfig.capacity_notice`` windows ahead, or
    # None when no notice is granted — the scale-in (drain) lookahead.
    capacity_ahead: np.ndarray | None = None


def provision_schedule(
    utg: UserGraph, cluster: Cluster, rate: float, margin: float = 1.05
) -> ExecutionGraph:
    """Smallest-effort schedule sustaining ``rate`` (× ``margin``).

    Algorithm 1's minimal ETG, grown with Algorithm 2's hottest-component
    rule (the incremental engine's closed-form growth step) only until the
    closed-form R* covers the target — the paper's protocol of provisioning
    for the *currently observed* rate rather than the cluster's maximum.
    Returns the best-effort schedule even if the target is unreachable.
    """
    target = float(rate) * margin
    etg = first_assignment(utg, cluster, min(target, 1.0))
    state = ScheduleState.from_etg(etg, cluster)
    # Progressive scale-up toward the target (Algorithm 2's regime: grow at
    # moderate rates, not straight at the target — a single component's
    # chunks at a far-away rate may fit on no machine even though stepped
    # growth reaches it comfortably).
    step_rate = state.max_stable_rate()
    for _ in range(10_000):
        if step_rate >= target:
            break
        step_rate = min(max(step_rate * 1.25, target / 64.0), target)
        while state.max_stable_rate() < step_rate:
            util = state.utilization(step_rate)
            over = np.flatnonzero(cluster.capacity - util < 0.0)
            if over.size == 0:
                break
            component = _hottest_component(state, int(over[0]), step_rate)
            if _grow_component_fast(state, component, step_rate) == 0:
                return state.to_etg()  # saturated below the target: best effort
    return state.to_etg()


class OnlineController:
    """Windowed drift detector + guarded incremental rescheduler.

    Args:
      utg: the running topology.
      cluster: the nominal cluster (capacities are overridden per
        observation).
      period: control period in windows.
      max_moves: refine rounds per replan (each round applies one move, so
        this bounds migrations per control period).
      util_high: hot-machine trigger as a fraction of capacity.
      queue_high: queue-fraction trigger.
      migration_cost: tuples charged per migrated/new instance in the
        guard (restart downtime floor, state-independent).
      horizon_windows: windows the projected gain is assumed to persist
        (the guard's amortization horizon).
      state_aware: price migrations by the keyed state they actually ship
        (``placement_transfer`` with the observation's skew model) and
        subtract state-transfer pause downtime from the projected gain.
        ``False`` is the state-blind baseline: flat per-move pricing and
        flat one-window pauses, exactly the pre-state cost model.
      state_cost: guard tuples charged per state tuple shipped (the
        network/recovery price of a unit of keyed state).
      elastic_budget: hard cap on a single replan's transfer cost
        (``moves × migration_cost + state_shipped × state_cost``); plans
        above it are skipped regardless of benefit. ``inf`` disables.
      elastic_moves: refine round budget for ``scale_out``/``drain``
        replans (defaults to ``4 × max_moves``): growing onto a new
        machine or vacating a dying one routinely needs longer move
        chains than steady-state touch-ups.
      adaptive_growth: forward refine's depth-adaptive growth menu (lets a
        single replan grow a component past 4 instances when the closed
        form keeps improving — useful under fast rate ramps).
      measure_noise: when > 0, the controller observes machine utilization
        through the §6.2 measurement model instead of exactly: zero-mean
        Gaussian error with std ``measure_noise * cap_w * 4u(1-u)``
        (peaked at 50% load, truncated below the paper's observed 8% of
        capacity) is added to the drift detector's view. Only *detection*
        sees the noise — replans still score on the exact closed form,
        and the demand-capped cost/benefit guard is what keeps spurious
        triggers from churning the placement (tested no-churn at steady
        state).
      noise_seed: seed stream for the measurement noise (drawn per window,
        so runs stay deterministic).
      recorder: optional ``repro.obs.TraceRecorder``; when enabled, every
        consult gets a span, every decision is mirrored into the
        recorder's record stream, and replans' ``refine`` calls emit
        per-round profiling spans. Decisions land in :attr:`ledger`
        either way — the recorder only adds the trace view.

    Every decision point appends a structured
    ``repro.obs.ReplanDecision`` (trigger, candidate move list, the full
    two-sided guard breakdown, verdict) to :attr:`ledger`; the historical
    string log is the derived :attr:`log` view over it.
    """

    def __init__(
        self,
        utg: UserGraph,
        cluster: Cluster,
        period: int = 10,
        max_moves: int = 4,
        util_high: float = 0.92,
        queue_high: float = 0.25,
        migration_cost: float = 25.0,
        horizon_windows: int = 60,
        adaptive_growth: bool = False,
        measure_noise: float = 0.0,
        noise_seed: int = 0,
        state_aware: bool = True,
        state_cost: float = 1.0,
        elastic_budget: float = float("inf"),
        elastic_moves: int | None = None,
        recorder=None,
    ):
        self.utg = utg
        self.cluster = cluster
        self.period = int(period)
        self.max_moves = int(max_moves)
        self.util_high = float(util_high)
        self.queue_high = float(queue_high)
        self.migration_cost = float(migration_cost)
        self.horizon_windows = int(horizon_windows)
        self.adaptive_growth = bool(adaptive_growth)
        self.measure_noise = float(measure_noise)
        self.noise_seed = int(noise_seed)
        self.state_aware = bool(state_aware)
        self.state_cost = float(state_cost)
        self.elastic_budget = float(elastic_budget)
        self.elastic_moves = (
            4 * self.max_moves if elastic_moves is None else int(elastic_moves)
        )
        self._cir_sum = float(cost_model.component_rates(utg, 1.0).sum())
        self._last_capacity: np.ndarray | None = None
        self._last_skew_epoch: int | None = None
        self.recorder = NULL_RECORDER if recorder is None else recorder
        self.ledger = ReplanLedger()

    @property
    def log(self) -> list[tuple[int, str]]:
        """Legacy ``(window, message)`` view derived from :attr:`ledger`."""
        return self.ledger.legacy_view()

    def _decide(self, dec: ReplanDecision) -> None:
        """Append to the ledger and mirror into the recorder (if any)."""
        self.ledger.append(dec)
        rec = self.recorder
        if rec.enabled:
            rec.decision(dec)
            rec.metrics.counter(
                "controller.replans_accepted"
                if dec.accepted
                else "controller.replans_rejected"
            ).add(1)

    # ------------------------------------------------------------ drift

    def _observed_util(self, obs: WindowObs) -> np.ndarray:
        """The drift detector's view of machine utilization — exact, or
        perturbed by the §6.2 measurement model when ``measure_noise`` > 0
        (seeded per window: same run, same observations)."""
        if self.measure_noise <= 0.0:
            return obs.machine_util
        cap = np.where(obs.capacity > 0.0, obs.capacity, 1.0)
        u = np.clip(obs.machine_util / cap, 0.0, 1.0)
        # §6.2 shape scaled per machine: error is a fraction of *that
        # machine's* instantaneous capacity (the paper's 100-point budget
        # and <8-point truncation as capacity fractions), so slowed-down
        # machines aren't over-noised.
        std = self.measure_noise * cap * 4.0 * u * (1.0 - u)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.noise_seed, obs.window])
        )
        bound = 0.079 * cap
        noise = np.clip(rng.normal(0.0, 1.0, size=std.shape) * std, -bound, bound)
        return np.clip(obs.machine_util + noise, 0.0, None)

    def _drifted(self, obs: WindowObs) -> str | None:
        if self._last_capacity is not None and not np.array_equal(
            obs.capacity, self._last_capacity
        ):
            if np.any((self._last_capacity <= 0.0) & (obs.capacity > 0.0)):
                # A machine came online (machine_addition): elastic growth.
                return "scale_out"
            return "capacity"
        if obs.capacity_ahead is not None:
            dying = (obs.capacity > 0.0) & (np.asarray(obs.capacity_ahead) <= 0.0)
            if dying.any() and np.any(dying[obs.etg.task_machine()]):
                # Capacity notice: a machine hosting instances disappears
                # within the lookahead — drain it proactively instead of
                # losing its instances (and their state) when the column
                # actually drops.
                return "drain"
        if self._last_skew_epoch is not None and (
            obs.skew_epoch != self._last_skew_epoch
        ):
            # A key_skew_shift moved the hot keys: the placement was tuned
            # for the old realization even if nothing saturates yet.
            return "skew_shift"
        if obs.throttle < 1.0 or obs.queue_frac > self.queue_high:
            return "saturated"
        machine_util = self._observed_util(obs)
        alive = obs.capacity > 0.0
        if np.any(machine_util[alive] >= self.util_high * obs.capacity[alive]):
            return "hot"
        if obs.skew is not None and obs.queue_frac > 0.5 * self.queue_high:
            # Keyed blind spot: a single hot instance's queue is building
            # while every machine-average utilization still looks healthy
            # — the even-split signals above would wait for saturation.
            return "hot_instance"
        return None

    # ------------------------------------------------------- evacuation

    @staticmethod
    def _evacuate(etg: ExecutionGraph, cluster_t: Cluster, rate: float) -> ExecutionGraph:
        """Relocate every instance hosted on a capacity-0 machine.

        Thin wrapper over ``ScheduleState.evacuate_machines`` (the shared
        drain primitive): dead machines are drained greedily first because
        a hill climb scoring closed-form throughput cannot escape the
        0-throughput plateau when several instances sit on one, and
        ``refine`` polishes from there. Draining a machine under capacity
        notice is the same call against the lookahead capacity.
        """
        dead = cluster_t.capacity <= 0.0
        if not dead.any():
            return etg
        state = ScheduleState.from_etg(etg, cluster_t)
        state.evacuate_machines(dead, rate)
        return state.to_etg()

    # ----------------------------------------------------------- update

    def update(self, obs: WindowObs) -> ExecutionGraph | None:
        """Executor hook: returns a new placement or None to keep going."""
        from repro.runtime_stream.executor import (
            RuntimeConfig,
            placement_transfer,
            transfer_pause_windows,
        )

        rec = self.recorder
        reason = self._drifted(obs)
        self._last_capacity = obs.capacity.copy()
        self._last_skew_epoch = obs.skew_epoch
        if rec.enabled:
            rec.metrics.counter("controller.drift_checks").add(1)
        if reason is None:
            return None
        if rec.enabled:
            rec.event("drift", cat="controller", trigger=reason)
        capacity = obs.capacity
        if obs.capacity_ahead is not None:
            # Plan against the *future* capacity whenever notice is
            # granted: a machine dying within the lookahead looks dead to
            # the planner, so the drain primitive vacates it (and no other
            # trigger's replan migrates back onto it while the notice
            # stands — that would be churn the removal immediately undoes).
            capacity = np.minimum(obs.capacity, np.asarray(obs.capacity_ahead))
        cluster_t = self.cluster.with_capacity(capacity)
        # Skew-aware scoring throughout: on keyed topologies both the
        # incumbent's worth and every replan candidate price per-instance
        # key shares, so a hot instance the even split cannot see is
        # exactly what the replan optimizes away.
        _, cur_thpt = cost_model.max_stable_rate(obs.etg, cluster_t, skew=obs.skew)
        base = self._evacuate(obs.etg, cluster_t, obs.offered_rate)
        rounds = (
            self.elastic_moves if reason in ("scale_out", "drain") else self.max_moves
        )
        plan = refine(
            base,
            cluster_t,
            max_rounds=rounds,
            adaptive_growth=self.adaptive_growth,
            skew=obs.skew,
            recorder=rec if rec.enabled else None,
        )
        # State-aware transfer pricing: which instances restart, and how
        # much keyed state each ships. The blind baseline prices the same
        # plan with skew=None — flat multiset moves, zero state.
        transfer = placement_transfer(
            obs.etg, plan.etg, skew=obs.skew if self.state_aware else None
        )
        if transfer.moves == 0:
            self._decide(
                ReplanDecision(
                    window=obs.window,
                    trigger=reason,
                    outcome="no_move",
                    candidate_moves=tuple(plan.moves),
                )
            )
            return None
        # Gain only materializes up to what the trace offers; the window
        # length comes from the observation (i.e. the executed trace), so
        # the guard's tuple arithmetic can never disagree with the run.
        demand = obs.offered_rate * self._cir_sum
        gain_rate = min(plan.throughput, demand) - min(cur_thpt, demand)
        benefit = gain_rate * self.horizon_windows * obs.window_s
        # Two-sided accounting: migrated instances serve nothing while
        # paused, and hot-key instances pause longer (state transfer), so
        # their forgone service comes off the projected gain — priced with
        # the executor's own pause formula so guard and run agree.
        cfg = obs.config if isinstance(obs.config, RuntimeConfig) else RuntimeConfig()
        pauses = transfer_pause_windows(transfer, cfg, obs.window_s)
        run_rate = min(obs.offered_rate, plan.rate)
        inst_ir = cost_model.instance_rates(plan.etg, run_rate, skew=obs.skew)
        pause_loss = float(
            (pauses * obs.window_s * inst_ir)[transfer.migrated].sum()
        )
        benefit -= pause_loss
        move_cost = transfer.moves * self.migration_cost
        state_cost = transfer.state_shipped * self.state_cost
        cost = move_cost + state_cost
        if rec.enabled:
            rec.metrics.counter("controller.guard_evals").add(1)
        if cost > self.elastic_budget:
            outcome = "budget"
        elif benefit <= cost:
            outcome = "skip"
        else:
            outcome = "replan"
        self._decide(
            ReplanDecision(
                window=obs.window,
                trigger=reason,
                outcome=outcome,
                moves=int(transfer.moves),
                state_shipped=float(transfer.state_shipped),
                gain_rate=float(gain_rate),
                benefit=float(benefit),
                pause_loss=pause_loss,
                move_cost=float(move_cost),
                state_cost=float(state_cost),
                cost=float(cost),
                budget=self.elastic_budget,
                demand=float(demand),
                current_throughput=float(cur_thpt),
                plan_throughput=float(plan.throughput),
                plan_rate=float(plan.rate),
                horizon_windows=self.horizon_windows,
                candidate_moves=tuple(plan.moves),
            )
        )
        if outcome != "replan":
            return None
        return plan.etg


class OracleRescheduler:
    """Upper-bound baseline: a full ``schedule()`` re-run at every window.

    No drift detection, no cost/benefit guard — the benchmark's oracle
    re-plans from scratch against every window's instantaneous capacity.
    Results are cached per *(capacity vector, skew epoch)*: ``schedule``
    is deterministic and rate-independent, but a ``key_skew_shift``
    changes which placement is best on a keyed topology even though the
    capacity grid is untouched — caching on capacity alone (the old bug)
    served a plan tuned for dead hot keys for the rest of the trace, which
    is how an "oracle" managed to lose to the online controller on keyed
    rows. On keyed topologies the cached plan is also polished skew-aware
    (``refine`` with the observation's skew model) so the oracle prices
    realized key shares, not the even split. Pair with
    ``RuntimeConfig(migration_pause=0)`` for the idealized free-migration
    oracle the ISSUE acceptance compares the controller against.
    """

    period = 1

    def __init__(self, utg: UserGraph, cluster: Cluster, rate_epsilon: float = 0.05):
        self.utg = utg
        self.cluster = cluster
        self.rate_epsilon = rate_epsilon
        self._cache: dict[tuple[bytes, int], ExecutionGraph] = {}

    def _current_polished(
        self, obs: WindowObs, alive: np.ndarray, sub: Cluster
    ) -> "object":
        """Skew-aware ``refine`` seeded from the *running* placement.

        Instances stranded on dead machines are drained first via the
        shared ``ScheduleState.evacuate_machines`` primitive, then machine
        indices are remapped onto the alive subcluster.
        """
        cluster_t = self.cluster.with_capacity(obs.capacity)
        etg = obs.etg
        dead = obs.capacity <= 0.0
        if dead[etg.task_machine()].any():
            state = ScheduleState.from_etg(etg, cluster_t)
            state.evacuate_machines(dead, obs.offered_rate)
            etg = state.to_etg()
        inv = np.full(obs.capacity.shape[0], -1, dtype=np.int64)
        inv[alive] = np.arange(alive.size)
        cur = ExecutionGraph(
            utg=self.utg,
            n_instances=etg.n_instances.copy(),
            assignment=[inv[a] for a in etg.assignment],
        )
        return refine(cur, sub, skew=obs.skew)

    def update(self, obs: WindowObs) -> ExecutionGraph | None:
        from repro.core.maximize_throughput import schedule as _schedule

        key = (obs.capacity.tobytes(), obs.skew_epoch)
        alive = np.flatnonzero(obs.capacity > 0.0)
        if alive.size == 0:
            return None
        # Algorithm 1 assumes every machine is usable, so schedule on
        # the alive subcluster and map machine indices back.
        # ``subcluster`` carries the resource-vector fields (memory
        # capacities and the distance matrix restrict to the alive rows),
        # so the oracle optimizes the same generalized objective.
        sub = self.cluster.subcluster(alive, capacity=obs.capacity[alive])
        plan = self._cache.get(key)
        if plan is None:
            sub_plan = _schedule(
                self.utg, sub, r0=1.0, rate_epsilon=self.rate_epsilon
            ).etg
            if obs.skew is not None:
                # Skew-aware polish on the subcluster (key shares are
                # machine-agnostic, so the skew model carries over as-is).
                sub_plan = refine(sub_plan, sub, skew=obs.skew).etg
            plan = ExecutionGraph(
                utg=self.utg,
                n_instances=sub_plan.n_instances.copy(),
                assignment=[alive[a] for a in sub_plan.assignment],
            )
            self._cache[key] = plan
        if plan.task_machine().tolist() == obs.etg.task_machine().tolist():
            return None
        if obs.skew is not None:
            # Transition window (the plan differs from what is running).
            # Algorithm 1 sizes instances for the even split; under a
            # realized skew its instance counts can hash the hot keys
            # together — a local optimum ``refine`` cannot leave — and a
            # *cached* plan can predate a better placement the executor
            # has since reached. Seed a second polish from the running
            # placement and keep whichever scores the higher skew-aware
            # rate: a capacity or skew transition must never move the
            # oracle onto a worse plan than the one it already executes.
            # Steady-state windows short-circuit above, so this re-polish
            # runs only on the handful of transition windows per trace.
            polished = self._current_polished(obs, alive, sub)
            plan_sub = ExecutionGraph(
                utg=self.utg,
                n_instances=plan.n_instances.copy(),
                assignment=[
                    np.searchsorted(alive, a) for a in plan.assignment
                ],
            )
            plan_rate = refine(plan_sub, sub, max_rounds=0, skew=obs.skew).rate
            if polished.rate > plan_rate:
                plan = ExecutionGraph(
                    utg=self.utg,
                    n_instances=polished.etg.n_instances.copy(),
                    assignment=[alive[a] for a in polished.etg.assignment],
                )
                self._cache[key] = plan
            if plan.task_machine().tolist() == obs.etg.task_machine().tolist():
                return None
        return plan
