"""Online streaming runtime: trace-driven execution of schedules over time.

Everything before this subsystem scored and searched *static* placements;
this package executes them against time-varying workloads:

* ``traces``     — declarative workload scenarios (rate ramps, bursts,
                   sinusoidal drift, machine slowdown/removal) compiled to
                   dense per-window arrays by a seed;
* ``executor``   — a deterministic windowed event loop with per-instance
                   queues, profile-table service costs, machine saturation
                   and spout back-pressure;
* ``controller`` — drift detection + guarded incremental replanning on
                   ``ScheduleState`` via ``refine``'s move set;
* ``eval_jax``   — B traces × P policies in one ``lax.scan`` sweep,
                   agreeing with the Python loop to ~1e-9.

See docs/architecture.md (Online streaming runtime) and docs/api.md.
"""

from repro.runtime_stream.controller import (
    OnlineController,
    OracleRescheduler,
    WindowObs,
    provision_schedule,
)
from repro.runtime_stream.eval_jax import PolicyEvalResult, evaluate_policies_batch
from repro.runtime_stream.executor import (
    MigrationTransfer,
    RuntimeConfig,
    RuntimeResult,
    StreamExecutor,
    placement_migrations,
    placement_transfer,
    transfer_pause_windows,
)
from repro.runtime_stream.traces import (
    CompiledTrace,
    KeyRealization,
    KeyedEdgeTrace,
    TraceSpec,
    burst_trace,
    elastic_trace,
    failure_trace,
    key_skew_shift,
    machine_addition,
    machine_removal,
    machine_slowdown,
    ramp_trace,
    rate_burst,
    rate_noise,
    rate_ramp,
    rate_sine,
    sine_trace,
    skew_shift_trace,
    slowdown_trace,
)

__all__ = [
    "TraceSpec",
    "CompiledTrace",
    "KeyRealization",
    "KeyedEdgeTrace",
    "rate_ramp",
    "rate_burst",
    "rate_sine",
    "rate_noise",
    "machine_slowdown",
    "machine_removal",
    "machine_addition",
    "key_skew_shift",
    "ramp_trace",
    "burst_trace",
    "sine_trace",
    "slowdown_trace",
    "failure_trace",
    "skew_shift_trace",
    "elastic_trace",
    "RuntimeConfig",
    "RuntimeResult",
    "StreamExecutor",
    "MigrationTransfer",
    "placement_migrations",
    "placement_transfer",
    "transfer_pause_windows",
    "WindowObs",
    "OnlineController",
    "OracleRescheduler",
    "provision_schedule",
    "PolicyEvalResult",
    "evaluate_policies_batch",
]
