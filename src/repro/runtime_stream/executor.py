"""Trace-driven streaming executor: a deterministic windowed event loop.

Executes a schedule (an ``ExecutionGraph`` on a ``Cluster``) against a
compiled workload trace in fixed-length windows. Per window, the loop is a
discrete-time fluid model of Storm's executor pipeline:

1. **Arrive.** Spouts emit the window's offered rate scaled by the current
   back-pressure throttle; each bolt receives its parents' *previous-window*
   processed output times the edge's tuple-division ratio alpha (eq. 6) —
   tuples travel one hop per window. A shuffle-grouped stream splits evenly
   over the component's instances; a fields-grouped edge routes each key's
   share to the instance its drawn hash pins it to
   (``KeyRealization.shares``, the deterministic hash→instance map), so
   hot keys land in single per-instance queues. Queues are bounded at
   ``max_queue`` tuples; overflow is dropped (and counted).
2. **Serve.** Every instance tries to drain its whole queue this window;
   its service demand prices at the profile tables (eq. 5:
   ``e·rate + MET``). A machine whose demand exceeds its windowed capacity
   applies proportional fair throttling — the same saturation model as the
   §6.3 simulator (``s_w = clip(head_w / var_w, 0, 1)``).
3. **Back-pressure.** When any queue crosses the high watermark the spout
   throttle halves (Storm 1.x-style spout back-pressure); when all queues
   drain below the low watermark it recovers multiplicatively.

Determinism: the loop is a pure function of the compiled trace (all
randomness lives in ``TraceSpec.compile(seed)``), so the same seed + spec
produce bit-identical event logs and metrics. The JAX batch evaluator
(``eval_jax.evaluate_policies_batch``) mirrors this window step exactly and
agrees to ~1e-9 on shared scenarios (tested).

A controller (see ``controller.py``) may swap the placement between
windows; migrated/new instances pause for ``migration_pause`` windows
(their queues hold but do not serve), modeling restart downtime. Keyed
instances with operator state (``FieldsGrouping.state_per_tuple``)
additionally pause for the time their state takes to ship at
``state_transfer_rate`` — a hot-key instance pauses longer than a cold
one (``placement_transfer`` is the single owner of the who-moves /
how-much-state accounting the executor and the controller's cost/benefit
guard share).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math

import numpy as np

from repro.core.graph import ExecutionGraph
from repro.core.metrics import per_machine_utilization
from repro.core.profiles import Cluster
from repro.obs.trace import NULL_RECORDER

from repro.runtime_stream.traces import CompiledTrace, TraceSpec

__all__ = [
    "RuntimeConfig",
    "RuntimeResult",
    "StreamExecutor",
    "MigrationTransfer",
    "placement_migrations",
    "placement_transfer",
    "transfer_pause_windows",
]


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Event-loop constants shared by the Python executor and the JAX
    evaluator (both backends must see identical values for parity).

    Attributes:
      max_queue: per-instance queue bound (tuples); overflow is dropped.
      bp_high: queue fraction that trips spout back-pressure.
      bp_low: queue fraction below which the throttle recovers.
      throttle_down / throttle_up: multiplicative spout-throttle AIMD-style
        decrease/recovery factors.
      throttle_min: floor so a saturated spout keeps probing.
      migration_pause: windows a migrated or newly added instance pauses
        (queues hold, no service) after a placement change.
      state_transfer_rate: keyed-state tuples shippable per second while an
        instance migrates; a migrated instance holding S state tuples
        pauses ``migration_pause + ceil(S / (rate * window_s))`` windows.
        The default (inf) makes state transfer instantaneous — the
        state-blind runtime of earlier PRs, bit-identical.
      capacity_notice: windows of advance notice the controller gets about
        capacity changes (``WindowObs.capacity_ahead`` — cloud removals
        are announced, e.g. spot-instance termination warnings). 0
        disables the lookahead.
    """

    max_queue: float = 500.0
    bp_high: float = 0.5
    bp_low: float = 0.1
    throttle_down: float = 0.5
    throttle_up: float = 1.25
    throttle_min: float = 0.05
    migration_pause: int = 1
    state_transfer_rate: float = float("inf")
    capacity_notice: int = 0


@dataclasses.dataclass(frozen=True)
class RuntimeResult:
    """Windowed metrics of one executed run (arrays indexed by window).

    ``machine_util`` follows ``core.metrics`` semantics: the sum of hosted
    tasks' TCU (eq. 5 at the *processed* rate) per machine. ``throughput``
    is the paper's eq. 2 objective — the sum of all task processing rates —
    measured per window. ``sustained_throughput()`` is the steady-state
    summary the benchmarks compare policies on.
    """

    name: str
    window_s: float
    offered: np.ndarray        # (W,) trace rate
    admitted: np.ndarray       # (W,) spout rate after back-pressure throttle
    throughput: np.ndarray     # (W,) sum of task processing rates
    dropped: np.ndarray        # (W,) tuples/s lost to full queues
    queue_total: np.ndarray    # (W,) total backlog (tuples)
    queue_max: np.ndarray      # (W,) deepest per-instance queue (tuples)
    machine_util: np.ndarray   # (W, m)
    throttle: np.ndarray       # (W,)
    migrations: np.ndarray     # (W,) instances moved/added by replans
    events: tuple[tuple[int, str], ...]
    final_etg: ExecutionGraph

    @property
    def n_windows(self) -> int:
        return int(self.throughput.shape[0])

    def sustained_throughput(self, tail_frac: float = 0.5) -> float:
        """Mean throughput over the trailing ``tail_frac`` of the horizon
        (the steady state after controllers/queues converge)."""
        start = int(self.n_windows * (1.0 - tail_frac))
        return float(self.throughput[start:].mean())

    def latency(self) -> np.ndarray:
        """(W,) per-window queueing-latency estimate in seconds: standing
        backlog over the window's service rate (Little's law, L = λ·T).
        Windows that serve nothing while holding backlog saturate at the
        horizon length — "unboundedly late" without an inf in the stats.
        Derived, not stored: fingerprints of earlier PRs stay valid."""
        horizon = self.n_windows * self.window_s
        with np.errstate(divide="ignore", invalid="ignore"):
            lat = np.where(
                self.queue_total > 0.0,
                self.queue_total / np.maximum(self.throughput, 1e-300),
                0.0,
            )
        return np.minimum(lat, horizon)

    def latency_slo_frac(self, slo_s: float, tail_frac: float = 0.5) -> float:
        """Fraction of the trailing ``tail_frac`` windows whose estimated
        queueing latency meets ``slo_s`` — the latency-SLO column the
        runtime benchmark records alongside sustained throughput."""
        start = int(self.n_windows * (1.0 - tail_frac))
        return float((self.latency()[start:] <= slo_s).mean())

    def fingerprint(self) -> str:
        """md5 over every metric array + the event log — two runs of the
        same seed/spec must produce equal fingerprints (bit-determinism)."""
        h = hashlib.md5()
        for arr in (
            self.offered, self.admitted, self.throughput, self.dropped,
            self.queue_total, self.queue_max, self.machine_util,
            self.throttle, self.migrations,
        ):
            h.update(np.ascontiguousarray(arr).tobytes())
        h.update(repr(self.events).encode())
        h.update(repr(self.final_etg.task_machine().tolist()).encode())
        return h.hexdigest()


def placement_migrations(old: ExecutionGraph, new: ExecutionGraph) -> int:
    """Instances that must start or move to turn ``old`` into ``new``.

    Per component, instances on a machine are interchangeable, so the cost
    is the multiset difference of per-machine counts: ``sum_w max(0,
    new_cw - old_cw)`` — newly added instances and relocations both count
    once; drops are free (a stopped instance ships no state). This is the
    flat *move count*; ``placement_transfer`` adds the state-weighted view
    (which instances restart and how much keyed state each must load).
    """
    m = 1 + max(
        (int(a.max()) for a in old.assignment + new.assignment if a.size),
        default=0,
    )
    total = 0
    for c in range(old.utg.n_components):
        oc = np.bincount(old.assignment[c], minlength=m)
        nc = np.bincount(new.assignment[c], minlength=m)
        total += int(np.clip(nc - oc, 0, None).sum())
    return total


@dataclasses.dataclass(frozen=True)
class MigrationTransfer:
    """State-aware cost of turning one placement into another.

    Attributes:
      moves: instances that restart (start, move, or — for keyed
        components whose instance count changed — rehash). Equals
        ``placement_migrations`` on shuffle-only topologies.
      state_shipped: total keyed state (state tuples) that must change
        hosts before the new placement serves at full strength.
      migrated: (T_new,) bool — per new-layout instance, does it restart.
      instance_state: (T_new,) state tuples each restarting instance must
        load (0 for carried-over instances and stateless components) —
        the executor prices each instance's migration pause from this,
        the controller guard the service lost while it sits paused.
    """

    moves: int
    state_shipped: float
    migrated: np.ndarray
    instance_state: np.ndarray


def placement_transfer(
    old: ExecutionGraph, new: ExecutionGraph, skew=None
) -> MigrationTransfer:
    """State-weighted migration accounting (the cost model the controller
    guard and the executor's pause mechanics share).

    Shuffle components keep the multiset rule of ``placement_migrations``
    (instances on a machine are interchangeable; the first ``old_cw``
    instances a machine retains carry over, the rest restart) and ship no
    state. Keyed components are *index-pinned* — the hash→instance map
    routes key k to instance ``hash_k % N`` — so instance k restarts iff
    its machine changed at index k; if the instance count changed, every
    key rehashes and the whole component restarts and reships its state.
    Each restarting instance loads the keyed state of the key share it
    owns under the *new* realization (``SkewModel.instance_state``): hot
    instances ship more. With ``skew=None`` the accounting is state-blind
    and multiset everywhere — drops remain free in every mode.
    """
    m = 1 + max(
        (int(a.max()) for a in old.assignment + new.assignment if a.size),
        default=0,
    )
    offsets = new.component_offsets()
    T_new = int(offsets[-1])
    migrated = np.zeros(T_new, dtype=bool)
    instance_state = np.zeros(T_new, dtype=np.float64)
    keyed = set() if skew is None else set(skew.keyed_components)
    moves = 0
    for c in range(old.utg.n_components):
        lo, hi = int(offsets[c]), int(offsets[c + 1])
        if c in keyed:
            n_old, n_new = int(old.n_instances[c]), int(new.n_instances[c])
            state_vec = skew.instance_state(c, n_new)
            if n_old != n_new:
                # Resize rehashes every key: the whole component restarts
                # and repartitions its state (Storm rebalance semantics).
                mig = np.ones(n_new, dtype=bool)
            else:
                mig = np.asarray(old.assignment[c]) != np.asarray(new.assignment[c])
            migrated[lo:hi] = mig
            instance_state[lo:hi] = np.where(mig, state_vec, 0.0)
            moves += int(mig.sum())
        else:
            keep = np.bincount(old.assignment[c], minlength=m)
            for k, w in enumerate(new.assignment[c]):
                if keep[w] > 0:
                    keep[w] -= 1
                else:
                    migrated[lo + k] = True
                    moves += 1
    return MigrationTransfer(
        moves=moves,
        state_shipped=float(instance_state.sum()),
        migrated=migrated,
        instance_state=instance_state,
    )


def transfer_pause_windows(
    transfer: MigrationTransfer, config: RuntimeConfig, window_s: float
) -> np.ndarray:
    """(T_new,) pause windows per new-layout instance: restarting
    instances hold for ``migration_pause`` plus however long their keyed
    state takes to ship at ``config.state_transfer_rate`` — the shared
    formula behind the executor's pauses and the guard's lost-service
    term (one copy, so the guard can never disagree with the run)."""
    pause = np.where(transfer.migrated, config.migration_pause, 0).astype(np.int64)
    rate = config.state_transfer_rate
    if math.isfinite(rate) and rate > 0.0:
        extra = np.ceil(transfer.instance_state / (rate * window_s))
        pause = pause + np.where(
            transfer.migrated, extra.astype(np.int64), 0
        )
    return pause


class _Placement:
    """Flat per-task views of one ExecutionGraph on one cluster."""

    __slots__ = ("etg", "comp", "machine", "e", "met", "n_inst", "offsets")

    def __init__(self, etg: ExecutionGraph, cluster: Cluster):
        self.etg = etg
        self.comp = etg.task_component()
        self.machine = etg.task_machine()
        ttypes = etg.utg.component_types[self.comp]
        mtypes = cluster.machine_types[self.machine]
        self.e = cluster.profile.e[ttypes, mtypes]
        self.met = cluster.profile.met[ttypes, mtypes]
        self.n_inst = etg.n_instances
        self.offsets = etg.component_offsets()


class StreamExecutor:
    """Deterministic windowed event loop for one (topology, cluster, trace).

    Args:
      etg: the initial schedule to execute.
      cluster: the cluster (nominal capacities; the trace modulates them).
      trace: a ``TraceSpec`` (compiled here with ``seed``) or an already
        compiled ``CompiledTrace`` (its own seed wins).
      seed: compilation seed for stochastic trace events.
      config: event-loop constants (see ``RuntimeConfig``).
      background_load: optional (W, m) or (m,) load other occupants of the
        shared machines consume — subtracted (clipped at zero) from the
        trace's capacity grid each window, so both the service step and
        every controller observation see only the residual head room.
        This is how the multi-tenant runtime prices co-tenants.
    """

    def __init__(
        self,
        etg: ExecutionGraph,
        cluster: Cluster,
        trace: TraceSpec | CompiledTrace,
        seed: int = 0,
        config: RuntimeConfig | None = None,
        background_load: np.ndarray | None = None,
        recorder=None,
    ):
        self.cluster = cluster
        self.config = config or RuntimeConfig()
        # Observability (repro.obs): NULL_RECORDER makes every hook a no-op
        # and keeps the windowed loop bit-identical to the uninstrumented
        # path — the recorder only ever *appends* to its own state.
        self.recorder = NULL_RECORDER if recorder is None else recorder
        self.trace = (
            trace
            if isinstance(trace, CompiledTrace)
            else trace.compile(cluster, seed, utg=etg.utg)
        )
        if self.trace.capacity.shape[1] != cluster.n_machines:
            raise ValueError("trace capacity grid does not match the cluster")
        if background_load is not None:
            bg = np.asarray(background_load, dtype=np.float64)
            if bg.ndim == 1:
                bg = np.broadcast_to(bg, self.trace.capacity.shape)
            if bg.shape != self.trace.capacity.shape:
                raise ValueError(
                    "background_load must be (m,) or match the trace's "
                    f"(W, m) capacity grid {self.trace.capacity.shape}"
                )
            self.trace = dataclasses.replace(
                self.trace,
                capacity=np.clip(self.trace.capacity - bg, 0.0, None),
            )
        keyed_edges = {kt.edge for kt in self.trace.keyed}
        want_edges = {g.edge for g in etg.utg.groupings}
        if keyed_edges != want_edges:
            raise ValueError(
                "compiled trace's keyed edges do not match the topology's "
                "fields groupings — compile the trace with utg=etg.utg"
            )
        self._initial_etg = etg
        self._skew_cache: dict[int, object] = {}

    # ------------------------------------------------------------- run

    def run(self, controller=None) -> RuntimeResult:
        """Execute the trace; optionally let ``controller`` replan between
        windows.

        ``controller`` is any object with an integer ``period`` attribute
        and an ``update(obs) -> ExecutionGraph | None`` method; it is
        consulted every ``period`` windows with a ``WindowObs`` (see
        ``controller.py``) and may return a new placement, which takes
        effect next window (migrated/new instances pause per the config).

        When the executor was constructed with a ``repro.obs``
        ``TraceRecorder``, the run activates it (so closed-form dispatch
        decisions anywhere below land in its log) and emits window-clock
        events, back-pressure transitions, replan events and
        per-component throughput / queue high-water metrics. The recorder
        only appends to its own state: results and
        ``RuntimeResult.fingerprint()`` are bit-identical with or without
        it.
        """
        with self.recorder.activate():
            return self._run(controller)

    def _run(self, controller=None) -> RuntimeResult:
        from repro.runtime_stream.controller import WindowObs

        cfg = self.config
        tr = self.trace
        dt = tr.window_s
        W = tr.n_windows
        m = self.cluster.n_machines
        utg = self._initial_etg.utg
        n = utg.n_components
        topo = utg.topo_order()
        sources = set(utg.sources)
        parents = [utg.parents(i) for i in range(n)]
        alpha = utg.alpha

        # Keyed routing state: per fields edge, the parent, destination,
        # per-window active-segment index and the segment realizations;
        # shuffle_parents keeps only the evenly-split in-edges (spout
        # injection is always even). With no fields groupings this leaves
        # the arrival path bit-identical to the even-split event loop.
        keyed: list[tuple[int, int, np.ndarray, list]] = []
        for kt in tr.keyed:
            keyed.append(
                (
                    kt.edge[0],
                    kt.edge[1],
                    kt.segment_indices(W),
                    [r for _, r in kt.segments],
                )
            )
        keyed_edge_set = {(p, i) for p, i, _, _ in keyed}
        shuffle_parents = [
            [p for p in parents[i] if (p, i) not in keyed_edge_set]
            for i in range(n)
        ]

        place = _Placement(self._initial_etg, self.cluster)
        backlog = np.zeros(place.comp.shape[0], dtype=np.float64)
        pause = np.zeros(place.comp.shape[0], dtype=np.int64)
        prev_out = np.zeros(n, dtype=np.float64)
        throttle = 1.0

        offered = tr.rates
        admitted = np.zeros(W)
        throughput = np.zeros(W)
        dropped = np.zeros(W)
        queue_total = np.zeros(W)
        queue_max = np.zeros(W)
        machine_util = np.zeros((W, m))
        throttle_log = np.zeros(W)
        migrations = np.zeros(W, dtype=np.int64)
        events: list[tuple[int, str]] = list(tr.events)
        bp_on = False

        rec = self.recorder
        obs_on = rec.enabled
        if obs_on:
            rec.event("run_start", cat="executor", windows=W, machines=m, trace=tr.name)
            comp_tuples = [
                rec.metrics.counter(f"executor.throughput.c{i}") for i in range(n)
            ]
            q_hwm = rec.metrics.gauge("executor.queue_max")
            dropped_ctr = rec.metrics.counter("executor.dropped_tuples")
            replan_ctr = rec.metrics.counter("executor.replans_applied")
            # Per-window values accumulate in a vector and flush to the
            # counters once after the loop — W*n Counter.add calls in the
            # hot loop would dominate recorder overhead.
            comp_acc = np.zeros(n, dtype=np.float64)

        for t in range(W):
            if obs_on:
                rec.set_window(t)
            cap = tr.capacity[t]
            r_adm = offered[t] * throttle

            # 1. Arrivals: one hop per window (spouts this window, bolts
            # from their parents' previous-window processed output).
            # Shuffle streams split evenly; each fields edge then adds its
            # keyed contribution at the active realization's hash shares.
            arr = np.zeros(n, dtype=np.float64)
            for i in topo:
                if i in sources:
                    arr[i] = r_adm
                else:
                    for p in shuffle_parents[i]:
                        arr[i] += alpha[p] * prev_out[p]
            arr_inst = arr[place.comp] / place.n_inst[place.comp]
            for p, i, seg_idx, segs in keyed:
                lo, hi = int(place.offsets[i]), int(place.offsets[i + 1])
                real = segs[seg_idx[t]]
                arr_inst[lo:hi] += (alpha[p] * prev_out[p]) * real.shares(hi - lo)
            backlog = backlog + arr_inst * dt
            over = np.clip(backlog - cfg.max_queue, 0.0, None)
            backlog = backlog - over
            dropped[t] = float(over.sum()) / dt

            # 2. Service under proportional fair machine throttling.
            active = (pause == 0).astype(np.float64)
            desired = backlog / dt * active
            var_w = per_machine_utilization(place.machine, place.e * desired, m)
            met_w = per_machine_utilization(place.machine, place.met * active, m)
            head = np.maximum(cap - met_w, 0.0)
            with np.errstate(divide="ignore", invalid="ignore"):
                s = np.where(var_w > head, head / np.maximum(var_w, 1e-300), 1.0)
            processed = desired * s[place.machine]
            backlog = np.maximum(backlog - processed * dt, 0.0)
            alive = (cap > 0.0).astype(np.float64)
            tcu = place.e * processed + place.met * active * alive[place.machine]

            # bincount == np.add.at bit-for-bit (sequential input-order
            # accumulation), minus the per-window ufunc dispatch cost.
            prev_out = np.bincount(place.comp, weights=processed, minlength=n)

            # 3. Metrics + spout back-pressure for the next window.
            admitted[t] = r_adm
            throughput[t] = float(processed.sum())
            queue_total[t] = float(backlog.sum())
            queue_max[t] = float(backlog.max()) if backlog.size else 0.0
            machine_util[t] = per_machine_utilization(place.machine, tcu, m)
            throttle_log[t] = throttle
            if obs_on:
                comp_acc += prev_out
            q_frac = queue_max[t] / cfg.max_queue
            if q_frac > cfg.bp_high:
                throttle = max(cfg.throttle_min, throttle * cfg.throttle_down)
                if not bp_on:
                    events.append((t, "backpressure_on"))
                    bp_on = True
                    if obs_on:
                        rec.event("backpressure_on", cat="executor")
            elif q_frac < cfg.bp_low:
                throttle = min(1.0, throttle * cfg.throttle_up)
                if bp_on and throttle >= 1.0:
                    events.append((t, "backpressure_off"))
                    bp_on = False
                    if obs_on:
                        rec.event("backpressure_off", cat="executor")
            pause = np.maximum(pause - 1, 0)

            # 4. Controller hook (takes effect from the next window).
            if controller is not None and (t + 1) % controller.period == 0 and t + 1 < W:
                notice = cfg.capacity_notice
                obs = WindowObs(
                    window=t,
                    window_s=dt,
                    etg=place.etg,
                    capacity=cap,
                    offered_rate=float(offered[t]),
                    throttle=float(throttle),
                    machine_util=machine_util[t],
                    queue_frac=float(q_frac),
                    queue_by_component=self._component_backlog(place, backlog),
                    throughput=float(throughput[t]),
                    skew=self.skew_model_at(t),
                    skew_epoch=tr.skew_epoch(t),
                    config=cfg,
                    capacity_ahead=(
                        tr.capacity[min(t + notice, W - 1)] if notice > 0 else None
                    ),
                )
                if obs_on:
                    with rec.span("controller.update", cat="controller"):
                        new_etg = controller.update(obs)
                else:
                    new_etg = controller.update(obs)
                if new_etg is not None:
                    transfer = placement_transfer(
                        place.etg, new_etg, skew=self.skew_model_at(t)
                    )
                    place, backlog, pause = self._migrate(
                        place, new_etg, backlog, transfer, t
                    )
                    migrations[t] = transfer.moves
                    events.append((t, f"replan:{transfer.moves}moves"))
                    if obs_on:
                        replan_ctr.add(1)
                        rec.event(
                            "replan_applied",
                            cat="executor",
                            moves=int(transfer.moves),
                            state_shipped=float(transfer.state_shipped),
                        )

        if obs_on:
            for i in range(n):
                comp_tuples[i].add(float(comp_acc[i]) * dt)
            if W:
                q_hwm.set(float(queue_max.max()))  # high-water mark
                q_hwm.set(float(queue_max[W - 1]))  # value = last window
            dropped_ctr.add(float(dropped.sum()) * dt)

        return RuntimeResult(
            name=tr.name,
            window_s=dt,
            offered=offered.copy(),
            admitted=admitted,
            throughput=throughput,
            dropped=dropped,
            queue_total=queue_total,
            queue_max=queue_max,
            machine_util=machine_util,
            throttle=throttle_log,
            migrations=migrations,
            events=tuple(events),
            final_etg=place.etg,
        )

    # ------------------------------------------------------------- skew

    def skew_model_at(self, window: int):
        """Skew-aware cost view of the active key realizations (cached per
        realization epoch; None for all-shuffle topologies). Controllers
        thread this into ``refine`` so replans score imbalanced placements
        with the realized per-instance load fractions."""
        if not self.trace.keyed:
            return None
        epoch = self.trace.skew_epoch(window)
        model = self._skew_cache.get(epoch)
        if model is None:
            from repro.core.cost_model import SkewModel

            reals = self.trace.realizations_at(window)
            model = SkewModel(
                self._initial_etg.utg, {e: r.shares for e, r in reals.items()}
            )
            self._skew_cache[epoch] = model
        return model

    # ------------------------------------------------------- migration

    @staticmethod
    def _component_backlog(place: _Placement, backlog: np.ndarray) -> np.ndarray:
        return np.bincount(
            place.comp, weights=backlog, minlength=place.n_inst.shape[0]
        )

    def _migrate(
        self,
        place: _Placement,
        new_etg: ExecutionGraph,
        backlog: np.ndarray,
        transfer: MigrationTransfer,
        window: int,
    ) -> tuple[_Placement, np.ndarray, np.ndarray]:
        """Swap the live placement.

        A shuffle component's total backlog redistributes evenly over its
        new instances (shuffle regrouping on restart). A keyed component's
        in-flight tuples re-route *by key*: its backlog redistributes by
        the active realization's per-instance fractions
        (``SkewModel.instance_fractions`` — the same blend of even shuffle
        share and hash→instance key share every arrival uses), so a hot
        instance's queue stays hot across a replan instead of being
        laundered into an even split the routing immediately undoes.
        Restarting instances (``transfer.migrated``) pause for
        ``migration_pause`` windows plus their keyed state's transfer time
        (``transfer_pause_windows``) — a hot-key instance pauses longer
        than a cold one.
        """
        comp_backlog = self._component_backlog(place, backlog)
        new_place = _Placement(new_etg, self.cluster)
        new_backlog = (
            comp_backlog[new_place.comp] / new_place.n_inst[new_place.comp]
        )
        skew = self.skew_model_at(window)
        if skew is not None:
            offsets = new_etg.component_offsets()
            for c in skew.keyed_components:
                lo, hi = int(offsets[c]), int(offsets[c + 1])
                new_backlog[lo:hi] = comp_backlog[c] * skew.instance_fractions(
                    c, hi - lo
                )
        pause = transfer_pause_windows(transfer, self.config, self.trace.window_s)
        return new_place, new_backlog, pause
