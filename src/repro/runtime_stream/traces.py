"""Declarative workload traces for the streaming runtime.

A ``TraceSpec`` describes a time-varying workload as a base offered rate
plus a tuple of composable *events* — rate ramps, bursts, sinusoidal
drift, multiplicative noise, machine slowdown/removal — applied in order.
``TraceSpec.compile(cluster, seed)`` lowers the spec to a ``CompiledTrace``:
two dense arrays, the per-window offered spout rate ``rates`` (W,) and the
per-window machine capacity grid ``capacity`` (W, m). Everything stochastic
(burst jitter, rate noise) is drawn from ``np.random.default_rng(seed)``
during compilation, so a compiled trace is a pure value: the executor and
the JAX evaluator consume the same arrays, and repeated runs are
bit-identical by construction.

This mirrors the paper's §6.3 measurement protocol — "gradually increase
the input rate until the cluster saturates" — as the ``rate_ramp`` event,
and extends it with the drift/failure scenarios evaluated by the online
controller (see docs/paper_map.md).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.profiles import Cluster

__all__ = [
    "TraceSpec",
    "CompiledTrace",
    "rate_ramp",
    "rate_burst",
    "rate_sine",
    "rate_noise",
    "machine_slowdown",
    "machine_removal",
    "ramp_trace",
    "burst_trace",
    "sine_trace",
    "slowdown_trace",
    "failure_trace",
]


@dataclasses.dataclass(frozen=True)
class CompiledTrace:
    """Dense per-window arrays of one workload scenario.

    Attributes:
      name: scenario name (from the spec).
      window_s: window length in seconds (the event-loop dt).
      rates: (W,) offered topology input rate per window (tuples/s at each
        spout, the paper's R0 as a function of time).
      capacity: (W, m) per-machine CPU capacity per window; 0.0 = removed.
      events: (window, description) markers for capacity changes, for
        event logs and plots.
      seed: the seed the stochastic events were drawn with.
    """

    name: str
    window_s: float
    rates: np.ndarray
    capacity: np.ndarray
    events: tuple[tuple[int, str], ...]
    seed: int

    @property
    def n_windows(self) -> int:
        return int(self.rates.shape[0])

    @property
    def n_machines(self) -> int:
        return int(self.capacity.shape[1])


# ------------------------------------------------------------------ events


@dataclasses.dataclass(frozen=True)
class rate_ramp:
    """Linear rate ramp from the curve's value at ``start`` to ``to_rate``
    over [start, end); windows >= end hold ``to_rate`` (the paper's gradual
    rate increase protocol)."""

    to_rate: float
    start: int = 0
    end: int | None = None

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = rates.shape[0]
        end = W if self.end is None else min(self.end, W)
        if end > self.start:
            span = end - self.start
            rates[self.start : end] = np.linspace(
                rates[self.start], self.to_rate, span
            )
            rates[end:] = self.to_rate
        return []


@dataclasses.dataclass(frozen=True)
class rate_burst:
    """Multiplicative bursts: every ``every`` windows from ``start``, the
    rate is multiplied by ``factor`` for ``width`` windows. ``jitter``
    shifts each burst start by a seeded uniform integer in [-jitter, jitter]."""

    factor: float = 3.0
    every: int = 40
    width: int = 5
    start: int = 0
    jitter: int = 0

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = rates.shape[0]
        for s in range(self.start, W, self.every):
            if self.jitter:
                s += int(rng.integers(-self.jitter, self.jitter + 1))
            lo, hi = max(s, 0), min(max(s, 0) + self.width, W)
            rates[lo:hi] *= self.factor
        return []


@dataclasses.dataclass(frozen=True)
class rate_sine:
    """Sinusoidal drift: ``rate *= 1 + amplitude * sin(2*pi*(t-start)/period)``
    for windows t >= start (clipped at zero)."""

    amplitude: float = 0.5
    period: int = 60
    start: int = 0

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = rates.shape[0]
        t = np.arange(W - self.start, dtype=np.float64)
        wave = 1.0 + self.amplitude * np.sin(2.0 * np.pi * t / self.period)
        rates[self.start :] *= np.clip(wave, 0.0, None)
        return []


@dataclasses.dataclass(frozen=True)
class rate_noise:
    """Seeded multiplicative log-normal rate noise (sigma = ``scale``)."""

    scale: float = 0.05

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        rates *= np.exp(rng.normal(0.0, self.scale, size=rates.shape))
        return []


@dataclasses.dataclass(frozen=True)
class machine_slowdown:
    """Machine ``machine`` runs at ``factor`` of its capacity in
    [start, end) (end=None -> until the trace ends)."""

    machine: int
    factor: float = 0.5
    start: int = 0
    end: int | None = None

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = capacity.shape[0]
        end = W if self.end is None else min(self.end, W)
        capacity[self.start : end, self.machine] *= self.factor
        return [
            (self.start, f"slowdown m{self.machine} x{self.factor}"),
            *([(end, f"recover m{self.machine}")] if end < W else []),
        ]


@dataclasses.dataclass(frozen=True)
class machine_removal:
    """Machine ``machine`` is removed (capacity 0) in [start, end)."""

    machine: int
    start: int = 0
    end: int | None = None

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = capacity.shape[0]
        end = W if self.end is None else min(self.end, W)
        capacity[self.start : end, self.machine] = 0.0
        return [
            (self.start, f"remove m{self.machine}"),
            *([(end, f"restore m{self.machine}")] if end < W else []),
        ]


# -------------------------------------------------------------------- spec


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """A workload scenario: base rate + ordered composable events.

    ``n_windows`` fixed-length windows of ``window_s`` seconds each; the
    offered rate starts flat at ``base_rate`` and each event transforms the
    rate curve and/or the capacity grid in declaration order.
    """

    name: str
    n_windows: int
    base_rate: float
    events: tuple = ()
    window_s: float = 1.0

    def compile(self, cluster: Cluster, seed: int = 0) -> CompiledTrace:
        """Lower to dense (W,) rate and (W, m) capacity arrays.

        All randomness (burst jitter, noise) is drawn here from
        ``default_rng(seed)`` — the compiled trace is a pure value and
        every consumer of it is deterministic.
        """
        if self.n_windows < 1:
            raise ValueError("trace needs at least one window")
        rng = np.random.default_rng(seed)
        rates = np.full(self.n_windows, float(self.base_rate), dtype=np.float64)
        capacity = np.tile(cluster.capacity, (self.n_windows, 1)).astype(np.float64)
        markers: list[tuple[int, str]] = []
        for ev in self.events:
            markers.extend(ev.apply(rates, capacity, rng))
        np.clip(rates, 0.0, None, out=rates)
        np.clip(capacity, 0.0, None, out=capacity)
        return CompiledTrace(
            name=self.name,
            window_s=float(self.window_s),
            rates=rates,
            capacity=capacity,
            events=tuple(sorted(markers)),
            seed=seed,
        )


# ------------------------------------------------------- stock scenarios


def ramp_trace(
    lo_rate: float, hi_rate: float, n_windows: int = 240, hold: int = 20
) -> TraceSpec:
    """The paper's gradual rate-ramp protocol: hold ``lo_rate`` for
    ``hold`` windows, ramp linearly to ``hi_rate``, then hold."""
    return TraceSpec(
        name="ramp",
        n_windows=n_windows,
        base_rate=lo_rate,
        events=(rate_ramp(hi_rate, start=hold, end=n_windows - hold),),
    )


def burst_trace(
    base_rate: float,
    factor: float = 3.0,
    n_windows: int = 240,
    every: int = 48,
    width: int = 8,
    jitter: int = 3,
) -> TraceSpec:
    """Periodic rate bursts with seeded start jitter."""
    return TraceSpec(
        name="burst",
        n_windows=n_windows,
        base_rate=base_rate,
        events=(rate_burst(factor, every=every, width=width, start=16, jitter=jitter),),
    )


def sine_trace(
    mean_rate: float, amplitude: float = 0.5, n_windows: int = 240, period: int = 80
) -> TraceSpec:
    """Sinusoidal diurnal-style drift around ``mean_rate``."""
    return TraceSpec(
        name="sine",
        n_windows=n_windows,
        base_rate=mean_rate,
        events=(rate_sine(amplitude, period=period),),
    )


def slowdown_trace(
    rate: float, machine: int, factor: float = 0.5, n_windows: int = 240
) -> TraceSpec:
    """Constant rate; ``machine`` slows to ``factor`` capacity a third of
    the way in (resource churn without failure)."""
    return TraceSpec(
        name="slowdown",
        n_windows=n_windows,
        base_rate=rate,
        events=(machine_slowdown(machine, factor, start=n_windows // 3),),
    )


def failure_trace(rate: float, machine: int, n_windows: int = 240) -> TraceSpec:
    """Constant rate; ``machine`` is removed a third of the way in."""
    return TraceSpec(
        name="failure",
        n_windows=n_windows,
        base_rate=rate,
        events=(machine_removal(machine, start=n_windows // 3),),
    )
