"""Declarative workload traces for the streaming runtime.

A ``TraceSpec`` describes a time-varying workload as a base offered rate
plus a tuple of composable *events* — rate ramps, bursts, sinusoidal
drift, multiplicative noise, machine slowdown/removal — applied in order.
``TraceSpec.compile(cluster, seed)`` lowers the spec to a ``CompiledTrace``:
two dense arrays, the per-window offered spout rate ``rates`` (W,) and the
per-window machine capacity grid ``capacity`` (W, m). Everything stochastic
(burst jitter, rate noise) is drawn from ``np.random.default_rng(seed)``
during compilation, so a compiled trace is a pure value: the executor and
the JAX evaluator consume the same arrays, and repeated runs are
bit-identical by construction.

This mirrors the paper's §6.3 measurement protocol — "gradually increase
the input rate until the cluster saturates" — as the ``rate_ramp`` event,
and extends it with the drift/failure scenarios evaluated by the online
controller (see docs/paper_map.md).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import UserGraph
from repro.core.profiles import Cluster

__all__ = [
    "TraceSpec",
    "CompiledTrace",
    "KeyRealization",
    "KeyedEdgeTrace",
    "rate_ramp",
    "rate_burst",
    "rate_sine",
    "rate_noise",
    "machine_slowdown",
    "machine_removal",
    "machine_addition",
    "key_skew_shift",
    "ramp_trace",
    "burst_trace",
    "sine_trace",
    "slowdown_trace",
    "failure_trace",
    "skew_shift_trace",
    "elastic_trace",
]

# Child-stream tag for key realizations: keyed randomness draws from
# ``default_rng([seed, _KEY_STREAM])``, a stream independent of the rate /
# capacity event rng, so compiling the same spec with and without a keyed
# topology yields bit-identical rate and capacity arrays.
_KEY_STREAM = 0x6B6579  # "key"


def zipf_weights(n_keys: int, zipf_s: float) -> np.ndarray:
    """(K,) normalized Zipf key masses: ``p_k ∝ (k + 1) ** -zipf_s``."""
    w = (np.arange(1, n_keys + 1, dtype=np.float64)) ** (-float(zipf_s))
    return w / w.sum()


@dataclasses.dataclass(frozen=True)
class KeyRealization:
    """One drawn key population for a fields-grouped edge.

    ``weights[k]`` is key k's share of the edge's tuples (Zipf over the
    grouping's key space); ``hashes[k]`` is its drawn hash value. Key k is
    pinned to instance ``hashes[k] % n`` of the downstream component — the
    deterministic hash→instance map the executor, the cost model and the
    JAX evaluator all share, so routing is a pure function of (realization,
    instance count).
    """

    edge: tuple[int, int]
    weights: np.ndarray  # (K,) non-negative, sums to 1
    hashes: np.ndarray   # (K,) int64 hash values >= 0

    def __post_init__(self) -> None:
        object.__setattr__(self, "edge", (int(self.edge[0]), int(self.edge[1])))
        object.__setattr__(
            self, "weights", np.asarray(self.weights, dtype=np.float64)
        )
        object.__setattr__(self, "hashes", np.asarray(self.hashes, dtype=np.int64))
        if self.weights.ndim != 1 or self.weights.shape != self.hashes.shape:
            raise ValueError("weights and hashes must be aligned 1-D arrays")
        if self.weights.size == 0 or np.any(self.weights < 0.0):
            raise ValueError("key weights must be non-empty and non-negative")
        if np.any(self.hashes < 0):
            raise ValueError("hash values must be non-negative")
        object.__setattr__(self, "_share_cache", {})

    def shares(self, n_instances: int) -> np.ndarray:
        """(n,) fraction of the edge's tuples landing on each downstream
        instance when the component runs ``n_instances`` instances."""
        n = int(n_instances)
        if n < 1:
            raise ValueError("need >= 1 downstream instance")
        cached = self._share_cache.get(n)
        if cached is None:
            cached = np.bincount(
                self.hashes % n, weights=self.weights, minlength=n
            )
            self._share_cache[n] = cached
        return cached

    @staticmethod
    def draw(
        edge: tuple[int, int], n_keys: int, zipf_s: float, rng: np.random.Generator
    ) -> "KeyRealization":
        """Draw a realization: Zipf weights + uniform random hash values
        (which instance a hot key lands on is seed-determined)."""
        return KeyRealization(
            edge=edge,
            weights=zipf_weights(n_keys, zipf_s),
            hashes=rng.integers(0, np.iinfo(np.int64).max, size=n_keys),
        )


@dataclasses.dataclass(frozen=True)
class KeyedEdgeTrace:
    """Per-window key routing of one fields edge: ordered realization
    segments ``(start_window, realization)``; segment i is active on
    windows ``[start_i, start_{i+1})``. Segment 0 always starts at 0."""

    edge: tuple[int, int]
    segments: tuple[tuple[int, KeyRealization], ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "edge", (int(self.edge[0]), int(self.edge[1])))
        object.__setattr__(
            self,
            "segments",
            tuple((int(s), r) for s, r in self.segments),
        )
        if not self.segments or self.segments[0][0] != 0:
            raise ValueError("keyed edge needs a realization from window 0")
        starts = [s for s, _ in self.segments]
        if starts != sorted(starts):
            raise ValueError("segments must be ordered by start window")

    def segment_index(self, window: int) -> int:
        return int(self.segment_indices(window + 1)[window])

    def segment_indices(self, n_windows: int) -> np.ndarray:
        """(W,) active-segment index per window — the single owner of the
        start-inclusive boundary rule; the executor's per-window routing
        and the JAX evaluator's share grids both expand through it, so
        their bit-parity cannot drift."""
        starts = np.array([s for s, _ in self.segments], dtype=np.int64)
        return np.searchsorted(starts, np.arange(n_windows), side="right") - 1

    def realization_at(self, window: int) -> KeyRealization:
        return self.segments[self.segment_index(window)][1]


@dataclasses.dataclass(frozen=True)
class CompiledTrace:
    """Dense per-window arrays of one workload scenario.

    Attributes:
      name: scenario name (from the spec).
      window_s: window length in seconds (the event-loop dt).
      rates: (W,) offered topology input rate per window (tuples/s at each
        spout, the paper's R0 as a function of time).
      capacity: (W, m) per-machine CPU capacity per window; 0.0 = removed.
      events: (window, description) markers for capacity changes, for
        event logs and plots.
      seed: the seed the stochastic events were drawn with.
      keyed: per-window key routing for every fields-grouped edge of the
        topology the trace was compiled against (empty when compiled
        without a ``utg`` or for an all-shuffle topology).
    """

    name: str
    window_s: float
    rates: np.ndarray
    capacity: np.ndarray
    events: tuple[tuple[int, str], ...]
    seed: int
    keyed: tuple[KeyedEdgeTrace, ...] = ()

    @property
    def n_windows(self) -> int:
        return int(self.rates.shape[0])

    @property
    def n_machines(self) -> int:
        return int(self.capacity.shape[1])

    def skew_epoch(self, window: int) -> int:
        """Monotone counter that bumps whenever any keyed edge's active
        realization changes (a ``key_skew_shift`` boundary crossed)."""
        return sum(kt.segment_index(window) for kt in self.keyed)

    def realizations_at(self, window: int) -> dict[tuple[int, int], KeyRealization]:
        """Active realization per fields edge at ``window``."""
        return {kt.edge: kt.realization_at(window) for kt in self.keyed}


# ------------------------------------------------------------------ events


@dataclasses.dataclass(frozen=True)
class rate_ramp:
    """Linear rate ramp from the curve's value at ``start`` to ``to_rate``
    over [start, end); windows >= end hold ``to_rate`` (the paper's gradual
    rate increase protocol)."""

    to_rate: float
    start: int = 0
    end: int | None = None

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = rates.shape[0]
        end = W if self.end is None else min(self.end, W)
        if end > self.start:
            span = end - self.start
            rates[self.start : end] = np.linspace(
                rates[self.start], self.to_rate, span
            )
            rates[end:] = self.to_rate
        return []


@dataclasses.dataclass(frozen=True)
class rate_burst:
    """Multiplicative bursts: every ``every`` windows from ``start``, the
    rate is multiplied by ``factor`` for ``width`` windows. ``jitter``
    shifts each burst start by a seeded uniform integer in [-jitter, jitter]."""

    factor: float = 3.0
    every: int = 40
    width: int = 5
    start: int = 0
    jitter: int = 0

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = rates.shape[0]
        for s in range(self.start, W, self.every):
            if self.jitter:
                s += int(rng.integers(-self.jitter, self.jitter + 1))
            lo, hi = max(s, 0), min(max(s, 0) + self.width, W)
            rates[lo:hi] *= self.factor
        return []


@dataclasses.dataclass(frozen=True)
class rate_sine:
    """Sinusoidal drift: ``rate *= 1 + amplitude * sin(2*pi*(t-start)/period)``
    for windows t >= start (clipped at zero)."""

    amplitude: float = 0.5
    period: int = 60
    start: int = 0

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = rates.shape[0]
        t = np.arange(W - self.start, dtype=np.float64)
        wave = 1.0 + self.amplitude * np.sin(2.0 * np.pi * t / self.period)
        rates[self.start :] *= np.clip(wave, 0.0, None)
        return []


@dataclasses.dataclass(frozen=True)
class rate_noise:
    """Seeded multiplicative log-normal rate noise (sigma = ``scale``)."""

    scale: float = 0.05

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        rates *= np.exp(rng.normal(0.0, self.scale, size=rates.shape))
        return []


@dataclasses.dataclass(frozen=True)
class machine_slowdown:
    """Machine ``machine`` runs at ``factor`` of its capacity in
    [start, end) (end=None -> until the trace ends)."""

    machine: int
    factor: float = 0.5
    start: int = 0
    end: int | None = None

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = capacity.shape[0]
        end = W if self.end is None else min(self.end, W)
        capacity[self.start : end, self.machine] *= self.factor
        return [
            (self.start, f"slowdown m{self.machine} x{self.factor}"),
            *([(end, f"recover m{self.machine}")] if end < W else []),
        ]


@dataclasses.dataclass(frozen=True)
class machine_removal:
    """Machine ``machine`` is removed (capacity 0) in [start, end)."""

    machine: int
    start: int = 0
    end: int | None = None

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = capacity.shape[0]
        end = W if self.end is None else min(self.end, W)
        capacity[self.start : end, self.machine] = 0.0
        return [
            (self.start, f"remove m{self.machine}"),
            *([(end, f"restore m{self.machine}")] if end < W else []),
        ]


@dataclasses.dataclass(frozen=True)
class machine_addition:
    """Machine ``machine`` joins the cluster at ``start`` (cloud scale-out).

    The cluster passed to ``TraceSpec.compile`` is the *fleet* — every
    machine that could ever serve, provisioned or not. An added machine's
    capacity column is 0 before ``start`` (the dense grid gains a column
    that switches on mid-trace) and its nominal capacity — or the
    ``capacity`` override — on [start, end). ``end`` models a leased
    machine returned to the provider. Pair with
    ``RuntimeConfig(capacity_notice=...)`` so controllers can also *drain*
    ahead of the lease expiring instead of losing the instances with it.
    """

    machine: int
    start: int
    end: int | None = None
    capacity: float | None = None

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        W = capacity.shape[0]
        end = W if self.end is None else min(self.end, W)
        val = (
            float(self.capacity)
            if self.capacity is not None
            else float(capacity[min(self.start, W - 1), self.machine])
        )
        capacity[: self.start, self.machine] = 0.0
        capacity[self.start : end, self.machine] = val
        capacity[end:, self.machine] = 0.0
        return [
            (self.start, f"add m{self.machine}"),
            *([(end, f"remove m{self.machine}")] if end < W else []),
        ]


@dataclasses.dataclass(frozen=True)
class key_skew_shift:
    """Re-draw the key population of fields-grouped edges at ``start``.

    Models key-distribution drift in keyed streams: the hot keys move (new
    seeded hash draw) and optionally the skew exponent changes
    (``zipf_s``). ``edge=None`` shifts every fields edge. Requires the
    trace to be compiled against a keyed topology
    (``TraceSpec.compile(..., utg=...)``).
    """

    start: int
    edge: tuple[int, int] | None = None
    zipf_s: float | None = None

    def apply(self, rates: np.ndarray, capacity: np.ndarray, rng) -> list:
        # Rate/capacity are untouched; the keyed pass in ``compile``
        # consumes this event (and emits its markers) separately.
        return []


# -------------------------------------------------------------------- spec


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """A workload scenario: base rate + ordered composable events.

    ``n_windows`` fixed-length windows of ``window_s`` seconds each; the
    offered rate starts flat at ``base_rate`` and each event transforms the
    rate curve and/or the capacity grid in declaration order.
    """

    name: str
    n_windows: int
    base_rate: float
    events: tuple = ()
    window_s: float = 1.0

    def compile(
        self, cluster: Cluster, seed: int = 0, utg: UserGraph | None = None
    ) -> CompiledTrace:
        """Lower to dense (W,) rate and (W, m) capacity arrays.

        All randomness (burst jitter, noise, key populations) is drawn here
        from ``default_rng(seed)`` — the compiled trace is a pure value and
        every consumer of it is deterministic. ``utg`` supplies the
        fields-grouped edges whose key realizations the trace must carry;
        keyed randomness draws from an independent child stream, so the
        rate/capacity arrays are bit-identical with or without it.
        """
        if self.n_windows < 1:
            raise ValueError("trace needs at least one window")
        rng = np.random.default_rng(seed)
        rates = np.full(self.n_windows, float(self.base_rate), dtype=np.float64)
        capacity = np.tile(cluster.capacity, (self.n_windows, 1)).astype(np.float64)
        markers: list[tuple[int, str]] = []
        for ev in self.events:
            markers.extend(ev.apply(rates, capacity, rng))
        keyed, key_markers = self._compile_keyed(utg, seed)
        markers.extend(key_markers)
        np.clip(rates, 0.0, None, out=rates)
        np.clip(capacity, 0.0, None, out=capacity)
        return CompiledTrace(
            name=self.name,
            window_s=float(self.window_s),
            rates=rates,
            capacity=capacity,
            events=tuple(sorted(markers)),
            seed=seed,
            keyed=keyed,
        )

    def _compile_keyed(
        self, utg: UserGraph | None, seed: int
    ) -> tuple[tuple[KeyedEdgeTrace, ...], list[tuple[int, str]]]:
        """Draw every fields edge's key realization segments.

        Draw order is deterministic: one initial realization per grouping
        (declaration order), then one re-draw per (shift event, matched
        edge) in declaration order — so the initial population for a given
        (utg, seed) is identical across specs regardless of their events.
        """
        shifts = [ev for ev in self.events if isinstance(ev, key_skew_shift)]
        groupings = () if utg is None else utg.groupings
        if not groupings:
            if shifts:
                raise ValueError(
                    "key_skew_shift requires a keyed topology "
                    "(compile with utg=... and fields groupings)"
                )
            return (), []
        rng = np.random.default_rng(np.random.SeedSequence([seed, _KEY_STREAM]))
        segments: dict[tuple[int, int], list[tuple[int, KeyRealization]]] = {}
        exponent: dict[tuple[int, int], float] = {}
        for g in groupings:
            segments[g.edge] = [(0, KeyRealization.draw(g.edge, g.n_keys, g.zipf_s, rng))]
            exponent[g.edge] = g.zipf_s
        markers: list[tuple[int, str]] = []
        by_edge = {g.edge: g for g in groupings}
        for ev in shifts:
            edges = list(by_edge) if ev.edge is None else [tuple(ev.edge)]
            for edge in edges:
                if edge not in by_edge:
                    raise ValueError(f"key_skew_shift on non-fields edge {edge}")
                s = exponent[edge] if ev.zipf_s is None else float(ev.zipf_s)
                exponent[edge] = s
                real = KeyRealization.draw(edge, by_edge[edge].n_keys, s, rng)
                if 0 <= ev.start < self.n_windows:
                    segments[edge].append((int(ev.start), real))
                    markers.append(
                        (int(ev.start), f"key_skew_shift e{edge[0]}->{edge[1]} s={s:g}")
                    )
        keyed = tuple(
            KeyedEdgeTrace(
                edge=g.edge,
                segments=tuple(sorted(segments[g.edge], key=lambda t: t[0])),
            )
            for g in groupings
        )
        return keyed, markers


# ------------------------------------------------------- stock scenarios


def ramp_trace(
    lo_rate: float, hi_rate: float, n_windows: int = 240, hold: int = 20
) -> TraceSpec:
    """The paper's gradual rate-ramp protocol: hold ``lo_rate`` for
    ``hold`` windows, ramp linearly to ``hi_rate``, then hold."""
    return TraceSpec(
        name="ramp",
        n_windows=n_windows,
        base_rate=lo_rate,
        events=(rate_ramp(hi_rate, start=hold, end=n_windows - hold),),
    )


def burst_trace(
    base_rate: float,
    factor: float = 3.0,
    n_windows: int = 240,
    every: int = 48,
    width: int = 8,
    jitter: int = 3,
) -> TraceSpec:
    """Periodic rate bursts with seeded start jitter."""
    return TraceSpec(
        name="burst",
        n_windows=n_windows,
        base_rate=base_rate,
        events=(rate_burst(factor, every=every, width=width, start=16, jitter=jitter),),
    )


def sine_trace(
    mean_rate: float, amplitude: float = 0.5, n_windows: int = 240, period: int = 80
) -> TraceSpec:
    """Sinusoidal diurnal-style drift around ``mean_rate``."""
    return TraceSpec(
        name="sine",
        n_windows=n_windows,
        base_rate=mean_rate,
        events=(rate_sine(amplitude, period=period),),
    )


def slowdown_trace(
    rate: float, machine: int, factor: float = 0.5, n_windows: int = 240
) -> TraceSpec:
    """Constant rate; ``machine`` slows to ``factor`` capacity a third of
    the way in (resource churn without failure)."""
    return TraceSpec(
        name="slowdown",
        n_windows=n_windows,
        base_rate=rate,
        events=(machine_slowdown(machine, factor, start=n_windows // 3),),
    )


def failure_trace(rate: float, machine: int, n_windows: int = 240) -> TraceSpec:
    """Constant rate; ``machine`` is removed a third of the way in."""
    return TraceSpec(
        name="failure",
        n_windows=n_windows,
        base_rate=rate,
        events=(machine_removal(machine, start=n_windows // 3),),
    )


def elastic_trace(
    lo_rate: float,
    hi_rate: float,
    machine: int,
    n_windows: int = 240,
    join: int | None = None,
) -> TraceSpec:
    """Cloud scale-out: the offered rate ramps past what the initial
    machines sustain, and spare ``machine`` joins a third of the way in
    (default) — only a controller that grows onto the new column rides the
    ramp; a frozen schedule saturates at the old fleet's bound."""
    join = n_windows // 3 if join is None else join
    return TraceSpec(
        name="elastic",
        n_windows=n_windows,
        base_rate=lo_rate,
        events=(
            rate_ramp(hi_rate, start=20, end=n_windows - 40),
            machine_addition(machine, start=join),
        ),
    )


def skew_shift_trace(
    rate: float, n_windows: int = 240, zipf_s: float | None = None
) -> TraceSpec:
    """Constant rate on a keyed topology; the key population of every
    fields edge re-draws a third of the way in (hot keys move, optionally
    to a new skew exponent) — rate and capacity never change, so only a
    skew-aware controller sees the drift."""
    return TraceSpec(
        name="skew_shift",
        n_windows=n_windows,
        base_rate=rate,
        events=(key_skew_shift(start=n_windows // 3, zipf_s=zipf_s),),
    )
