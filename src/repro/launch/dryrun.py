import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against the production mesh with 512 placeholder host devices, and
extract the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Per cell this prints/records:
  * memory_analysis()  — per-device bytes (proves the cell fits 16 GB HBM)
  * cost_analysis()    — HLO FLOPs / bytes for the roofline terms
  * collective bytes   — parsed from the partitioned HLO text, summed over
                         all-gather / all-reduce / reduce-scatter /
                         all-to-all / collective-permute result shapes
  * the three roofline terms (seconds) + dominant bottleneck
  * MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and its ratio to HLO FLOPs
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_shape
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.dist import partition
from repro.optim import adamw
from repro.roofline import (
    TPU_V5E_CONSTANTS,
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
)

SKIP_LONG = "long_500k requires sub-quadratic attention; skipped for pure full-attention archs (DESIGN.md)"


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    print_analysis: bool = True,
    overrides: dict | None = None,
) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    if shape_name == "long_500k" and not cfg.is_sub_quadratic:
        return {"arch": arch, "shape": shape_name, "skipped": SKIP_LONG}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if shape.kind == "train":
        lowered = _lower_train(cfg, shape, mesh)
    else:
        lowered = _lower_serve(cfg, shape, mesh)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    coll = collective_bytes_from_hlo(compiled.as_text())

    n_chips = int(np.prod(list(mesh.shape.values())))
    # Loop-aware matmul FLOPs from the HLO analyzer (cost_analysis() visits
    # while bodies once and would undercount a scanned model by ~n_layers x).
    flops_per_dev = float(coll["matmul_flops"])
    mem_d = _mem_dict(mem)
    # HBM traffic floor: args read + outputs written + temps written & read.
    bytes_per_dev = float(
        mem_d.get("argument_size_in_bytes", 0)
        + mem_d.get("output_size_in_bytes", 0)
        + 2 * mem_d.get("temp_size_in_bytes", 0)
    )
    terms = roofline_terms(
        flops_per_dev=flops_per_dev,
        bytes_per_dev=bytes_per_dev,
        coll_bytes_per_dev=coll["total"],
    )
    mf = model_flops(cfg, shape)
    hlo_total = flops_per_dev * n_chips

    result = {
        "arch": arch,
        "shape": shape_name,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_d,
        "flops_per_device": flops_per_dev,
        "bytes_per_device": bytes_per_dev,
        "touched_bytes_per_device": float(coll["touched_bytes"]),
        "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "collective_bytes_per_device": coll["total"],
        "collectives": coll["by_kind"],
        "collective_counts": coll["counts"],
        "terms_s": terms,
        "dominant": max(terms, key=terms.get),
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_total) if hlo_total else 0.0,
    }
    if print_analysis:
        print(f"== {arch} x {shape_name} on {result['mesh']} ==")
        print(mem)
        print({k: v for k, v in cost.items() if k in ("flops", "bytes accessed")})
        print(json.dumps({k: result[k] for k in
                          ("terms_s", "dominant", "useful_flops_ratio",
                           "collective_bytes_per_device")}, indent=2))
    return result


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def _lower_train(cfg: ModelConfig, shape: ShapeConfig, mesh):
    opt_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    train_step = steps_lib.make_train_step(cfg, opt_cfg, mesh)

    state_abs = steps_lib.abstract_train_state(cfg, opt_cfg)
    batch_abs = steps_lib.input_specs(cfg, shape)

    pspecs = partition.param_specs(state_abs["params"], mesh, cfg)
    state_specs = {
        "params": pspecs,
        "opt": {
            "m": pspecs,
            "v": pspecs,
            "step": jax.sharding.PartitionSpec(),
        },
    }
    bspecs = partition.batch_specs(batch_abs, mesh, cfg)
    in_shardings = (
        partition.shardings(state_specs, mesh),
        partition.shardings(bspecs, mesh),
    )
    out_shardings = (in_shardings[0], None)

    jitted = jax.jit(
        train_step,
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        donate_argnums=(0,),
    )
    with mesh:
        return jitted.lower(state_abs, batch_abs)


def _lower_serve(cfg: ModelConfig, shape: ShapeConfig, mesh):
    serve_step = steps_lib.make_serve_step(
        cfg, mesh, kind="decode" if shape.is_decode else "prefill"
    )
    params_abs = steps_lib.abstract_params(cfg)
    batch_abs = steps_lib.input_specs(cfg, shape)
    caches_abs = steps_lib.abstract_caches(cfg, shape)

    pspecs = partition.param_specs(params_abs, mesh, cfg)
    bspecs = partition.batch_specs(batch_abs, mesh, cfg)
    cspecs = partition.cache_specs(caches_abs, mesh, cfg)
    in_shardings = (
        partition.shardings(pspecs, mesh),
        partition.shardings(bspecs, mesh),
        partition.shardings(cspecs, mesh),
    )
    out_shardings = (None, in_shardings[2])

    jitted = jax.jit(
        serve_step,
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        donate_argnums=(2,),
    )
    with mesh:
        return jitted.lower(params_abs, batch_abs, caches_abs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel activations (hillclimb config)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    overrides = {"sequence_parallel": True} if args.sp else None

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch}_{shape_name}_{'multi' if mp else 'single'}"
            if args.sp:
                tag += "_sp"
            path = out_dir / f"{tag}.json"
            if path.exists():
                print(f"-- {tag}: cached")
                continue
            try:
                res = run_cell(arch, shape_name, multi_pod=mp, overrides=overrides)
            except Exception as e:
                traceback.print_exc()
                failures.append(tag)
                res = {"arch": arch, "shape": shape_name,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": f"{type(e).__name__}: {e}"}
            path.write_text(json.dumps(res, indent=2, default=float))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
