import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Rank the collectives of one dry-run cell by total per-device bytes
(trip-count aware) — the profiling tool behind the §Perf iterations.

  PYTHONPATH=src python -m repro.launch.rank_collectives --arch X --shape Y [--sp]
"""

import argparse
import dataclasses
import re

import numpy as np

from repro.configs import get_config, get_shape
from repro.hlo_analysis import _parse_computations, _shape_bytes
from repro.launch.dryrun import _lower_serve, _lower_train
from repro.launch.mesh import make_production_mesh


def rank(arch: str, shape_name: str, overrides=None, top: int = 18):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=False)
    lowered = (
        _lower_train(cfg, shape, mesh)
        if shape.kind == "train"
        else _lower_serve(cfg, shape, mesh)
    )
    txt = lowered.compile().as_text()
    comps, entry = _parse_computations(txt)

    # computation -> execution multiplier (while trip counts)
    mult: dict[str, float] = {}

    def calls_of(comp):
        out = []
        for ins in comp.instrs:
            if ins.op == "while":
                m = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                c = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                t = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.rest)
                trips = int(t.group(1)) if t else 1
                if m:
                    out.append((m.group(1), trips))
                if c:
                    out.append((c.group(1), trips))
            else:
                for m in re.finditer(
                    r"(?:calls|to_apply|update_computation|comparator)=%?([\w\.\-]+)",
                    ins.rest,
                ):
                    out.append((m.group(1), 1))
        return out

    queue = [(entry, 1.0)]
    while queue:
        name, w = queue.pop()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0.0) + w
        for child, trips in calls_of(comps[name]):
            queue.append((child, w * trips))

    kinds = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
    items = []
    for cname, comp in comps.items():
        w = mult.get(cname, 0.0)
        if w == 0:
            continue
        for ins in comp.instrs:
            for k in kinds:
                if ins.op == k or ins.op == k + "-start":
                    b = _shape_bytes(ins.shape) * w
                    if ins.op.endswith("-start") and ins.shape.startswith("("):
                        b /= 2
                    m = re.search(r'op_name="([^"]+)"', ins.rest)
                    items.append((b, k, w, (m.group(1) if m else cname)))
    items.sort(reverse=True)
    total = sum(i[0] for i in items)
    print(f"TOTAL {total/1e9:.1f} GB/device/step across {len(items)} collective sites")
    for b, k, w, name in items[:top]:
        print(f"{b/1e9:8.2f}GB {k:16s} x{w:<4.0f} {name[:110]}")
    return items


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--sp", action="store_true")
    args = ap.parse_args()
    rank(args.arch, args.shape,
         overrides={"sequence_parallel": True} if args.sp else None)


if __name__ == "__main__":
    main()
