"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches JAX device state — required because the
dry-run must set XLA_FLAGS before any device query.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: ``data`` carries batch/FSDP, ``model`` carries TP/EP, ``pod``
    extends data parallelism hierarchically across pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Tiny host-device mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))
