"""Jitted train/serve step builders + abstract input specs for every
(architecture x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, zero allocation) for every model input, keyed exactly
like the runtime batch dicts. ``abstract_state`` does the same for params /
optimizer state / caches, so the dry-run lowers the full training state
without materializing a single byte.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist import partition
from repro.models import model as M
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.layers import MeshCtx
from repro.optim import adamw

__all__ = [
    "input_specs",
    "abstract_params",
    "abstract_train_state",
    "abstract_caches",
    "make_train_step",
    "make_serve_step",
    "mesh_ctx",
]


def mesh_ctx(mesh: jax.sharding.Mesh | None, cfg: ModelConfig) -> MeshCtx:
    if mesh is None:
        return MeshCtx(mesh=None)
    data_axes, tp = partition.mesh_axes(mesh, cfg)
    return MeshCtx(mesh=mesh, data_axes=data_axes, tp_axis=tp,
                   seq_sharded=cfg.sequence_parallel)


# ---------------------------------------------------------------------------
# Abstract inputs / state
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract batch for one cell.

    train/prefill: the full sequence. decode: one new token + pos0 (the
    caches hold seq_len history — see ``abstract_caches``).
    """
    B = shape.global_batch
    S = 1 if shape.is_decode else shape.seq_len
    act_dt = cfg.dtype
    batch: dict[str, Any] = {}

    if cfg.embedding_inputs:
        batch["embeds"] = _sds((B, S, cfg.d_model), act_dt)
        if shape.kind == "train":
            batch["labels"] = _sds((B, S), "int32")
    else:
        batch["tokens"] = _sds((B, S), "int32")
        if shape.kind == "train":
            batch["labels"] = _sds((B, S), "int32")

    if cfg.mrope_sections:
        batch["mrope_positions"] = _sds((3, B, S), "int32")

    if cfg.is_encoder_decoder:
        if shape.is_decode:
            # Encoder ran at prefill; decode consumes its cached output.
            batch["encoder_out"] = _sds((B, cfg.encoder_seq, cfg.d_model), act_dt)
        else:
            batch["encoder_embeds"] = _sds((B, cfg.encoder_seq, cfg.d_model), act_dt)

    if shape.is_decode:
        batch["pos0"] = _sds((), "int32")
    return batch


def abstract_params(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def abstract_train_state(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig) -> dict:
    params = abstract_params(cfg)
    opt = jax.eval_shape(lambda: adamw.init_opt_state(params, opt_cfg))
    return {"params": params, "opt": opt}


def abstract_caches(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    return jax.eval_shape(
        lambda: M.init_caches(
            cfg, shape.global_batch, shape.seq_len, jnp.dtype(cfg.dtype)
        )
    )


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    ctx = mesh_ctx(mesh, cfg)

    def train_step(state, batch):
        def loss(params):
            return M.loss_fn(params, cfg, ctx, batch)

        loss_val, grads = jax.value_and_grad(loss)(state["params"])
        new_params, new_opt, metrics = adamw.adamw_update(
            state["params"], grads, state["opt"], opt_cfg
        )
        metrics = dict(metrics, loss=loss_val)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, mesh=None, kind: str = "decode"):
    """decode: one-token step against caches. prefill: fill caches from a
    full prompt. Returns serve_step(params, batch, caches) -> (logits, caches).
    """
    ctx = mesh_ctx(mesh, cfg)

    if kind == "decode":
        def serve_step(params, batch, caches):
            return M.decode_step(params, cfg, ctx, batch, caches)
    else:
        def serve_step(params, batch, caches):
            return M.prefill(params, cfg, ctx, batch, caches)

    return serve_step


def make_prefill_step(cfg: ModelConfig, mesh=None):
    return make_serve_step(cfg, mesh, kind="prefill")
