"""Sharded, atomic, async checkpointing.

Layout: ``<dir>/step_<N>/`` holding one ``shard_<k>.npz`` per host (flat
key -> array) plus ``manifest.json`` (tree structure, dtypes, step,
timestamp). Writes go to ``step_<N>.tmp`` and are renamed into place only
after every shard and the manifest are fsynced — a preempted writer never
corrupts the latest checkpoint (restart-safety requirement at 1000-node
scale, where some host is always mid-write).

``AsyncCheckpointer`` moves serialization off the training thread: `save`
enqueues a host-transferred snapshot; a worker thread persists it. A bounded
queue (depth 1) applies back-pressure instead of accumulating snapshots in
RAM.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer", "retain"]

_SEP = "::"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    """Flatten to npz-safe arrays. Dtypes numpy can't serialize natively
    (bf16, fp8) are stored as raw-bit views; the original dtype is recorded
    in a parallel ``<key>::dtype`` entry and restored on load."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            flat[key + "::dtype"] = np.str_(arr.dtype.name)
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save(ckpt_dir: str | os.PathLike, step: int, tree: Any, extra: dict | None = None) -> Path:
    """Synchronous atomic save. Returns the final directory path."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    np.savez(tmp / "shard_0.npz", **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "time": time.time(),
        "treedef": str(treedef),
        "keys": sorted(flat.keys()),
        "extra": extra or {},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        m = re.fullmatch(r"step_(\d+)", d.name)
        if m and (d / "manifest.json").exists():
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str | os.PathLike, tree_like: Any, step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``. Returns (tree, step)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / "shard_0.npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in paths:
        key = _SEP.join(_path_str(p) for p in path)
        arr = data[key]
        if key + "::dtype" in data:
            import ml_dtypes  # jax dependency; provides bf16/fp8 numpy dtypes

            orig = np.dtype(getattr(ml_dtypes, str(data[key + "::dtype"])))
            arr = arr.view(orig)
        if hasattr(like, "dtype") and arr.dtype != like.dtype:
            arr = arr.astype(like.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def retain(ckpt_dir: str | os.PathLike, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` checkpoints."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted(
        int(m.group(1))
        for d in ckpt_dir.iterdir()
        if (m := re.fullmatch(r"step_(\d+)", d.name))
    )
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)


class AsyncCheckpointer:
    """Background checkpoint writer with bounded queue back-pressure."""

    def __init__(self, ckpt_dir: str | os.PathLike, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                save(self.ckpt_dir, step, tree, extra)
                retain(self.ckpt_dir, self.keep)
            except Exception as e:  # surfaced on next save()/close()
                self._err = e

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        if self._err:
            raise self._err
        # Snapshot to host memory before enqueueing (device buffers may be
        # donated by the next step).
        host_tree = jax.tree.map(np.asarray, tree)
        self._q.put((step, host_tree, extra))

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise self._err
