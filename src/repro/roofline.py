"""Roofline-term extraction: HLO analysis, hardware constants, model-FLOPs
accounting.

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Methodology notes (see EXPERIMENTS.md §Roofline):

* FLOPs and collective bytes come from ``repro.hlo_analysis.analyze_hlo``
  over the partitioned per-device HLO — NOT from ``cost_analysis()``,
  which visits while-loop bodies once and so undercounts a scanned L-layer
  model by ~L× (verified; see tests/test_roofline.py).
* The memory term uses the live-buffer traffic floor
  ``args + outputs + 2·temps`` from ``memory_analysis()`` — params are read
  once, outputs written once, temporaries written+read. The analyzer's
  "touched bytes" (every instruction's result, pre-fusion) is recorded as
  an upper bound.
"""

from __future__ import annotations

import re
from typing import Any

import numpy as np

__all__ = [
    "TPU_V5E_CONSTANTS",
    "collective_bytes_from_hlo",
    "roofline_terms",
    "model_flops",
    "param_counts",
]

TPU_V5E_CONSTANTS = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
}


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Collective payload bytes per device (loop-trip-count aware)."""
    from repro.hlo_analysis import analyze_hlo

    c = analyze_hlo(hlo_text)
    return {
        "total": c.collective_bytes,
        "by_kind": c.by_kind,
        "counts": c.collective_counts,
        "matmul_flops": c.matmul_flops,
        "touched_bytes": c.touched_bytes,
    }


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float,
    constants: dict = TPU_V5E_CONSTANTS,
) -> dict:
    """The three per-step roofline terms, in seconds (per chip)."""
    return {
        "compute": flops_per_dev / constants["peak_flops"],
        "memory": bytes_per_dev / constants["hbm_bw"],
        "collective": coll_bytes_per_dev / constants["ici_bw"],
    }


def param_counts(cfg) -> dict:
    """Analytic parameter counts: total and active-per-token."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    embed = cfg.vocab_size * d
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * d

    per_layer_total = 0.0
    per_layer_active = 0.0
    for i, kind in enumerate(cfg.resolved_block_pattern):
        if kind in ("attn", "local_attn"):
            if cfg.use_mla:
                a = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads *
                     (cfg.qk_nope_dim + cfg.qk_rope_dim)
                     + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                     + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                     + cfg.n_heads * cfg.v_head_dim * d)
            else:
                a = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
                    + cfg.n_heads * hd * d
            moe_layer = cfg.is_moe and i >= cfg.n_dense_layers
            if moe_layer:
                expert = 3 * d * cfg.moe_d_ff
                total_ffn = cfg.n_experts * expert + d * cfg.n_experts  # + router
                active_ffn = cfg.top_k * expert
                if cfg.n_shared_experts:
                    shared = 3 * d * cfg.moe_d_ff * cfg.n_shared_experts
                    total_ffn += shared
                    active_ffn += shared
            else:
                total_ffn = active_ffn = 3 * d * cfg.d_ff
            per_layer_total += a + total_ffn
            per_layer_active += a + active_ffn
        elif kind == "rglru":
            w = cfg.lru_width or d
            a = 2 * d * w + 2 * w * w + w * d + cfg.conv_width * w
            ffn = 3 * d * cfg.d_ff if cfg.d_ff else 0
            per_layer_total += a + ffn
            per_layer_active += a + ffn
        elif kind == "mlstm":
            du = 2 * d
            a = 2 * d * du + 3 * du * du + du * 2 * cfg.n_heads + du * d
            per_layer_total += a
            per_layer_active += a
        elif kind == "slstm":
            a = 6 * d * d
            per_layer_total += a
            per_layer_active += a

    enc = 0
    if cfg.is_encoder_decoder:
        enc = cfg.encoder_layers * (4 * d * cfg.n_heads * hd + 3 * d * cfg.d_ff
                                    + 4 * d * cfg.n_heads * hd)
    total = embed + head + per_layer_total + enc
    active = embed + head + per_layer_active + enc
    return {"total": total, "active": active}


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); D = tokens this step.

    Decode steps process global_batch tokens; train/prefill process
    global_batch x seq_len. Embedding params are excluded from N per the
    usual convention (table lookups are not matmul FLOPs).
    """
    counts = param_counts(cfg)
    n_active = counts["active"] - cfg.vocab_size * cfg.d_model * (
        1 if cfg.tie_embeddings else 2
    )
    # keep the lm-head matmul (it is real compute): add back one head's worth
    n_active += cfg.vocab_size * cfg.d_model
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * max(n_active, 0) * tokens
