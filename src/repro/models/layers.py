"""Common layers: norms, rotary embeddings (RoPE + M-RoPE), MLP, embeddings,
and the sharding-constraint helper threaded through every model.

All layers are pure functions ``apply(params, x, ...)`` with a matching
``init(key, cfg) -> params`` builder. Parameter trees are plain dicts so the
partitioner (repro.dist.partition) can assign PartitionSpecs by path name.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = [
    "MeshCtx",
    "rms_norm",
    "layer_norm",
    "rope",
    "apply_rope",
    "apply_mrope",
    "init_dense",
    "dense",
    "init_mlp",
    "mlp",
    "init_embedding",
    "embed_tokens",
]


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Mesh context threaded through model code for activation sharding.

    ``data_axes`` shard the batch dimension (("pod","data") on the multi-pod
    mesh); ``tp_axis`` shards feature/head dimensions. ``None`` mesh disables
    all constraints (single-device smoke tests).
    """

    mesh: jax.sharding.Mesh | None = None
    data_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    seq_sharded: bool = False  # Megatron-style sequence parallelism between blocks

    def axis_size(self, axes) -> int:
        if self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def shard(self, x: jax.Array, *spec) -> jax.Array:
        """Constrain ``x`` to PartitionSpec(*spec); drops non-divisible axes.

        Each spec entry is None, an axis name, or a tuple of axis names. Any
        entry whose mesh size does not divide the corresponding array dim is
        replaced by None (replicated) so constraints never fail for odd head
        counts / vocab sizes.
        """
        if self.mesh is None:
            return x
        fixed = []
        for dim, entry in zip(x.shape, spec):
            if entry is None:
                fixed.append(None)
                continue
            size = self.axis_size(entry)
            fixed.append(entry if dim % size == 0 else None)
        sharding = jax.sharding.NamedSharding(self.mesh, P(*fixed))
        return jax.lax.with_sharding_constraint(x, sharding)

    def shard_tokens(self, x: jax.Array) -> jax.Array:
        """(B, S, ...) activations: batch over data axes; with sequence
        parallelism the seq dim additionally shards over the TP axis (the
        divisibility check inside ``shard`` turns this off for decode)."""
        seq = self.tp_axis if self.seq_sharded else None
        spec = [self.data_axes, seq] + [None] * (x.ndim - 2)
        return self.shard(x, *spec)

    def shard_features(self, x: jax.Array) -> jax.Array:
        """(B, S, F) activations: batch over data axes, features over TP."""
        spec = [self.data_axes] + [None] * (x.ndim - 2) + [self.tp_axis]
        return self.shard(x, *spec)

    _OUT_PROJ = ("wo", "w_down", "w_out")

    def gather_params(self, p):
        """ZeRO-3 use-site gather: constrain a layer's 2-D weights to
        TP-only sharding before compute.

        FSDP stores weights (d@data, f@model); left unconstrained, GSPMD
        often partitions the matmuls by moving *activations* over the data
        axis instead of gathering the (much smaller) weight shards —
        measured at 2.15 GB/site x 15 sites/layer on qwen2-vl train_4k.
        This constraint pins the ZeRO-3 schedule: all-gather each weight
        over the data axes at its use site (and re-gather during remat),
        exactly once per visit, leaving only Megatron-style TP collectives
        on activations. Expert tensors (3-D) are consumed fully sharded by
        the MoE shard_map and pass through untouched.
        """
        if self.mesh is None:
            return p
        fsdp = self.data_axes
        fsdp_size = self.axis_size(fsdp)
        tp_size = self.axis_size(self.tp_axis)

        def gather(w, fsdp_dim, tp_dim):
            """Explicit ZeRO-3 all-gather of one weight over the FSDP axes.

            shard_map + lax.all_gather pins the collective at the use site —
            a plain with_sharding_constraint lets GSPMD propagate the
            TP-only layout back through the scan slice to the *stacked*
            params, hoisting every layer's gather out of the loop (measured
            264 GB live on qwen2-vl). The gather's transpose is a
            reduce-scatter of the weight gradient: textbook ZeRO.
            """
            if w.shape[fsdp_dim] % fsdp_size or w.shape[tp_dim] % tp_size:
                return w
            spec = [None, None]
            spec[fsdp_dim] = fsdp
            spec[tp_dim] = self.tp_axis
            out = [None, None]
            out[tp_dim] = self.tp_axis

            def body(x):
                for a in reversed(fsdp):
                    x = jax.lax.all_gather(x, a, axis=fsdp_dim, tiled=True)
                return x

            return jax.shard_map(
                body, mesh=self.mesh,
                in_specs=P(*spec), out_specs=P(*out),
                check_vma=False,  # all_gather(tiled) does replicate over fsdp
            )(w)

        def walk(node, name=""):
            if isinstance(node, dict):
                # propagate the projection name down to its "w"/"b" leaves
                return {
                    k: walk(v, k if isinstance(v, dict) else (name or k))
                    for k, v in node.items()
                }
            if not hasattr(node, "ndim") or node.ndim != 2:
                return node
            if name == "router":
                return node  # consumed replicated inside the MoE shard_map
            if any(name == t or name.startswith(t) for t in self._OUT_PROJ):
                return gather(node, fsdp_dim=1, tp_dim=0)
            return gather(node, fsdp_dim=0, tp_dim=1)

        return walk(p)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(
    scale: jax.Array, bias: jax.Array, x: jax.Array, eps: float = 1e-6
) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """(..., S) int positions -> cos/sin of shape (..., S, dim/2), f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs. x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:              # (B, S, D/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: Sequence[int],
    theta: float,
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): head_dim/2 split into (t, h, w) sections,
    each rotated by its own position stream.

    Args:
      x: (B, S, H, D).
      positions: (3, B, S) int — temporal / height / width position ids.
      sections: per-section sizes in *pair* units, sum == D/2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    cos_parts, sin_parts = [], []
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    # Section s uses frequency slots offset by the previous sections' sizes
    # (matches HF's interleaved mrope_section splitting at pair granularity).
    off = 0
    for i, sec in enumerate(sections):
        f = freqs[off : off + sec]
        ang = positions[i].astype(jnp.float32)[..., None] * f  # (B, S, sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        off += sec
    cos = jnp.concatenate(cos_parts, axis=-1)  # (B, S, D/2)
    sin = jnp.concatenate(sin_parts, axis=-1)
    return apply_rope(x, cos, sin)


# ---------------------------------------------------------------------------
# Dense / MLP / embeddings
# ---------------------------------------------------------------------------


def init_dense(
    key: jax.Array,
    d_in: int,
    d_out: int,
    dtype,
    bias: bool = False,
    scale: float | None = None,
) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype) -> dict:
    """Gated SwiGLU MLP (llama-style)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d_model, d_ff, dtype),
        "w_up": init_dense(k2, d_model, d_ff, dtype),
        "w_down": init_dense(k3, d_ff, d_model, dtype, scale=d_ff ** -0.5),
    }


def mlp(p: dict, x: jax.Array, ctx: MeshCtx) -> jax.Array:
    h = jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x)
    h = ctx.shard_features(h)
    return dense(p["w_down"], h)


def init_gelu_mlp(key: jax.Array, d_model: int, d_ff: int, dtype) -> dict:
    """Plain GELU MLP (Whisper/StarCoder2-style), with biases."""
    k1, k2 = jax.random.split(key)
    return {
        "w_fc": init_dense(k1, d_model, d_ff, dtype, bias=True),
        "w_out": init_dense(k2, d_ff, d_model, dtype, bias=True, scale=d_ff ** -0.5),
    }


def gelu_mlp(p: dict, x: jax.Array, ctx: MeshCtx) -> jax.Array:
    h = jax.nn.gelu(dense(p["w_fc"], x))
    h = ctx.shard_features(h)
    return dense(p["w_out"], h)


def init_embedding(key: jax.Array, vocab: int, d_model: int, dtype) -> dict:
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]
