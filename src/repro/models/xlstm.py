"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential) — arXiv:2405.04517.

mLSTM cell, per head with key/value dim D:

    C_t = f_t C_{t-1} + i_t (v_t k_t^T)        matrix memory (D, D)
    n_t = f_t n_{t-1} + i_t k_t                normalizer (D,)
    h_t = (C_t q_t) / max(|n_t^T q_t|, 1)

with exponential input gate i_t = exp(ĩ_t) and sigmoid-vs-exp forget gate
stabilized by the running max m_t (Appendix A of the paper):

    m_t = max(log f_t + m_{t-1}, ĩ_t)
    i'_t = exp(ĩ_t - m_t),  f'_t = exp(log f_t + m_{t-1} - m_t)

Training runs a chunk-parallel evaluation (chunked linear attention with
per-step decay — the TPU-friendly formulation; the original CUDA kernel is
fused sequential); decode carries (C, n, m) state. sLSTM is inherently
sequential (non-diagonal recurrence through h_{t-1}) and runs a time scan in
both modes.

Block layout follows the paper: mLSTM blocks wrap the cell in an
up/down-projection (factor 2) with a GeLU gate branch; sLSTM blocks apply
the cell at model width with a gated output. ``d_ff == 0``: there is no
separate FFN sub-layer.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import MeshCtx, dense, init_dense, rms_norm

__all__ = [
    "MLSTMState",
    "SLSTMState",
    "init_mlstm_block",
    "mlstm_block",
    "init_mlstm_state",
    "init_slstm_block",
    "slstm_block",
    "init_slstm_state",
]

_CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MLSTMState:
    C: jax.Array   # (B, H, D, D)
    n: jax.Array   # (B, H, D)
    m: jax.Array   # (B, H)

    def tree_flatten(self):
        return (self.C, self.n, self.m), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    MLSTMState, MLSTMState.tree_flatten, MLSTMState.tree_unflatten
)


def init_mlstm_state(batch: int, cfg: ModelConfig, dtype) -> MLSTMState:
    h, d = cfg.n_heads, _mlstm_head_dim(cfg)
    return MLSTMState(
        C=jnp.zeros((batch, h, d, d), jnp.float32),
        n=jnp.zeros((batch, h, d), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


def _mlstm_head_dim(cfg: ModelConfig) -> int:
    return (2 * cfg.d_model) // cfg.n_heads  # cell runs at up-projected width


def init_mlstm_block(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    du = 2 * d
    ks = jax.random.split(key, 8)
    return {
        "w_up": init_dense(ks[0], d, du, dtype),
        "w_gate": init_dense(ks[1], d, du, dtype),
        "wq": init_dense(ks[2], du, du, dtype),
        "wk": init_dense(ks[3], du, du, dtype),
        "wv": init_dense(ks[4], du, du, dtype),
        "w_if": init_dense(ks[5], du, 2 * cfg.n_heads, dtype, bias=True),
        "out_norm": jnp.zeros((du,), dtype),
        "w_down": init_dense(ks[6], du, d, dtype, scale=du ** -0.5),
    }


def _mlstm_chunk_parallel(
    q, k, v, log_i, log_f, state: MLSTMState
) -> tuple[jax.Array, MLSTMState]:
    """Chunkwise evaluation. q/k/v: (B, H, S, D) f32; gates: (B, H, S) f32."""
    B, H, S, D = q.shape
    nc = S // _CHUNK if S >= _CHUNK else 1
    chunk = S // nc
    q = q.reshape(B, H, nc, chunk, D)
    k = k.reshape(B, H, nc, chunk, D)
    v = v.reshape(B, H, nc, chunk, D)
    log_i = log_i.reshape(B, H, nc, chunk)
    log_f = log_f.reshape(B, H, nc, chunk)

    # Within-chunk cumulative log forget (inclusive) per position.
    cum_f = jnp.cumsum(log_f, axis=-1)                       # (B,H,nc,chunk)

    def step(carry, xs):
        C, n, m = carry                                       # (B,H,D,D),(B,H,D),(B,H)
        qc, kc, vc, lic, lfc, cfc = xs                        # per-chunk slices
        total_f = cfc[..., -1]                                # sum log f in chunk

        # Stabilizers. Contribution of in-chunk source s<=t at output t has
        # log-scale cfc[t] - cfc[s] + lic[s]; the carried state enters with
        # log-scale cfc[t] + m_prev. The sequential recurrence
        # m_t = max(log_f_t + m_{t-1}, lic_t) therefore unrolls to
        # m_t = cfc[t] + max(m_prev, cummax_s(lic[s] - cfc[s])).
        src = lic - cfc                                       # (B,H,chunk)
        m_t = cfc + jnp.maximum(
            m[..., None], jax.lax.cummax(src, axis=src.ndim - 1)
        )
        m_new = total_f + jnp.maximum(m, jnp.max(src, axis=-1))

        # Decay matrix D[t,s] = exp(cfc[t] - cfc[s] + lic[s] - m_t) masked s<=t.
        dmat = cfc[..., :, None] - cfc[..., None, :] + lic[..., None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(mask, dmat - m_t[..., :, None], -1e30)
        w = jnp.exp(dmat)                                     # (B,H,chunk,chunk)

        scores = jnp.einsum("bhtd,bhsd->bhts", qc, kc) * (D ** -0.5)
        intra = jnp.einsum("bhts,bhsd->bhtd", scores * w, vc)
        # Normalizer uses the same decay weights against raw keys.
        n_w = jnp.einsum("bhts,bhsd->bhtd", w, kc)

        # Inter-chunk: state entering the chunk, decayed per position.
        # C follows the decode-step convention C[v_dim, k_dim].
        carry_scale = jnp.exp(cfc + m[..., None] - m_t)       # (B,H,chunk)
        inter = jnp.einsum("bhtk,bhvk->bhtv", qc, C) * (D ** -0.5)
        inter = inter * carry_scale[..., None]
        n_carry = n[..., None, :] * carry_scale[..., None]    # (B,H,chunk,D)

        h_num = intra + inter
        n_tot = n_w + n_carry
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhtd,bhtd->bht", n_tot, qc * (D ** -0.5))), 1.0
        )
        h = h_num / denom[..., None]

        # State update to end of chunk.
        scale_state = jnp.exp(total_f + m - m_new)            # (B,H)
        src_scale = jnp.exp(total_f[..., None] - cfc + lic - m_new[..., None])
        C_new = C * scale_state[..., None, None] + jnp.einsum(
            "bhs,bhsd,bhse->bhde", src_scale, vc, kc
        )
        n_new = n * scale_state[..., None] + jnp.einsum(
            "bhs,bhsd->bhd", src_scale, kc
        )
        return (C_new, n_new, m_new), h

    xs = (
        q.transpose(2, 0, 1, 3, 4),
        k.transpose(2, 0, 1, 3, 4),
        v.transpose(2, 0, 1, 3, 4),
        log_i.transpose(2, 0, 1, 3),
        log_f.transpose(2, 0, 1, 3),
        cum_f.transpose(2, 0, 1, 3),
    )
    (C, n, m), hs = jax.lax.scan(step, (state.C, state.n, state.m), xs)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, D)
    return h, MLSTMState(C=C, n=n, m=m)


def mlstm_block(
    p: dict,
    x: jax.Array,                 # (B, S, d)
    ctx: MeshCtx,
    cfg: ModelConfig,
    state: MLSTMState | None = None,
) -> tuple[jax.Array, MLSTMState | None]:
    B, S, d = x.shape
    H = cfg.n_heads
    up = dense(p["w_up"], x)
    up = ctx.shard_features(up)
    gate = jax.nn.gelu(dense(p["w_gate"], x))
    du = up.shape[-1]
    D = du // H

    q = dense(p["wq"], up).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    k = dense(p["wk"], up).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    v = dense(p["wv"], up).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    gates = dense(p["w_if"], up).astype(jnp.float32)          # (B,S,2H)
    log_i = gates[..., :H].transpose(0, 2, 1)                 # (B,H,S)
    log_f = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)

    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    st = state if state is not None else init_mlstm_state(B, cfg, x.dtype)

    if S == 1:  # decode
        m_new = jnp.maximum(log_f[..., 0] + st.m, log_i[..., 0])
        i_p = jnp.exp(log_i[..., 0] - m_new)
        f_p = jnp.exp(log_f[..., 0] + st.m - m_new)
        C = st.C * f_p[..., None, None] + i_p[..., None, None] * (
            vf[:, :, 0, :, None] * kf[:, :, 0, None, :]
        )
        n = st.n * f_p[..., None] + i_p[..., None] * kf[:, :, 0]
        num = jnp.einsum("bhde,bhe->bhd", C, qf[:, :, 0]) * (D ** -0.5)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf[:, :, 0])) * (D ** -0.5), 1.0)
        h = (num / den[..., None])[:, :, None, :]
        new_state = MLSTMState(C=C, n=n, m=m_new)
    else:
        h, new_state = _mlstm_chunk_parallel(qf, kf, vf, log_i, log_f, st)

    h = h.transpose(0, 2, 1, 3).reshape(B, S, du).astype(x.dtype)
    h = rms_norm(p["out_norm"], h, cfg.norm_eps) * gate
    h = ctx.shard_features(h)
    out = dense(p["w_down"], h)
    return out, (new_state if state is not None else None)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SLSTMState:
    c: jax.Array   # (B, d)
    n: jax.Array   # (B, d)
    h: jax.Array   # (B, d)
    m: jax.Array   # (B, d)

    def tree_flatten(self):
        return (self.c, self.n, self.h, self.m), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    SLSTMState, SLSTMState.tree_flatten, SLSTMState.tree_unflatten
)


def init_slstm_state(batch: int, cfg: ModelConfig, dtype) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=z - 1e30)


def init_slstm_block(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_z": init_dense(ks[0], d, d, dtype, bias=True),
        "w_i": init_dense(ks[1], d, d, dtype, bias=True),
        "w_f": init_dense(ks[2], d, d, dtype, bias=True),
        "w_o": init_dense(ks[3], d, d, dtype, bias=True),
        # recurrent (h_{t-1}) connections — the non-diagonal part.
        "r_z": init_dense(ks[4], d, d, dtype),
        "w_out": init_dense(ks[5], d, d, dtype, scale=d ** -0.5),
    }


def slstm_block(
    p: dict,
    x: jax.Array,
    ctx: MeshCtx,
    cfg: ModelConfig,
    state: SLSTMState | None = None,
) -> tuple[jax.Array, SLSTMState | None]:
    B, S, d = x.shape
    zx = dense(p["w_z"], x).astype(jnp.float32)
    ix = dense(p["w_i"], x).astype(jnp.float32)
    fx = dense(p["w_f"], x).astype(jnp.float32)
    ox = dense(p["w_o"], x).astype(jnp.float32)
    rw = p["r_z"]["w"].astype(jnp.float32)
    st = state if state is not None else init_slstm_state(B, cfg, x.dtype)

    def step(carry, xs):
        c, n, h, m = carry
        zt, it, ft, ot = xs
        zt = jnp.tanh(zt + h @ rw)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    xs = (
        zx.transpose(1, 0, 2),
        ix.transpose(1, 0, 2),
        fx.transpose(1, 0, 2),
        ox.transpose(1, 0, 2),
    )
    (c, n, h, m), hs = jax.lax.scan(step, (st.c, st.n, st.h, st.m), xs)
    out = hs.transpose(1, 0, 2).astype(x.dtype)
    out = ctx.shard_tokens(out)
    new_state = SLSTMState(c=c, n=n, h=h, m=m) if state is not None else None
    return dense(p["w_out"], out), new_state
