"""Config-driven model composition for all assigned architectures.

A model is a sequence of *segments*; each segment is a periodic pattern of
block signatures scanned over its repeats (``jax.lax.scan`` keeps the HLO
size independent of depth; ``jax.checkpoint`` inside the scan body gives
per-layer rematerialization). Segmentation is derived automatically from the
config's block pattern + MoE layout:

* dense GQA archs      -> one segment, period 1;
* DeepSeek-V3          -> [attn+dense]x3, [attn+MoE]x58 (two segments);
* RecurrentGemma       -> (rglru, rglru, local_attn)x8 + (rglru, rglru) tail;
* xLSTM                -> (mlstm, slstm)x6;
* Whisper              -> encoder stack (bidirectional) + decoder stack with
                          cross-attention.

Public entry points:

* ``init_params(key, cfg)``
* ``loss_fn(params, cfg, ctx, batch)``          — training loss (chunked xent)
* ``prefill(params, cfg, ctx, batch, caches)``  — fill caches, last-token logits
* ``decode_step(params, cfg, ctx, batch, caches)`` — one-token serve step
* ``init_caches(cfg, batch, s_cache, dtype)``
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import xlstm as xlstm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (
    MeshCtx,
    apply_mrope,
    apply_rope,
    dense,
    embed_tokens,
    init_dense,
    init_embedding,
    init_mlp,
    mlp,
    rms_norm,
    rope,
)

__all__ = [
    "Signature",
    "segments_of",
    "init_params",
    "init_caches",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
]

_LOSS_SEQ_CHUNK = 512


# ---------------------------------------------------------------------------
# Segmentation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Signature:
    kind: str          # attn | local_attn | rglru | mlstm | slstm
    moe: bool
    cross: bool = False  # decoder block with cross-attention (Whisper)


def _layer_signatures(cfg: ModelConfig) -> list[Signature]:
    sigs = []
    for i, kind in enumerate(cfg.resolved_block_pattern):
        moe = cfg.is_moe and i >= cfg.n_dense_layers and kind in ("attn", "local_attn")
        sigs.append(Signature(kind=kind, moe=moe, cross=cfg.is_encoder_decoder))
    return sigs


def _smallest_period(seq: list) -> int:
    n = len(seq)
    for p in range(1, n + 1):
        if all(seq[i] == seq[i % p] for i in range(n)):
            return p
    return n


def segments_of(cfg: ModelConfig) -> list[tuple[tuple[Signature, ...], int]]:
    """[(pattern, repeats), ...] covering the decoder stack in order."""
    sigs = _layer_signatures(cfg)
    n = len(sigs)
    p = _smallest_period(sigs)
    if p <= max(4, n // 2):
        reps = n // p
        segs = [(tuple(sigs[:p]), reps)]
        if n % p:
            segs.append((tuple(sigs[reps * p :]), 1))
        return segs
    # Fallback: maximal uniform runs (handles DeepSeek's dense prefix).
    segs = []
    start = 0
    for i in range(1, n + 1):
        if i == n or sigs[i] != sigs[start]:
            segs.append(((sigs[start],), i - start))
            start = i
    return segs


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _init_block(key: jax.Array, cfg: ModelConfig, sig: Signature) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dt)}
    if sig.kind in ("attn", "local_attn"):
        if cfg.use_mla:
            p["attn"] = mla_lib.init_mla(ks[0], cfg, dt)
        else:
            p["attn"] = attn_lib.init_attention(
                ks[0],
                cfg.d_model,
                cfg.n_heads,
                cfg.n_kv_heads,
                cfg.resolved_head_dim,
                dt,
                qkv_bias=cfg.qkv_bias,
            )
        if sig.cross:
            p["cross_norm"] = jnp.zeros((cfg.d_model,), dt)
            p["cross"] = attn_lib.init_attention(
                ks[1], cfg.d_model, cfg.n_heads, cfg.n_heads,
                cfg.resolved_head_dim, dt,
            )
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
        if sig.moe:
            p["moe"] = moe_lib.init_moe(ks[2], cfg, dt)
        elif cfg.d_ff:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dt)
    elif sig.kind == "rglru":
        p["rec"] = rglru_lib.init_rglru_block(ks[0], cfg, dt)
        if cfg.d_ff:
            p["norm2"] = jnp.zeros((cfg.d_model,), dt)
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dt)
    elif sig.kind == "mlstm":
        p["cell"] = xlstm_lib.init_mlstm_block(ks[0], cfg, dt)
    elif sig.kind == "slstm":
        p["cell"] = xlstm_lib.init_slstm_block(ks[0], cfg, dt)
    else:
        raise ValueError(sig.kind)
    return p


def _rope_fn(cfg: ModelConfig, mrope_positions: jax.Array | None) -> Callable | None:
    """Builds fn(x4d, positions) applying the arch's rotary flavor."""
    if cfg.is_encoder_decoder:
        return None  # Whisper: absolute (sinusoidal) embeddings, added earlier
    hd = cfg.qk_rope_dim if cfg.use_mla else cfg.resolved_head_dim

    if cfg.mrope_sections:
        def fn(x, positions):
            if mrope_positions is not None:
                pos3 = mrope_positions
            else:
                # Text-only fallback: all three streams share positions.
                pos3 = jnp.broadcast_to(
                    positions[None, None, :], (3, x.shape[0], x.shape[1])
                )
            return apply_mrope(x, pos3, cfg.mrope_sections, cfg.rope_theta)
        return fn

    def fn(x, positions):
        cos, sin = rope(positions, hd, cfg.rope_theta)
        return apply_rope(x, cos, sin)
    return fn


def _apply_block(
    p: dict,
    sig: Signature,
    x: jax.Array,
    ctx: MeshCtx,
    cfg: ModelConfig,
    cache,
    *,
    rope_fn,
    positions,
    encoder_out,
    causal: bool = True,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.zero3_use_site_gather:
        p = ctx.gather_params(p)  # ZeRO-3 use-site weight gather (see MeshCtx)
    if sig.kind in ("attn", "local_attn"):
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        window = cfg.local_window if sig.kind == "local_attn" else 0
        if cfg.use_mla:
            y, new_cache = mla_lib.mla_block(
                p["attn"], h, ctx, cfg, positions=positions, cache=cache
            )
        else:
            y, new_cache = attn_lib.attention_block(
                p["attn"], h, ctx,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim,
                causal=causal,
                window=window,
                rope_fn=rope_fn,
                positions=positions,
                cache=cache,
            )
        x = x + y
        if sig.cross and encoder_out is not None:
            h = rms_norm(p["cross_norm"], x, cfg.norm_eps)
            k = dense(p["cross"]["wk"], encoder_out).reshape(
                *encoder_out.shape[:2], cfg.n_heads, cfg.resolved_head_dim
            )
            v = dense(p["cross"]["wv"], encoder_out).reshape(
                *encoder_out.shape[:2], cfg.n_heads, cfg.resolved_head_dim
            )
            y, _ = attn_lib.attention_block(
                p["cross"], h, ctx,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_heads,
                head_dim=cfg.resolved_head_dim,
                cross_kv=(k, v),
            )
            x = x + y
        h = rms_norm(p["norm2"], x, cfg.norm_eps)
        if sig.moe:
            y, aux = moe_lib.moe_block(p["moe"], h, ctx, cfg)
        elif "mlp" in p:
            y = mlp(p["mlp"], h, ctx)
        else:
            y = jnp.zeros_like(x)
        x = x + y
        return x, new_cache, aux

    if sig.kind == "rglru":
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        y, new_cache = rglru_lib.rglru_block(p["rec"], h, ctx, cfg, state=cache)
        x = x + y
        if "mlp" in p:
            h = rms_norm(p["norm2"], x, cfg.norm_eps)
            x = x + mlp(p["mlp"], h, ctx)
        return x, new_cache, aux

    if sig.kind == "mlstm":
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        y, new_cache = xlstm_lib.mlstm_block(p["cell"], h, ctx, cfg, state=cache)
        return x + y, new_cache, aux

    if sig.kind == "slstm":
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        y, new_cache = xlstm_lib.slstm_block(p["cell"], h, ctx, cfg, state=cache)
        return x + y, new_cache, aux

    raise ValueError(sig.kind)


def _init_cache_for(sig: Signature, cfg: ModelConfig, batch: int, s_cache: int, dtype):
    if sig.kind in ("attn", "local_attn"):
        if cfg.use_mla:
            return mla_lib.init_mla_cache(batch, s_cache, cfg, dtype)
        size = min(s_cache, cfg.local_window) if sig.kind == "local_attn" else s_cache
        return attn_lib.init_kv_cache(
            batch, size, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
        )
    if sig.kind == "rglru":
        return rglru_lib.init_rglru_state(batch, cfg, dtype)
    if sig.kind == "mlstm":
        return xlstm_lib.init_mlstm_state(batch, cfg, dtype)
    if sig.kind == "slstm":
        return xlstm_lib.init_slstm_state(batch, cfg, dtype)
    raise ValueError(sig.kind)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    params["embed"] = init_embedding(keys[0], cfg.padded_vocab, cfg.d_model, dt)
    params["final_norm"] = jnp.zeros((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(
            keys[1], cfg.d_model, cfg.padded_vocab, dt, scale=cfg.d_model ** -0.5
        )

    def init_segments(key, segs):
        out = []
        for si, (pattern, reps) in enumerate(segs):
            seg_params = []
            for pi, sig in enumerate(pattern):
                k = jax.random.fold_in(key, si * 64 + pi)
                layer_keys = jax.random.split(k, reps)
                seg_params.append(
                    jax.vmap(lambda kk: _init_block(kk, cfg, sig))(layer_keys)
                )
            out.append(seg_params)
        return out

    params["segments"] = init_segments(keys[2], segments_of(cfg))

    if cfg.is_encoder_decoder:
        enc_sig = Signature(kind="attn", moe=False, cross=False)
        enc_segs = [((enc_sig,), cfg.encoder_layers)]
        params["encoder"] = {
            "segments": init_segments(keys[3], enc_segs),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
        }

    if cfg.mtp_depth:
        # DeepSeek MTP: projection of [h ; emb(next)] + one extra block.
        params["mtp"] = {
            "proj": init_dense(keys[4], 2 * cfg.d_model, cfg.d_model, dt),
            "norm_h": jnp.zeros((cfg.d_model,), dt),
            "norm_e": jnp.zeros((cfg.d_model,), dt),
            "block": _init_block(
                keys[5], cfg, Signature(kind="attn", moe=False, cross=False)
            ),
        }
    return params


def init_caches(cfg: ModelConfig, batch: int, s_cache: int, dtype=None) -> list:
    dtype = dtype or jnp.dtype(cfg.dtype)

    def stack(sig, reps):
        one = _init_cache_for(sig, cfg, batch, s_cache, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy(), one)

    return [
        [stack(sig, reps) for sig in pattern]
        for (pattern, reps) in segments_of(cfg)
    ]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / (half - 1)))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _run_segments(
    segments_params,
    segs,
    x,
    ctx,
    cfg,
    caches,
    *,
    rope_fn,
    positions,
    encoder_out,
    causal,
):
    """Scan every segment. Returns (x, new_caches, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None

    for si, (pattern, reps) in enumerate(segs):
        seg_params = segments_params[si]
        seg_caches = caches[si] if caches is not None else [None] * len(pattern)

        def body(carry, xs):
            h = carry
            layer_params, layer_caches = xs
            aux_sum = jnp.zeros((), jnp.float32)
            outs = []
            for pi, sig in enumerate(pattern):
                h, nc, aux = _apply_block(
                    layer_params[pi], sig, h, ctx, cfg,
                    layer_caches[pi] if layer_caches is not None else None,
                    rope_fn=rope_fn,
                    positions=positions,
                    encoder_out=encoder_out,
                    causal=causal,
                )
                # Block boundary: with sequence parallelism this re-shards the
                # residual stream (and hence the saved scan carry) over the TP
                # axis — reduce-scatter after the block, all-gather inside the
                # next one (Megatron-SP), and 1/tp the remat memory.
                h = ctx.shard_tokens(h)
                outs.append(nc)
                aux_sum = aux_sum + aux
            return h, (outs, aux_sum)

        if cfg.remat:
            body = jax.checkpoint(body)

        xs = (
            seg_params,
            seg_caches if caches is not None else None,
        )
        x, (caches_out, aux_per_rep) = jax.lax.scan(body, x, xs, length=reps)
        total_aux = total_aux + aux_per_rep.sum()
        if new_caches is not None:
            new_caches.append(caches_out)
    return x, new_caches, total_aux


def forward(
    params: dict,
    cfg: ModelConfig,
    ctx: MeshCtx,
    batch: dict,
    caches=None,
) -> tuple[jax.Array, Any, jax.Array]:
    """Trunk forward. Returns (hidden (B,S,d), new_caches, aux_loss)."""
    if cfg.embedding_inputs and "embeds" in batch:
        x = batch["embeds"]
    else:
        x = embed_tokens(params["embed"], batch["tokens"])
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = ctx.shard_tokens(x)

    pos0 = batch.get("pos0", None)
    if pos0 is None and caches is not None:
        pos0 = _first_cache_pos(caches)
    S = x.shape[1]
    positions = (pos0 if pos0 is not None else 0) + jnp.arange(S, dtype=jnp.int32)

    encoder_out = None
    if cfg.is_encoder_decoder and "encoder_out" in batch:
        # Serving: encoder ran once at prefill; decode steps reuse its output.
        encoder_out = batch["encoder_out"]
    elif cfg.is_encoder_decoder:
        enc = batch["encoder_embeds"]
        enc = enc + _sinusoidal(
            jnp.arange(enc.shape[1], dtype=jnp.int32), cfg.d_model
        ).astype(enc.dtype)[None]
        enc = ctx.shard_tokens(enc)
        enc_sig = Signature(kind="attn", moe=False, cross=False)
        enc_segs = [((enc_sig,), cfg.encoder_layers)]
        enc_out, _, _ = _run_segments(
            params["encoder"]["segments"], enc_segs, enc, ctx, cfg, None,
            rope_fn=None, positions=None, encoder_out=None, causal=False,
        )
        encoder_out = rms_norm(params["encoder"]["final_norm"], enc_out, cfg.norm_eps)
    if cfg.is_encoder_decoder:
        # Decoder gets absolute sinusoidal positions.
        x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)[None]

    rope_fn = _rope_fn(cfg, batch.get("mrope_positions"))
    x, new_caches, aux = _run_segments(
        params["segments"], segments_of(cfg), x, ctx, cfg, caches,
        rope_fn=rope_fn, positions=positions, encoder_out=encoder_out, causal=True,
    )
    return x, new_caches, aux


def _first_cache_pos(caches):
    leaves = jax.tree.leaves(caches)
    # pos leaves are the scalar int32 entries broadcast to (reps,)
    for leaf in leaves:
        if leaf.dtype == jnp.int32 and leaf.ndim == 1:
            return leaf[0]
    return None


# ---------------------------------------------------------------------------
# Heads / losses
# ---------------------------------------------------------------------------


def _logits(params: dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """(B, S, padded_vocab) logits; padding columns masked to -inf so they
    never win an argmax and contribute ~0 to logsumexp."""
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings or "lm_head" not in params:
        w = params["embed"]["table"].T
        logits = jnp.einsum("bsd,dv->bsv", h, w)
    else:
        logits = dense(params["lm_head"], h)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def _chunked_xent(
    params: dict, cfg: ModelConfig, h: jax.Array, labels: jax.Array, mask: jax.Array
) -> jax.Array:
    """Mean next-token cross entropy without materializing (B,S,V) logits."""
    B, S, _ = h.shape
    chunk = min(_LOSS_SEQ_CHUNK, S)
    n = -(-S // chunk)
    total = jnp.zeros((), jnp.float32)
    count = jnp.zeros((), jnp.float32)
    for i in range(n):
        s0 = i * chunk
        sl = slice(s0, min(s0 + chunk, S))

        def piece(hc, yc, mc):
            logits = _logits(params, cfg, hc).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            # One-hot contraction instead of take_along_axis: the gather
            # would force an all-gather of the vocab-sharded logits, the
            # contraction reduces locally per vocab shard (verified to cut
            # the dry-run collective term ~30x on vocab-heavy models).
            onehot = jax.nn.one_hot(yc, logits.shape[-1], dtype=logits.dtype)
            gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
            nll = (lse - gold) * mc
            return nll.sum(), mc.sum()

        piece = jax.checkpoint(piece) if cfg.remat else piece
        t, c = piece(h[:, sl], labels[:, sl], mask[:, sl].astype(jnp.float32))
        total += t
        count += c
    return total / jnp.maximum(count, 1.0)


def loss_fn(params: dict, cfg: ModelConfig, ctx: MeshCtx, batch: dict) -> jax.Array:
    """Next-token LM loss (+ MoE aux + MTP head when configured)."""
    h, _, aux = forward(params, cfg, ctx, batch)
    tokens = batch.get("tokens")
    labels = batch.get("labels")
    if labels is None:
        if tokens is None:
            raise ValueError("embedding-input models need explicit labels")
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, dtype=jnp.float32)
        mask = mask.at[:, -1].set(0.0)
    loss = _chunked_xent(params, cfg, h, labels, mask)

    if cfg.mtp_depth and "mtp" in params and not cfg.embedding_inputs:
        # Predict token t+2 from [h_t ; emb(token_{t+1})].
        p = params["mtp"]
        emb_next = embed_tokens(params["embed"], jnp.pad(tokens[:, 1:], ((0, 0), (0, 1))))
        hh = jnp.concatenate(
            [rms_norm(p["norm_h"], h, cfg.norm_eps),
             rms_norm(p["norm_e"], emb_next, cfg.norm_eps)],
            axis=-1,
        )
        hh = dense(p["proj"], hh)
        sig = Signature(kind="attn", moe=False, cross=False)
        hh, _, _ = _apply_block(
            p["block"], sig, hh, ctx, cfg, None,
            rope_fn=_rope_fn(cfg, batch.get("mrope_positions")),
            positions=jnp.arange(hh.shape[1], dtype=jnp.int32),
            encoder_out=None,
        )
        labels2 = jnp.pad(tokens[:, 2:], ((0, 0), (0, 2)))
        mask2 = jnp.ones_like(labels2, dtype=jnp.float32).at[:, -2:].set(0.0)
        loss = loss + 0.3 * _chunked_xent(params, cfg, hh, labels2, mask2)

    return loss + 0.01 * aux


def prefill(params, cfg, ctx, batch, caches):
    """Run the full prompt through the model, filling caches.

    Returns (last-token logits (B, V), caches).
    """
    h, caches, _ = forward(params, cfg, ctx, batch, caches=caches)
    logits = _logits(params, cfg, h[:, -1:])
    return logits[:, 0, : cfg.vocab_size], caches


def decode_step(params, cfg, ctx, batch, caches):
    """One-token decode. batch["tokens"]: (B, 1). Returns (logits (B,V), caches)."""
    h, caches, _ = forward(params, cfg, ctx, batch, caches=caches)
    logits = _logits(params, cfg, h[:, -1:])
    return logits[:, 0, : cfg.vocab_size], caches
