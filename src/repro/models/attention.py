"""GQA/MQA attention with KV cache: full, causal, and local (windowed).

Supports three lowering shapes:
* train/prefill — q_len == kv_len, causal (or bidirectional for encoders);
* decode        — q_len == 1 against a pre-filled cache of ``max_seq`` slots;
* cross         — decoder queries over fixed encoder keys (Whisper).

The XLA path is used everywhere on CPU and in dry-runs; the Pallas flash
kernel (repro.kernels.flash_attention) is selected with
``cfg.attention_impl == "pallas"`` on real TPUs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import MeshCtx, dense, init_dense

__all__ = [
    "KVCache",
    "init_attention",
    "attention_block",
    "init_kv_cache",
    "sdpa",
]

NEG_INF = -2.0e38


@dataclasses.dataclass
class KVCache:
    """Ring-less KV cache: ``k``/``v`` are (B, S_cache, Hkv, D); ``pos`` is the
    number of valid entries (same for every row — batched decode steps in
    lockstep, the usual serving arrangement for fixed-shape benchmarks)."""

    k: jax.Array
    v: jax.Array
    pos: jax.Array  # scalar int32

    def tree_flatten(self):
        return (self.k, self.v, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    KVCache, KVCache.tree_flatten, KVCache.tree_unflatten
)


def init_kv_cache(
    batch: int, s_cache: int, n_kv_heads: int, head_dim: int, dtype
) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, s_cache, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, s_cache, n_kv_heads, head_dim), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def init_attention(
    key: jax.Array,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    dtype,
    qkv_bias: bool = False,
) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, dtype, bias=qkv_bias),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, dtype, bias=qkv_bias),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, dtype, bias=qkv_bias),
        "wo": init_dense(
            ko, n_heads * head_dim, d_model, dtype, scale=(n_heads * head_dim) ** -0.5
        ),
    }


def sdpa(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Sk, Hkv, D)
    v: jax.Array,          # (B, Sk, Hkv, D)
    *,
    causal: bool,
    window: int = 0,
    q_positions: jax.Array | None = None,  # (Sq,) absolute positions of queries
    kv_valid: jax.Array | None = None,     # (Sk,) bool — valid cache slots
    k_positions: jax.Array | None = None,  # (Sk,) absolute positions of keys
) -> jax.Array:
    """Grouped scaled-dot-product attention (pure XLA reference path).

    Masking composes: causal (query pos >= key pos), sliding window
    (key pos > query pos - window), and cache validity. ``k_positions``
    overrides the default storage-order positions (ring caches).
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = D ** -0.5

    qg = q.reshape(B, Sq, Hkv, G, D)
    # bf16 operands + f32 accumulation (MXU-style): keeps cotangents bf16 —
    # f32-cast inputs made every backward TP all-reduce carry f32 payloads.
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg * jnp.asarray(scale, q.dtype), k,
        preferred_element_type=jnp.float32,
    )

    q_pos = (
        q_positions
        if q_positions is not None
        else jnp.arange(Sq, dtype=jnp.int32)
    )
    k_pos = (
        k_positions
        if k_positions is not None
        else jnp.arange(k.shape[1], dtype=jnp.int32)
    )
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def sdpa_chunked(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Sk, Hkv, D)
    v: jax.Array,          # (B, Sk, Hkv, D)
    *,
    causal: bool,
    window: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> jax.Array:
    """Blocked online-softmax attention (FlashAttention dataflow in pure JAX).

    Never materializes the (Sq, Sk) score matrix: a static Python loop over
    query chunks (so causal block-skipping costs zero FLOPs — the lowered HLO
    simply omits fully-masked KV blocks) with an inner ``lax.scan`` over KV
    chunks carrying the running (max, denominator, accumulator). This is the
    XLA twin of the Pallas flash kernel and the oracle it is tested against.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    scale = D ** -0.5
    nq = -(-Sq // q_chunk)

    # Pad KV to a block multiple: dynamic_slice clamps out-of-range starts,
    # which would silently misalign the position labels of the final ragged
    # block (the k_pos < Sk mask assumes slice starts are exact).
    pad_k = (-Sk) % k_chunk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    outs = []
    for qi in range(nq):
        q0 = qi * q_chunk
        qlen = min(q_chunk, Sq - q0)
        qb = q[:, q0 : q0 + qlen] * jnp.asarray(scale, q.dtype)
        qb = qb.reshape(B, qlen, Hkv, G, D)
        q_pos = q0 + jnp.arange(qlen, dtype=jnp.int32)

        # Static causal/window bounds on which KV blocks can contribute.
        hi = Sk if not causal else min(Sk, q0 + qlen)
        lo = 0 if not window else max(0, q0 - window + 1)
        lo = (lo // k_chunk) * k_chunk
        nk = -(-max(hi - lo, 0) // k_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            k0 = lo + ki * k_chunk
            kb = jax.lax.dynamic_slice_in_dim(k, k0, k_chunk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, k0, k_chunk, axis=1)
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs", qb, kb, preferred_element_type=jnp.float32
            )  # (B, Hkv, G, qlen, k_chunk)
            k_pos = k0 + jnp.arange(k_chunk, dtype=jnp.int32)
            mask = k_pos[None, :] < Sk
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qlen), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qlen), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qlen, Dv), jnp.float32)
        if nk > 0:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), jnp.arange(nk, dtype=jnp.int32)
            )
        else:
            m, l, acc = m0, l0, a0
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, qlen, H, Dv)
        outs.append(out.astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


# Use the chunked path once the full score matrix would exceed this many
# elements per (batch, head) pair — train/prefill shapes take it, short
# encoder sequences and single-token decode stay on the plain path.
_CHUNKED_THRESHOLD_SEQ = 2048


def attention_block(
    p: dict,
    x: jax.Array,                      # (B, Sq, d_model)
    ctx: MeshCtx,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    causal: bool = True,
    window: int = 0,
    rope_fn=None,                      # fn(x4d, positions) -> x4d, or None
    positions: jax.Array | None = None,  # (Sq,) absolute positions
    cache: KVCache | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,  # encoder K/V
) -> tuple[jax.Array, KVCache | None]:
    """Full attention sub-layer: qkv proj -> rope -> (cache update) -> sdpa -> out.

    Returns (output, updated cache). With ``cross_kv`` the cache and rope are
    ignored (Whisper cross-attention precomputes encoder K/V once).
    """
    B, Sq, _ = x.shape
    q = dense(p["wq"], x).reshape(B, Sq, n_heads, head_dim)
    q = ctx.shard(q, ctx.data_axes, None, ctx.tp_axis, None)

    if cross_kv is not None:
        k, v = cross_kv
        if Sq >= _CHUNKED_THRESHOLD_SEQ:
            out = sdpa_chunked(q, k, v, causal=False)
        else:
            out = sdpa(q, k, v, causal=False)
        out = ctx.shard(out, ctx.data_axes, None, ctx.tp_axis, None)
        return dense(p["wo"], out.reshape(B, Sq, n_heads * head_dim)), cache

    k = dense(p["wk"], x).reshape(B, Sq, n_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(B, Sq, n_kv_heads, head_dim)

    if positions is None:
        base = cache.pos if cache is not None else 0
        positions = base + jnp.arange(Sq, dtype=jnp.int32)
    if rope_fn is not None:
        q = rope_fn(q, positions)
        k = rope_fn(k, positions)

    kv_valid = None
    ring = False
    fresh_k, fresh_v = k, v
    if cache is not None:
        # Rope is applied *before* caching, so stored keys carry their absolute
        # positions and storage order need not equal position order — which is
        # what makes the ring layout below legal for sliding windows.
        s_cache = cache.k.shape[1]
        if Sq == s_cache:
            # Full prefill: the whole cache is freshly written.
            new_k, new_v = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
        elif Sq > s_cache:
            # Window-sized ring cache smaller than the prompt: keep the last
            # s_cache entries, rolled so that slot(P) == P % s_cache.
            start = (cache.pos + Sq - s_cache) % s_cache
            new_k = jnp.roll(k[:, -s_cache:].astype(cache.k.dtype), start, axis=1)
            new_v = jnp.roll(v[:, -s_cache:].astype(cache.v.dtype), start, axis=1)
        else:
            # Incremental write (decode): ring addressing covers both the
            # full-size cache (pos < s_cache always) and window rings.
            write = cache.pos % s_cache if window else cache.pos
            new_k = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, write, 0, 0)
            )
            new_v = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, write, 0, 0)
            )
        ring = bool(window) and s_cache <= window
        cache = KVCache(k=new_k, v=new_v, pos=cache.pos + Sq)
        k, v = cache.k, cache.v
        kv_valid = jnp.arange(s_cache, dtype=jnp.int32) < cache.pos

    # Chunked path for long query spans (train / prefill). During a full-cache
    # prefill every cache slot is freshly written, so the validity mask is
    # redundant and the chunked kernel applies directly.
    if Sq >= _CHUNKED_THRESHOLD_SEQ:
        # k may by now be the (rolled, window-sized) cache; attention over the
        # prompt itself uses the freshly-projected pre-cache k/v.
        out = sdpa_chunked(q, fresh_k, fresh_v, causal=causal, window=window)
    elif ring:
        # Ring cache: reconstruct each slot's absolute position (slot i holds
        # the newest written position congruent to i mod s_cache) and apply
        # causal + window masks against true positions — storage order is not
        # position order once the ring has wrapped.
        s_cache = k.shape[1]
        slots = jnp.arange(s_cache, dtype=jnp.int32)
        total = cache.pos  # already includes this step's Sq
        k_abs = slots + ((total - 1 - slots) // s_cache) * s_cache
        out = sdpa(
            q, k, v,
            causal=True,
            window=window,
            q_positions=positions,
            kv_valid=kv_valid,
            k_positions=k_abs,
        )
    else:
        out = sdpa(
            q, k, v,
            causal=causal,
            window=window,
            q_positions=positions,
            kv_valid=kv_valid,
        )
    out = ctx.shard(out, ctx.data_axes, None, ctx.tp_axis, None)
    return dense(p["wo"], out.reshape(B, Sq, n_heads * head_dim)), cache
