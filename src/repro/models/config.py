"""Model configuration: one dataclass describing every assigned architecture.

``src/repro/configs/<arch>.py`` files instantiate this with published
hyper-parameters; reduced variants (``cfg.reduced()``) drive CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES"]

BlockKind = Literal["attn", "local_attn", "rglru", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm

    # trunk
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # block layout: tuple of BlockKind, length n_layers; () -> all "attn"
    block_pattern: tuple[str, ...] = ()
    local_window: int = 0        # for local_attn blocks
    lru_width: int = 0           # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4          # temporal conv width in recurrent blocks

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0      # leading dense-FFN layers (DeepSeek style)
    capacity_factor: float = 1.25
    moe_ep_mode: str = "a2a"     # "a2a" (seq-sharded dispatch) | "replicated"

    # MLA (DeepSeek latent attention)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # multi-token prediction (DeepSeek MTP)
    mtp_depth: int = 0

    # encoder-decoder (Whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0         # precomputed frame embeddings (frontend stub)

    # modality stub: inputs are embeddings, not token ids (audio/vlm frontends)
    embedding_inputs: bool = False

    # flavor knobs
    qkv_bias: bool = False
    mrope_sections: tuple[int, ...] = ()   # M-RoPE (t, h, w) section dims
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # numerics / distribution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: bool = True
    sequence_parallel: bool = False  # shard seq over TP between blocks (SP)
    zero3_use_site_gather: bool = False  # explicit per-layer FSDP weight gather
    fsdp_over_pod: bool = False  # ZeRO-3 across the pod axis too (huge models)
    attention_impl: str = "xla"  # "xla" | "pallas" (pallas = TPU only)

    def __post_init__(self) -> None:
        if self.block_pattern and len(self.block_pattern) != self.n_layers:
            raise ValueError(
                f"{self.name}: block_pattern length {len(self.block_pattern)} "
                f"!= n_layers {self.n_layers}"
            )

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 512 multiple so embeddings/logits shard over a
        16-way TP axis (Whisper 51865, Granite 49155 are otherwise unshardable
        and replicate the lm_head + full logits on every device)."""
        return -(-self.vocab_size // 512) * 512

    @property
    def resolved_block_pattern(self) -> tuple[str, ...]:
        return self.block_pattern or ("attn",) * self.n_layers

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_sub_quadratic(self) -> bool:
        """True iff per-token decode state is O(1) in history (SSM/hybrid)."""
        kinds = set(self.resolved_block_pattern)
        return kinds.issubset({"rglru", "mlstm", "slstm", "local_attn"})

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        n_layers = min(self.n_layers, 2 if not self.block_pattern else
                       min(len(_pattern_period(self.resolved_block_pattern)) + 1, 4))
        pattern = self.resolved_block_pattern[:n_layers] if self.block_pattern else ()
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            block_pattern=pattern,
            local_window=min(self.local_window, 32) if self.local_window else 0,
            lru_width=64 if self.lru_width else 0,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=32 if self.moe_d_ff else 0,
            n_dense_layers=min(self.n_dense_layers, 1),
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            qk_nope_dim=8 if self.qk_nope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            mrope_sections=(2, 3, 3) if self.mrope_sections else (),  # sums to 16/2
            mtp_depth=min(self.mtp_depth, 1),
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )


def _pattern_period(pattern: tuple[str, ...]) -> tuple[str, ...]:
    """Smallest repeating prefix of a block pattern (for reduced configs)."""
    n = len(pattern)
    for p in range(1, n + 1):
        if all(pattern[i] == pattern[i % p] for i in range(n)):
            return pattern[:p]
    return pattern


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}
