"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are produced through low-rank latents:

* q: d_model -> q_lora_rank -> n_heads × (qk_nope_dim + qk_rope_dim)
* kv: d_model -> kv_lora_rank (cached!) -> per-head nope-key and value;
  plus a single shared rope-key of qk_rope_dim (cached alongside).

The decode cache stores only the compressed latent (kv_lora_rank) and the
shared rope key (qk_rope_dim) per position — the paper's core serving win
(93 % KV-cache reduction vs full MHA at DeepSeek-V3 scale).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF
from repro.models.config import ModelConfig
from repro.models.layers import MeshCtx, apply_rope, dense, init_dense, rope, rms_norm

__all__ = ["MLACache", "init_mla", "init_mla_cache", "mla_block"]


@dataclasses.dataclass
class MLACache:
    """Compressed decode cache: latent (B, S, kv_lora), rope key (B, S, rope_d)."""

    latent: jax.Array
    k_rope: jax.Array
    pos: jax.Array

    def tree_flatten(self):
        return (self.latent, self.k_rope, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    MLACache, MLACache.tree_flatten, MLACache.tree_unflatten
)


def init_mla_cache(batch: int, s_cache: int, cfg: ModelConfig, dtype) -> MLACache:
    return MLACache(
        latent=jnp.zeros((batch, s_cache, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, s_cache, cfg.qk_rope_dim), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def init_mla(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    h, dq = cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": init_dense(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), dtype),
        "wq_b": init_dense(ks[1], cfg.q_lora_rank, h * dq, dtype),
        "wkv_a": init_dense(
            ks[2], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype
        ),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
        "wk_b": init_dense(ks[3], cfg.kv_lora_rank, h * cfg.qk_nope_dim, dtype),
        "wv_b": init_dense(ks[4], cfg.kv_lora_rank, h * cfg.v_head_dim, dtype),
        "wo": init_dense(
            ks[5], h * cfg.v_head_dim, cfg.d_model, dtype,
            scale=(h * cfg.v_head_dim) ** -0.5,
        ),
    }


def mla_block(
    p: dict,
    x: jax.Array,                     # (B, Sq, d)
    ctx: MeshCtx,
    cfg: ModelConfig,
    *,
    positions: jax.Array | None = None,
    cache: MLACache | None = None,
) -> tuple[jax.Array, MLACache | None]:
    B, Sq, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    # --- queries ---
    q_lat = rms_norm(p["q_norm"], dense(p["wq_a"], x), cfg.norm_eps)
    q = dense(p["wq_b"], q_lat).reshape(B, Sq, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    # --- compressed kv latent + shared rope key ---
    kv = dense(p["wkv_a"], x)
    latent = rms_norm(p["kv_norm"], kv[..., : cfg.kv_lora_rank], cfg.norm_eps)
    k_rope_new = kv[..., cfg.kv_lora_rank :]  # (B, Sq, dr) shared across heads

    if positions is None:
        base = cache.pos if cache is not None else 0
        positions = base + jnp.arange(Sq, dtype=jnp.int32)
    cos, sin = rope(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], cos, sin)[:, :, 0, :]

    kv_valid = None
    if cache is not None:
        s_cache = cache.latent.shape[1]
        if Sq == s_cache:
            cache = MLACache(latent=latent, k_rope=k_rope_new, pos=cache.pos + Sq)
        else:
            cache = MLACache(
                latent=jax.lax.dynamic_update_slice(
                    cache.latent, latent.astype(cache.latent.dtype), (0, cache.pos, 0)
                ),
                k_rope=jax.lax.dynamic_update_slice(
                    cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), (0, cache.pos, 0)
                ),
                pos=cache.pos + Sq,
            )
        latent_all, k_rope_all = cache.latent, cache.k_rope
        kv_valid = jnp.arange(latent_all.shape[1], dtype=jnp.int32) < cache.pos
    else:
        latent_all, k_rope_all = latent, k_rope_new

    # --- long query spans (train / full prefill): expand K/V per head and use
    # the blocked online-softmax path; the absorbed form below only pays off
    # for single-token decode (it trades score-matrix memory for per-step
    # latent reuse).
    if Sq >= 2048 and latent_all.shape[1] == Sq:
        k_nope = dense(p["wk_b"], latent_all).reshape(B, Sq, h, dn)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :], (B, Sq, h, dr))],
            axis=-1,
        )
        v_full = dense(p["wv_b"], latent_all).reshape(B, Sq, h, dv)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        # Keep the expanded heads TP-sharded: unconstrained, GSPMD replicates
        # these (B,S,128,192) tensors across the model axis (tens of GB).
        q_full = ctx.shard(q_full, ctx.data_axes, None, ctx.tp_axis, None)
        k_full = ctx.shard(k_full, ctx.data_axes, None, ctx.tp_axis, None)
        v_full = ctx.shard(v_full, ctx.data_axes, None, ctx.tp_axis, None)
        from repro.models.attention import sdpa_chunked

        out = sdpa_chunked(q_full, k_full, v_full, causal=True)
        out = ctx.shard(out, ctx.data_axes, None, ctx.tp_axis, None)
        return dense(p["wo"], out.reshape(B, Sq, h * dv)), cache

    # --- absorbed attention (decode-efficient form) ---
    # Instead of expanding per-position keys/values (undoing the compression),
    # fold wk_b into the queries: score = (q_nope @ wk_b^T) · latent.
    wk_b = p["wk_b"]["w"].reshape(cfg.kv_lora_rank, h, dn)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))            # (B,Sq,h,kv_lora)
    scores = jnp.einsum("bqhl,bsl->bhqs", q_abs, latent_all.astype(jnp.float32))
    scores += jnp.einsum(
        "bqhd,bsd->bhqs", q_rope.astype(jnp.float32), k_rope_all.astype(jnp.float32)
    )
    scores *= (dn + dr) ** -0.5

    Sk = latent_all.shape[1]
    q_pos = positions
    k_pos = jnp.arange(Sk, dtype=jnp.int32)
    mask = q_pos[:, None] >= k_pos[None, :]
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)

    # values through the latent as well: out_h = probs · latent @ wv_b
    ctx_lat = jnp.einsum("bhqs,bsl->bqhl", probs, latent_all.astype(jnp.float32))
    wv_b = p["wv_b"]["w"].reshape(cfg.kv_lora_rank, h, dv)
    out = jnp.einsum("bqhl,lhd->bqhd", ctx_lat, wv_b.astype(jnp.float32))
    out = ctx.shard(out.astype(x.dtype), ctx.data_axes, None, ctx.tp_axis, None)
    return dense(p["wo"], out.reshape(B, Sq, h * dv)), cache
