"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block layout (Griffin "recurrent block"):

    x ->  W_in_gate -> GeLU ------------------\
    x ->  W_in      -> causal conv1d -> RG-LRU -> (*) -> W_out

RG-LRU recurrence (diagonal, elementwise over the lru width):

    r_t = sigmoid(W_a u_t + b_a)              (recurrence gate)
    i_t = sigmoid(W_x u_t + b_x)              (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)    (decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill evaluates the linear recurrence with an associative scan
(O(log S) depth — the TPU-friendly replacement for the sequential CUDA scan
the original implements); decode is a single-step update carrying (h, conv
window) state. This is also the compute pattern of the Pallas
``rglru_scan`` kernel.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import MeshCtx, dense, init_dense

__all__ = ["RGLRUState", "init_rglru_block", "rglru_block", "init_rglru_state"]

_DECAY_C = 8.0


@dataclasses.dataclass
class RGLRUState:
    """Decode state: recurrence vector + trailing conv inputs."""

    h: jax.Array      # (B, W)
    conv: jax.Array   # (B, conv_width - 1, W)

    def tree_flatten(self):
        return (self.h, self.conv), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    RGLRUState, RGLRUState.tree_flatten, RGLRUState.tree_unflatten
)


def init_rglru_state(batch: int, cfg: ModelConfig, dtype) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    )


def init_rglru_block(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    lam = jax.random.uniform(ks[0], (w,), jnp.float32, 0.3, 0.8)
    return {
        "w_in": init_dense(ks[1], d, w, dtype),
        "w_gate": init_dense(ks[2], d, w, dtype),
        "conv_w": jax.random.normal(ks[3], (cfg.conv_width, w), dtype) * 0.1,
        "conv_b": jnp.zeros((w,), dtype),
        "wa": init_dense(ks[4], w, w, dtype, bias=True),
        "wx": init_dense(ks[5], w, w, dtype, bias=True),
        # Lambda parameterized so softplus(lambda_raw) > 0.
        "lambda_raw": jnp.log(jnp.expm1(lam)),
        "w_out": init_dense(ks[6], w, d, dtype, scale=w ** -0.5),
    }


def _causal_conv(p: dict, u: jax.Array, history: jax.Array | None) -> jax.Array:
    """Per-channel causal conv. u: (B, S, W); history: (B, cw-1, W) or None."""
    cw = p["conv_w"].shape[0]
    if history is None:
        history = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    padded = jnp.concatenate([history, u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(cw):
        out = out + padded[:, i : i + u.shape[1]] * p["conv_w"][i]
    return out + p["conv_b"]


def _lru_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t over axis 1, given h_0. a/b: (B, S, W) f32."""
    # Fold the initial state into the first step, then run the associative
    # scan for the linear recurrence composition (a2, b2)∘(a1, b1) =
    # (a1*a2, a2*b1 + b2).
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(
    p: dict,
    x: jax.Array,               # (B, S, d)
    ctx: MeshCtx,
    cfg: ModelConfig,
    state: RGLRUState | None = None,
) -> tuple[jax.Array, RGLRUState | None]:
    B, S, _ = x.shape
    gate = jax.nn.gelu(dense(p["w_gate"], x))
    u = dense(p["w_in"], x)
    u = ctx.shard_features(u)

    history = state.conv if state is not None else None
    u = _causal_conv(p, u, history)
    new_conv = None
    if state is not None:
        cw = p["conv_w"].shape[0]
        # Keep the last cw-1 raw inputs for the next decode step.
        tail = jnp.concatenate([state.conv, dense(p["w_in"], x)], axis=1)[:, -(cw - 1):]
        new_conv = tail

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(p["wa"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["wx"], u).astype(jnp.float32))
    log_a = -_DECAY_C * jax.nn.softplus(p["lambda_raw"]) * r       # (B,S,W) f32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9, None)) * (i * uf)

    h0 = state.h if state is not None else jnp.zeros((B, u.shape[-1]), jnp.float32)
    if S == 1:  # decode fast path
        h = (a[:, 0] * h0 + b[:, 0])[:, None]
    else:
        h = _lru_scan(a, b, h0)
    new_state = None
    if state is not None:
        new_state = RGLRUState(h=h[:, -1], conv=new_conv)

    y = (h.astype(x.dtype) * gate)
    y = ctx.shard_features(y)
    return dense(p["w_out"], y), new_state
