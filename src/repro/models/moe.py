"""Routed mixture-of-experts with gather-based dispatch and explicit
expert-parallel all-to-alls (shard_map).

Top-k routing with optional shared experts (DeepSeek-V3: 1 shared + 256
routed, top-8; Granite: 32 routed, top-8).

Design notes
------------
* Dispatch is **gather-based**, not one-hot-einsum based: a (E, C) slot
  table maps expert capacity slots to source token indices, and expert
  input buffers are plain gathers. The classic T5X einsum dispatch costs
  2·T·d·E·C FLOPs — at E=256 that is ~100x the expert matmuls themselves;
  gathers cost only bytes.
* Expert parallelism is explicit shard_map:

  - **full EP** (``E % (data*model ranks) == 0``): experts shard over the
    combined ("data", "model") group — DeepSeek-V3's 256 experts land one
    per chip on the 256-chip pod; expert weights never move, and dispatch/
    return are all-to-alls over the combined group (the inherent top-k
    token exchange). FSDP-sharding expert weights instead costs an
    all-gather of every expert tensor at every layer (~260 GB/device/step
    measured on deepseek-v3 train_4k).
  - **TP-axis EP** ("a2a" with E % tp == 0): experts shard over the model
    axis only; tokens re-shard seq over TP for the block.
  - **replicated EP** (``moe_ep_mode="replicated"`` or decode): tokens stay
    replicated over TP; each rank computes its local experts' slots and one
    psum over TP combines the outputs — cheapest when (B,S,d) resharding
    would dwarf the expert compute (Granite's d_model=1024) and for S=1
    decode steps.

* Fixed capacity per device: C = ceil(t_local · k / E · capacity_factor);
  overflow tokens fall through on the residual path (standard).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import MeshCtx, init_mlp, mlp

__all__ = ["init_moe", "moe_block"]


def init_moe(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    k_router, k_experts, k_shared = jax.random.split(key, 3)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ke = jax.random.split(k_experts, 3)
    p = {
        "router": {"w": jax.random.normal(k_router, (d, E), jnp.float32) * d ** -0.5},
        "experts": {
            "w_gate": jax.random.normal(ke[0], (E, d, f), dtype) * d ** -0.5,
            "w_up": jax.random.normal(ke[1], (E, d, f), dtype) * d ** -0.5,
            "w_down": jax.random.normal(ke[2], (E, f, d), dtype) * f ** -0.5,
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(k_shared, d, cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


def _route(tokens: jax.Array, router_w: jax.Array, k: int):
    """Top-k routing. tokens: (t, d) -> gates (t, k), ids (t, k), aux loss."""
    t = tokens.shape[0]
    logits = tokens.astype(jnp.float32) @ router_w            # (t, E)
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss from local statistics.
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0 / (t * k))
    aux = E * jnp.sum(me * ce)
    return gate_vals, expert_ids, aux


def _slot_tables(expert_ids: jax.Array, E: int, capacity: int):
    """Slot bookkeeping. Returns (slot_token (E*C,), token_slot (t,k), keep (t,k)).

    ``slot_token`` maps each expert-capacity slot to the source token index
    (sentinel t for empty slots); ``token_slot`` maps each (token, choice) to
    its flat slot (sentinel E*C when dropped for overflow).
    """
    t, k = expert_ids.shape
    onehot = jax.nn.one_hot(expert_ids.reshape(-1), E, dtype=jnp.int32)  # (t*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)                          # pos within expert
    pos = (pos * onehot).sum(-1).reshape(t, k)
    keep = pos < capacity
    flat_slot = expert_ids * capacity + pos                              # (t, k)
    token_slot = jnp.where(keep, flat_slot, E * capacity)
    token_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k))
    slot_token = jnp.full((E * capacity + 1,), t, jnp.int32)
    slot_token = slot_token.at[token_slot.reshape(-1)].set(
        token_idx.reshape(-1).astype(jnp.int32), mode="drop"
    )[: E * capacity]
    return slot_token, token_slot, keep


def _expert_ffn(experts: dict, buf: jax.Array) -> jax.Array:
    """buf: (E_local, C_all, d) -> same; weights (E_local, d, f) local."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, experts["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, experts["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, experts["w_down"])


def _moe_a2a(tokens, router_w, experts, cfg: ModelConfig, ep: int, ep_axes):
    """EP over the ``ep_axes`` group: dispatch/return all-to-alls.

    tokens: (t_local, d) — every rank in the EP group holds distinct tokens.
    experts: (E/ep, d, f) local shard.
    """
    t, d = tokens.shape
    E, k = cfg.n_experts, cfg.top_k
    E_local = E // ep
    capacity = max(int(t * k / E * cfg.capacity_factor), 4)

    gates, ids, aux = _route(tokens, router_w, k)
    slot_token, token_slot, keep = _slot_tables(ids, E, capacity)

    tokens_pad = jnp.concatenate([tokens, jnp.zeros((1, d), tokens.dtype)], axis=0)
    buf = tokens_pad[slot_token].reshape(E, capacity, d)

    if ep > 1:
        # exchange expert shards: every rank keeps E_local experts' slots
        # from every peer: (E, C, d) -> (E_local, ep*C, d).
        buf = buf.reshape(ep, E_local, capacity, d)
        buf = jax.lax.all_to_all(buf, ep_axes, split_axis=0, concat_axis=0,
                                 tiled=False)
        buf = buf.transpose(1, 0, 2, 3).reshape(E_local, ep * capacity, d)

    out_buf = _expert_ffn(experts, buf)

    if ep > 1:
        out_buf = out_buf.reshape(E_local, ep, capacity, d).transpose(1, 0, 2, 3)
        out_buf = jax.lax.all_to_all(out_buf, ep_axes, split_axis=0,
                                     concat_axis=0, tiled=False)
        out_buf = out_buf.reshape(E, capacity, d)

    flat = jnp.concatenate(
        [out_buf.reshape(E * capacity, d), jnp.zeros((1, d), out_buf.dtype)], axis=0
    )
    per_choice = flat[token_slot]                             # (t, k, d) gather
    w = (gates * keep).astype(tokens.dtype)
    return jnp.einsum("tkd,tk->td", per_choice, w), aux


def _moe_replicated_ep(tokens, router_w, experts_local, cfg: ModelConfig,
                       tp: int, axis: str):
    """EP with tokens replicated over the TP axis.

    Every TP rank sees the same tokens and routes identically; each rank
    processes only its local experts' slots and one psum over TP combines
    the partial outputs. No (B, S, d) resharding around the block.
    """
    t, d = tokens.shape
    E, k = cfg.n_experts, cfg.top_k
    E_local = E // tp
    capacity = max(int(t * k / E * cfg.capacity_factor), 4)

    gates, ids, aux = _route(tokens, router_w, k)
    slot_token, token_slot, keep = _slot_tables(ids, E, capacity)

    rank = jax.lax.axis_index(axis)
    lo = rank * E_local * capacity
    local_slots = jax.lax.dynamic_slice_in_dim(
        slot_token, lo, E_local * capacity, axis=0
    )
    tokens_pad = jnp.concatenate([tokens, jnp.zeros((1, d), tokens.dtype)], axis=0)
    buf = tokens_pad[local_slots].reshape(E_local, capacity, d)
    out_buf = _expert_ffn(experts_local, buf)

    flat_global = jnp.zeros((E * capacity + 1, d), out_buf.dtype)
    flat_global = jax.lax.dynamic_update_slice_in_dim(
        flat_global, out_buf.reshape(E_local * capacity, d), lo, axis=0
    )
    flat_global = jax.lax.psum(flat_global, axis)
    per_choice = flat_global[token_slot]                     # (t, k, d)
    w = (gates * keep).astype(tokens.dtype)
    return jnp.einsum("tkd,tk->td", per_choice, w), aux


def moe_block(
    p: dict, x: jax.Array, ctx: MeshCtx, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss). x: (B, S, d)."""
    B, S, d = x.shape

    dp_size = ctx.axis_size(ctx.data_axes) if ctx.mesh is not None else 1
    if ctx.mesh is None or B % dp_size != 0:
        # No mesh (or indivisible batch, e.g. tiny smoke tests): plain XLA
        # auto-sharded computation, no explicit shard_map.
        out, aux = _moe_a2a(
            x.reshape(B * S, d), p["router"]["w"], p["experts"], cfg,
            ep=1, ep_axes=None,
        )
        out = out.reshape(B, S, d)
    else:
        tp_axis = ctx.tp_axis
        tp = ctx.axis_size(tp_axis)
        dp_axes = ctx.data_axes
        E = cfg.n_experts

        # EP group selection (see module docstring). The full group is the
        # intra-pod "data" axis (when the mesh has one — pure-TP meshes
        # don't) plus the TP axis. Deliberately NOT ctx.data_axes: that may
        # include the cross-pod "pod" axis, and expert all-to-alls over DCN
        # would dwarf the expert compute — EP stays within a pod.
        full_ep_axes = tuple(
            a for a in ("data",) if a in ctx.mesh.shape
        ) + (tp_axis,)
        full_ep = int(np.prod([ctx.mesh.shape[a] for a in full_ep_axes]))
        seq_shardable = S % tp == 0 and cfg.moe_ep_mode != "replicated"
        if seq_shardable and E % full_ep == 0:
            mode, ep_axes, ep = "a2a", full_ep_axes, full_ep
        elif seq_shardable and E % tp == 0:
            mode, ep_axes, ep = "a2a", (tp_axis,), tp
        elif E % tp == 0:
            mode, ep_axes, ep = "replicated", (tp_axis,), tp
        else:
            raise ValueError(f"n_experts ({E}) must divide the TP axis ({tp})")

        token_spec = (
            P(dp_axes, tp_axis, None) if mode == "a2a" else P(dp_axes, None, None)
        )
        wspec = P(ep_axes if mode == "a2a" else tp_axis, None, None)
        weight_specs = {"w_gate": wspec, "w_up": wspec, "w_down": wspec}

        def body(xs, router_w, experts):
            b, s, _ = xs.shape
            flat = xs.reshape(b * s, d)
            if mode == "a2a":
                out, aux = _moe_a2a(flat, router_w, experts, cfg,
                                    ep=ep, ep_axes=ep_axes)
            else:
                out, aux = _moe_replicated_ep(flat, router_w, experts, cfg,
                                              tp=tp, axis=tp_axis)
            # aux loss averaged over the whole mesh.
            for a in ctx.mesh.axis_names:
                aux = jax.lax.pmean(aux, a)
            return out.reshape(b, s, d), aux

        out, aux = jax.shard_map(
            body,
            mesh=ctx.mesh,
            in_specs=(token_spec, P(None, None), weight_specs),
            out_specs=(token_spec, P()),
        )(x, p["router"]["w"], p["experts"])

    if "shared" in p:
        out = out + mlp(p["shared"], x.reshape(B * S, d), ctx).reshape(B, S, d)
    return out, aux
