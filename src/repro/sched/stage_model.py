"""LM stage-graph extraction: an architecture becomes a paper-style
topology whose components are pipeline stages.

The model is cut into ``n_stages`` contiguous stages (embed folded into the
first, lm head into the last). Each stage gets an analytic per-token cost
on every device pool — roofline seconds per token on one group of that
pool — which plays exactly the role of the paper's ``e_ij`` profiling
table (units: fraction-of-group-seconds per token/s, scaled to the 100-
point machine budget of ``repro.core``). Stage graphs are linear (alpha=1
chains): every token flows through every stage; MoE fan-out stays inside a
stage (its cost reflects the active-expert FLOPs).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import UserGraph
from repro.core.profiles import Cluster, Profile
from repro.models.config import ModelConfig
from repro.roofline import param_counts
from repro.sched.fleet import Fleet

__all__ = ["StageModel", "build_stage_model", "fleet_cluster"]


@dataclasses.dataclass(frozen=True)
class StageModel:
    utg: UserGraph
    profile: Profile
    flops_per_token: np.ndarray   # (n_stages,) forward FLOPs per token
    bytes_per_token: np.ndarray   # (n_stages,) weight bytes touched per token


def build_stage_model(
    cfg: ModelConfig,
    fleet: Fleet,
    n_stages: int = 4,
    decode: bool = True,
    met_points: float = 0.5,
) -> StageModel:
    """Cut the model into stages and profile them against fleet pools."""
    counts = param_counts(cfg)
    n_active = counts["active"]
    L = cfg.n_layers
    n_stages = min(n_stages, L)
    per_stage_layers = [
        L // n_stages + (1 if i < L % n_stages else 0) for i in range(n_stages)
    ]
    embed_params = cfg.vocab_size * cfg.d_model
    body = max(n_active - embed_params * (1 if cfg.tie_embeddings else 2), 0)
    layer_params = body / L

    flops, wbytes = [], []
    for i, nl in enumerate(per_stage_layers):
        p = layer_params * nl
        if i == 0:
            p += embed_params * 0.02  # embedding lookups: bytes, not matmul
        if i == n_stages - 1:
            p += embed_params        # lm head matmul
        flops.append(2.0 * p)        # fwd matmul FLOPs per token
        wbytes.append(2.0 * p)       # bf16 weight bytes per token (decode:
                                     # memory-bound weight streaming)

    flops = np.asarray(flops)
    wbytes = np.asarray(wbytes)

    # e_ij: seconds-per-token of stage i on one group of pool j, as
    # 100-point capacity units (100 points == 1 group-second per second).
    e = np.zeros((n_stages + 1, len(fleet.pools)))
    met = np.zeros_like(e)
    for j, pool in enumerate(fleet.pools):
        for i in range(n_stages):
            t_comp = flops[i] / pool.group_flops
            t_mem = (wbytes[i] / pool.group_hbm_bw) if decode else 0.0
            e[i + 1, j] = max(t_comp, t_mem) * 100.0
        # source component (request ingress): negligible compute
        e[0, j] = 1e-4
        met[:, j] = met_points

    types = np.arange(n_stages + 1)
    types[0] = 0
    utg = UserGraph(
        name=f"{cfg.name}-{n_stages}stages",
        component_types=types,
        edges=tuple((i, i + 1) for i in range(n_stages)),
        alpha=np.ones(n_stages + 1),
    )
    profile = Profile(
        e=e,
        met=met,
        type_names=tuple(["ingress"] + [f"stage{i}" for i in range(n_stages)]),
        machine_type_names=tuple(p.name or p.chip.name for p in fleet.pools),
    )
    return StageModel(utg=utg, profile=profile,
                      flops_per_token=flops, bytes_per_token=wbytes)


def fleet_cluster(fleet: Fleet, stage_model: StageModel) -> Cluster:
    """Fleet -> core.Cluster: one machine per device group."""
    return Cluster(
        machine_types=fleet.pool_of_group(),
        capacity=np.full(fleet.n_groups, 100.0),
        profile=stage_model.profile,
    )
