"""Heterogeneous accelerator fleet descriptions (the "machines" of the paper).

A fleet is a set of device *pools*; each pool is a number of identical
device groups (e.g. a TPU v5e pod slice hosting one model replica, or a
single chip). Pools play the role of the paper's machine types; the
per-(stage, pool) step-time model plays the role of the e_ij profiling
table; a pool member's step-time budget plays the role of the 100-point CPU
capacity.

Hardware constants (TPU v5e, per chip) — the same constants used by the
roofline analysis:

* peak bf16 compute: 197 TFLOP/s
* HBM bandwidth:     819 GB/s
* ICI link bandwidth: ~50 GB/s/link
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "TPU_V5E",
    "ChipSpec",
    "DevicePool",
    "Fleet",
    "v5e_pod_fleet",
]


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip hardware constants."""

    name: str
    peak_flops: float       # FLOP/s (bf16)
    hbm_bw: float           # bytes/s
    ici_bw: float           # bytes/s per link
    hbm_bytes: float        # capacity

    def step_seconds(self, flops: float, bytes_moved: float, coll_bytes: float) -> float:
        """Roofline step time: max of the three terms (no overlap assumed)."""
        return max(
            flops / self.peak_flops,
            bytes_moved / self.hbm_bw,
            coll_bytes / self.ici_bw,
        )


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
)

# Hypothetical older/newer generations for heterogeneous fleets; ratios are
# representative of real TPU generation gaps (v4 ~ 275 bf16 TFLOP/s but
# 1.2 TB/s HBM; an "edge" flavor far weaker) — what matters to the planner
# is that per-(stage, pool) speeds differ non-uniformly, exactly the
# heterogeneity structure of the paper's Table 3.
TPU_V4 = ChipSpec("tpu_v4", peak_flops=275e12, hbm_bw=1228e9, ici_bw=45e9, hbm_bytes=32e9)
TPU_LITE = ChipSpec("tpu_lite", peak_flops=45e12, hbm_bw=300e9, ici_bw=25e9, hbm_bytes=8e9)


@dataclasses.dataclass(frozen=True)
class DevicePool:
    """``count`` identical device groups of ``chips_per_group`` chips each.

    One group hosts one model replica (TP spans the group); a group is the
    paper's "machine".
    """

    chip: ChipSpec
    count: int
    chips_per_group: int = 1
    name: str = ""

    @property
    def group_flops(self) -> float:
        return self.chip.peak_flops * self.chips_per_group

    @property
    def group_hbm_bw(self) -> float:
        return self.chip.hbm_bw * self.chips_per_group

    @property
    def group_hbm_bytes(self) -> float:
        return self.chip.hbm_bytes * self.chips_per_group


@dataclasses.dataclass(frozen=True)
class Fleet:
    pools: tuple[DevicePool, ...]

    @property
    def n_groups(self) -> int:
        return sum(p.count for p in self.pools)

    def pool_of_group(self) -> np.ndarray:
        """(n_groups,) pool index per device group."""
        return np.concatenate(
            [np.full(p.count, i, dtype=np.int64) for i, p in enumerate(self.pools)]
        )


def v5e_pod_fleet(n_pods: int = 2, groups_per_pod: int = 16, chips_per_group: int = 16) -> Fleet:
    """The production mesh as a homogeneous fleet: n_pods × 256 chips."""
    return Fleet(
        pools=(
            DevicePool(
                chip=TPU_V5E,
                count=n_pods * groups_per_pod,
                chips_per_group=chips_per_group,
                name="v5e",
            ),
        )
    )
