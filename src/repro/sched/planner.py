"""Heterogeneous serving planner: the paper's algorithm as a first-class
framework feature.

``plan(cfg, fleet)`` builds the stage graph (repro.sched.stage_model), runs
FirstAssignment + MaximizeThroughput (+ the local-search refinement) over
the fleet's device groups, and returns a ParallelismPlan: how many replicas
of each pipeline stage run on which pool, and the max stable token
admission rate — the LM-serving incarnation of the paper's execution
topology graph.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import max_stable_rate, round_robin_schedule, schedule
from repro.core.refine import refine
from repro.models.config import ModelConfig
from repro.sched.fleet import Fleet
from repro.sched.stage_model import build_stage_model, fleet_cluster

__all__ = ["ParallelismPlan", "plan"]


@dataclasses.dataclass
class ParallelismPlan:
    arch: str
    n_stages: int
    # replicas[stage][pool] = number of stage replicas hosted by that pool
    replicas: np.ndarray
    assignment: list[np.ndarray]      # per-stage group indices
    tokens_per_s: float               # max stable admission rate
    predicted_throughput: float       # paper objective (sum of stage rates)
    baseline_tokens_per_s: float      # round-robin placement baseline
    iterations: int

    def summary(self) -> str:
        lines = [
            f"plan[{self.arch}] stages={self.n_stages} "
            f"admission={self.tokens_per_s:,.0f} tok/s "
            f"(round-robin baseline {self.baseline_tokens_per_s:,.0f} tok/s)"
        ]
        for s in range(self.replicas.shape[0]):
            pools = ", ".join(
                f"pool{j}x{int(c)}" for j, c in enumerate(self.replicas[s]) if c
            )
            lines.append(f"  stage{s}: {pools}")
        return "\n".join(lines)


def plan(
    cfg: ModelConfig,
    fleet: Fleet,
    n_stages: int = 4,
    r0: float = 1.0,
    use_refine: bool = True,
) -> ParallelismPlan:
    sm = build_stage_model(cfg, fleet, n_stages=n_stages)
    cluster = fleet_cluster(fleet, sm)

    sched = schedule(sm.utg, cluster, r0=r0, rate_epsilon=max(r0, 1.0))
    etg = sched.etg
    if use_refine and etg.total_tasks <= 64 and cluster.n_machines <= 64:
        etg = refine(etg, cluster).etg
    rate, thpt = max_stable_rate(etg, cluster)

    rr = round_robin_schedule(sm.utg, cluster, etg.n_instances)
    rr_rate, _ = max_stable_rate(rr, cluster)

    pool_of = fleet.pool_of_group()
    n_pools = len(fleet.pools)
    reps = np.zeros((sm.utg.n_components, n_pools), dtype=np.int64)
    for comp in range(sm.utg.n_components):
        for g in etg.assignment[comp]:
            reps[comp, pool_of[g]] += 1

    return ParallelismPlan(
        arch=cfg.name,
        n_stages=n_stages,
        replicas=reps[1:],           # drop the ingress component
        assignment=etg.assignment[1:],
        tokens_per_s=float(rate),
        predicted_throughput=float(thpt),
        baseline_tokens_per_s=float(rr_rate),
        iterations=sched.iterations,
    )
