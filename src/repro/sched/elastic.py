"""Elastic re-planning on fleet changes — the paper's "by any change in the
cluster state, this algorithm can be used to recalculate the new number of
instances and their suitable assignment", wired to the runtime.

``ElasticController`` tracks the healthy group set; ``fail()`` /
``restore()`` mutate it and re-run the planner, producing a new
ParallelismPlan and a new admission rate. The trainer's straggler hook and
the serve example both drive this.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig
from repro.sched.fleet import DevicePool, Fleet
from repro.sched.planner import ParallelismPlan, plan

__all__ = ["ElasticController"]


@dataclasses.dataclass
class _PoolState:
    pool: DevicePool
    healthy: int


class ElasticController:
    def __init__(self, cfg: ModelConfig, fleet: Fleet, n_stages: int = 4):
        self.cfg = cfg
        self._pools = [_PoolState(p, p.count) for p in fleet.pools]
        self.n_stages = n_stages
        self.history: list[tuple[str, ParallelismPlan]] = []
        self.current = self._replan("initial")

    def _fleet(self) -> Fleet:
        return Fleet(pools=tuple(
            dataclasses.replace(ps.pool, count=ps.healthy)
            for ps in self._pools if ps.healthy > 0
        ))

    def _replan(self, reason: str) -> ParallelismPlan:
        p = plan(self.cfg, self._fleet(), n_stages=self.n_stages)
        self.history.append((reason, p))
        return p

    def fail(self, pool_idx: int, count: int = 1) -> ParallelismPlan:
        """Mark ``count`` groups of a pool failed; re-plan the remainder."""
        ps = self._pools[pool_idx]
        ps.healthy = max(ps.healthy - count, 0)
        self.current = self._replan(f"fail pool{pool_idx} x{count}")
        return self.current

    def restore(self, pool_idx: int, count: int = 1) -> ParallelismPlan:
        ps = self._pools[pool_idx]
        ps.healthy = min(ps.healthy + count, ps.pool.count)
        self.current = self._replan(f"restore pool{pool_idx} x{count}")
        return self.current

    @property
    def admission_rate(self) -> float:
        return self.current.tokens_per_s
