"""AdamW with global-norm clipping, schedules, and optional gradient
compression — self-contained (no optax dependency).

State layout mirrors params (m, v trees) plus a scalar step counter; the
state dtype is configurable (``cfg.opt_state_dtype``) so 671B-class models
can keep moments in bf16 and stay within HBM.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "init_opt_state",
    "adamw_update",
    "cosine_schedule",
    "global_norm",
    "clip_by_global_norm",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def adamw_update(
    params: Any,
    grads: Any,
    state: dict,
    cfg: AdamWConfig,
    lr_fn: Callable | None = None,
) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_fn(step) if lr_fn is not None else jnp.asarray(cfg.lr, jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(sdt),
            v32.astype(sdt),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)

    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
