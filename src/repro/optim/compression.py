"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick for the multi-pod mesh).

Two composable pieces:

* ``to_bf16`` / ``from_bf16`` — cast gradients to bf16 before the (pjit-
  induced) all-reduce; halves cross-pod ICI bytes at negligible quality
  cost for LM training.
* ``Int8ErrorFeedback`` — per-tensor int8 quantization with an error-
  feedback residual carried in the optimizer loop (1-bit-Adam style, at 8
  bits): quantize(g + residual) is reduced; the de-quantization error is
  fed back next step so the compression bias vanishes in expectation.

The training step applies compression *before* grads cross the pod axis —
under pjit this is expressed by casting the grad tree, which XLA propagates
into the all-reduce collective itself.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["to_bf16", "from_f32", "init_residual", "quantize_ef", "dequantize"]


def to_bf16(grads: Any) -> Any:
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def from_f32(grads: Any, like: Any) -> Any:
    return jax.tree.map(lambda g, p: g.astype(p.dtype), grads, like)


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_ef(grads: Any, residual: Any) -> tuple[Any, Any, Any]:
    """int8 error-feedback quantization.

    Returns (q_int8_tree, scales_tree, new_residual_tree). Quantization is
    symmetric per tensor: q = round(g / s), s = max|g| / 127.
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        s = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
        new_r = g32 - q.astype(jnp.float32) * s
        return q, s, new_r

    flat, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, ss, rs = zip(*[one(g, r) for g, r in zip(flat, flat_r)])
    return (
        jax.tree.unflatten(treedef, qs),
        jax.tree.unflatten(treedef, ss),
        jax.tree.unflatten(treedef, rs),
    )


def dequantize(q: Any, scales: Any) -> Any:
    return jax.tree.map(lambda qq, s: qq.astype(jnp.float32) * s, q, scales)
