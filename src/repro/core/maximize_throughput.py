"""Algorithm 2 — MaximizeThroughput (paper §5.4).

Progressive scale-up: starting from the minimal ETG of Algorithm 1 at rate
R0, repeatedly

1. predict MACs at the current rate (eq. 5/6);
2. if no machine is over-utilized: commit the state as the latest stable
   schedule and raise the rate by ``Current_IR / Scale``;
3. otherwise: take a new instance of the component owning the *hottest*
   task on the *first* over-utilized machine and place it on the most
   suitable machine (least predicted TCU among machines that keep the whole
   schedule feasible); adding an instance re-splits that component's stream
   (eq. 6) and relieves the hot machine;
4. if no machine can host the new instance: halve the rate increment
   (``Scale *= 2``), roll back to the latest stable schedule, and retry;
5. terminate when the increment is exhausted (``Current_IR <= Scale`` in the
   paper; equivalently the next additive increment drops below a rate
   epsilon) — the cluster is saturated.

Returns the final stable ETG, its input rate, and an iteration trace used by
benchmarks and tests.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cost_model
from repro.core.first_assignment import first_assignment
from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = ["Schedule", "maximize_throughput", "schedule"]


@dataclasses.dataclass
class Schedule:
    """Result of the proposed scheduler.

    Attributes:
      etg: final execution topology graph with placement.
      rate: maximum stable topology input rate found.
      predicted_throughput: eq. 2 objective at ``rate``.
      iterations: number of Algorithm-2 loop iterations.
      trace: (iteration, event, rate) tuples for inspection.
    """

    etg: ExecutionGraph
    rate: float
    predicted_throughput: float
    iterations: int
    trace: list[tuple[int, str, float]]


def _least_tcu_machine(tcu: np.ndarray, head: np.ndarray) -> int | None:
    """Machine with the least (9-digit-quantized) TCU among those whose
    remaining head is >= 0; ties break toward most remaining head.

    The single copy of the placement tie-break rule: greedy growth (both
    engines, via ``_greedy_place``) and the streaming runtime's
    dead-machine evacuation select machines through this exact lexsort,
    so the rule cannot drift between paths. Returns None when no machine
    has head.
    """
    feasible = head >= 0.0
    if not np.any(feasible):
        return None
    cand_tcu = np.where(feasible, tcu, np.inf)
    return int(np.lexsort((-head, np.round(cand_tcu, 9)))[0])


def _greedy_place(
    capacity: np.ndarray,
    base_load: np.ndarray,
    existing_counts: np.ndarray,
    tcu: np.ndarray,
    k: int,
    max_new: np.ndarray | None = None,
) -> list[int] | None:
    """Greedily place ``k`` equal chunks of per-machine cost ``tcu``.

    Shared by the reference and incremental engines — the engines'
    equivalence contract depends on this exact feasibility check, lexsort
    tie-breaking and float accumulation order, so there is one copy.

    ``max_new`` optionally caps the number of *new* chunks per machine (the
    hard memory constraint on resource-vector clusters); ``None`` — the
    default and the scalar-CPU path — leaves the rule untouched.

    Returns the chosen machines in placement order, or None if some chunk
    does not fit.
    """
    load = base_load + existing_counts * tcu
    budget = None if max_new is None else np.asarray(max_new, dtype=np.float64).copy()
    placed: list[int] = []
    for _ in range(k):
        head = capacity - (load + tcu)
        if budget is not None:
            head = np.where(budget >= 1.0, head, -np.inf)
        w = _least_tcu_machine(tcu, head)
        if w is None:
            return None
        placed.append(w)
        load[w] += tcu[w]
        if budget is not None:
            budget[w] -= 1.0
    return placed


def _grow_component(
    etg: ExecutionGraph,
    cluster: Cluster,
    component: int,
    rate: float,
    max_extra: int | None = None,
) -> ExecutionGraph | None:
    """Grow ``component`` by the smallest number of new instances that fit.

    Faithful core ("take new instance ... if there is enough capacity to map
    the new instance"): a machine w hosts a new instance iff w stays within
    capacity once the component's stream is re-split (eq. 6). The usual case
    adds exactly one instance.

    Generalization (documented in docs/architecture.md §Multi-instance
    growth generalization): on
    large heterogeneous clusters a *single* extra instance can still carry a
    chunk (``CIR/(N+1)``) too big for any machine with remaining capacity —
    e.g. slow machine types need chunks several times smaller than the fast
    type's. The paper's own Table 4 instance counts (hundreds per component)
    are unreachable under a strict one-at-a-time rule, so when k=1 fails we
    search the smallest target N' > N whose per-instance chunk packs: new
    instances are placed greedily by least predicted TCU among machines that
    keep the placement within capacity. Existing instances never move.

    Returns the grown ETG, or None if no target up to the cap packs.
    """
    utg = etg.utg
    cir = cost_model.component_rates(utg, rate)[component]
    n0 = int(etg.n_instances[component])
    m = cluster.n_machines
    ctype = int(utg.component_types[component])
    e_row = cluster.profile.e[ctype][cluster.machine_types]      # (m,)
    met_row = cluster.profile.met[ctype][cluster.machine_types]  # (m,)

    # Machine load from everything except this component's variable part.
    pred = cost_model.predict(etg, cluster, rate)
    comp_mask = etg.task_component() == component
    machines_of_c = etg.task_machine()[comp_mask]
    base_load = pred.machine_util.copy()
    np.add.at(base_load, machines_of_c, -pred.tcu[comp_mask])
    existing_counts = np.bincount(machines_of_c, minlength=m)

    max_target = n0 + (max_extra if max_extra is not None else max(2 * n0, 2 * m, 16))
    for target in range(n0 + 1, max_target + 1):
        per_ir = cir / target
        tcu = e_row * per_ir + met_row                           # (m,) per new chunk
        # base_load + existing_counts * tcu: siblings re-split (eq. 6)
        placed = _greedy_place(
            cluster.capacity, base_load, existing_counts, tcu, target - n0
        )
        if placed is None:
            continue
        grown = etg
        for w in placed:
            grown = grown.with_new_instance(component, w)
        return grown
    return None


def maximize_throughput(
    etg: ExecutionGraph,
    cluster: Cluster,
    r0: float,
    rate_epsilon: float = 1.0,
    max_iters: int = 100_000,
    engine: str = "incremental",
) -> Schedule:
    """Algorithm 2, faithful to the paper's control flow.

    ``engine`` selects the implementation: ``"incremental"`` (default) runs
    the flat-ScheduleState engine in ``schedule_state.py`` — same decisions,
    same trace, ~2 orders of magnitude faster on large clusters;
    ``"reference"`` runs the original copy-everything path below, kept as
    the semantic reference for the golden equivalence tests.
    """
    if engine == "incremental":
        from repro.core.schedule_state import maximize_throughput_incremental

        return maximize_throughput_incremental(
            etg, cluster, r0, rate_epsilon=rate_epsilon, max_iters=max_iters
        )
    if engine != "reference":
        raise ValueError(f"unknown engine {engine!r}; use 'incremental' or 'reference'")
    if cluster.has_resources:
        # The reference loop scores via ``predict`` (scalar-CPU eq. 5 only);
        # running it on a resource-vector cluster would silently optimize a
        # different objective than the incremental engine. Same contract as
        # skew-aware refine: resource clusters require the state engine.
        raise ValueError(
            "engine='reference' does not support memory/network resource "
            "clusters; use engine='incremental'"
        )
    scale = 1.0
    current = etg.copy()
    current_rate = float(r0)
    final = current.copy()
    final_rate = 0.0
    trace: list[tuple[int, str, float]] = []

    it = 0
    while it < max_iters:
        it += 1
        pred = cost_model.predict(current, cluster, current_rate)  # line 1
        if pred.feasible:                                          # line 2
            final = current.copy()                                 # line 3 (Final_ETG)
            final_rate = current_rate
            increment = current_rate / scale
            if increment < rate_epsilon:                           # saturated
                trace.append((it, "terminate", current_rate))
                break
            current_rate += increment                              # line 4
            trace.append((it, "raise_rate", current_rate))
            continue
        # Over-utilization: hottest task on the first over-utilized machine.
        over = np.flatnonzero(pred.over_utilized)
        first_over = int(over[0])
        machine = current.task_machine()
        on_machine = np.flatnonzero(machine == first_over)
        hottest = int(on_machine[np.argmax(pred.tcu[on_machine])])
        component = int(current.task_component()[hottest])         # line 6
        grown = _grow_component(current, cluster, component, current_rate)
        if grown is not None:                                      # line 7
            added = int(grown.n_instances[component] - current.n_instances[component])
            current = grown                                        # line 8
            trace.append((it, f"new_instance:c{component}x{added}", current_rate))
            continue
        # No candidate machine (lines 11-16).
        if current_rate > scale and final_rate > 0.0:
            scale *= 2.0                                           # line 12
            current = final.copy()                                 # line 13
            current_rate = final_rate + final_rate / scale
            trace.append((it, "backoff", current_rate))
            continue
        trace.append((it, "terminate", final_rate))
        break

    pred_final = cost_model.predict(final, cluster, final_rate)
    return Schedule(
        etg=final,
        rate=final_rate,
        predicted_throughput=pred_final.throughput,
        iterations=it,
        trace=trace,
    )


def schedule(
    utg: UserGraph,
    cluster: Cluster,
    r0: float = 1.0,
    rate_epsilon: float = 1.0,
    engine: str = "incremental",
) -> Schedule:
    """End-to-end proposed scheduler: Algorithm 1 then Algorithm 2."""
    etg0 = first_assignment(utg, cluster, r0)
    return maximize_throughput(
        etg0, cluster, r0, rate_epsilon=rate_epsilon, engine=engine
    )
