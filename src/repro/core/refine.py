"""Local-search refinement of a schedule (beyond-paper enhancement).

The paper's Algorithm 2 only ever *adds* instances; it can never rebalance
earlier placement decisions, so on profiles where task "chunks" pack
awkwardly it terminates at a local optimum measurably below the exhaustive
optimum. This pass closes that gap with a hill climb over these move types,
each scored by the closed-form maximum stable throughput (paper eq. 5/6 are
linear in the topology input rate, so no simulation is needed):

* RELOCATE — move one instance to a different machine;
* SWAP     — exchange the machines of two instances of different components;
* ADD      — grow one component by one instance on some machine;
* GROW     — grow one component by k instances at once, placed greedily;
* PAIRGROW — grow two components together (crosses eq. 6 re-split valleys);
* DROP     — remove an instance of a component with >= 2 instances (undoes
             over-provisioning that only burns MET overhead).

The climb applies the single best improving move until no move improves
throughput by more than ``tol`` (first-improvement would also work; best-
improvement keeps the trace short and deterministic).

Engines
-------
``engine="state"`` (default) runs the climb on the incremental
``ScheduleState`` engine: moves are O(m) count-matrix deltas (no
``ExecutionGraph`` copies), and each round's candidate set is scored
through vectorized ``max_stable_rate_batch`` calls — candidate placements
are exported as (B, T) task->machine matrices, greedy growth chains across
all components/pairs advance in depth-lockstep per-row-count sweeps (4 per
round), and every NumPy-scored candidate's score is bit-identical to the
reference path's scalar ``max_stable_rate``, so the two engines provably
choose the same moves. The default ``backend="auto"`` preserves that
contract below the per-regime dispatch crossovers (shared / per-row /
skew element floors plus a CPU machine-count gate, calibrated by
benchmarks/bench_dispatch.py) — which cover every golden/equivalence-suite
sweep by construction — and above them trades bit-exactness for the
scatter-free jitted JAX scorer (~1e-15 agreement: exact ties between
moves may break differently from ``engine="reference"``, with
equal-quality results; pass ``backend="numpy"`` to keep strict
replayability on hosts where sweeps cross). ``engine="reference"`` keeps the
original copy-and-score implementation as the semantic reference for the
golden equivalence tests (``tests/test_sched_equivalence.py``).

This module is *not* part of the faithful reproduction; benchmarks report
"proposed" (faithful Alg. 1+2) and "proposed+refine" separately. See
docs/architecture.md for the engine design and docs/api.md for usage.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cost_model
from repro.core.cost_model import max_stable_rate
from repro.core.graph import ExecutionGraph
from repro.core.profiles import Cluster
from repro.core.schedule_state import ScheduleState

__all__ = ["RefineResult", "refine"]

# Candidate rows scored per vectorized sweep; bounds the (chunk, T) batch
# memory on large clusters without changing results (rows are independent).
# Network-aware clusters tighten this further (see ``_effective_chunk``):
# the cut-traffic term expands every row into (n_components, m) scatter
# tensors plus distance matvecs, so the naive cap would materialize the
# full edge×machine product on wide topologies (regression-tested at m=90).
_SCORE_CHUNK = 16_384


def _effective_chunk(cluster: Cluster, n_components: int) -> int:
    """Rows per scoring sweep: ``_SCORE_CHUNK``, tightened on network-aware
    clusters so one sweep's distance-expanded accumulation stays within the
    ``cost_model._NET_CHUNK_ELEMS`` (chunk · n · m) element budget instead
    of relying on the inner chunking to re-split an oversized batch."""
    if not cluster.has_network:
        return _SCORE_CHUNK
    per_row = max(1, n_components * cluster.n_machines)
    return min(_SCORE_CHUNK, max(256, cost_model._NET_CHUNK_ELEMS // per_row))

# Total steps (prefix included) a depth-adaptive growth chain may reach —
# a runaway backstop far above any profitable chain, shared by the lockstep
# and sequential explorers so their stopping decisions are identical.
_ADAPTIVE_GROW_CAP = 64


@dataclasses.dataclass(frozen=True)
class RefineResult:
    etg: ExecutionGraph
    rate: float
    throughput: float
    moves: list[str]


def _score(etg: ExecutionGraph, cluster: Cluster) -> float:
    return max_stable_rate(etg, cluster)[1]


def refine(
    etg: ExecutionGraph,
    cluster: Cluster,
    max_rounds: int = 200,
    tol: float = 1e-9,
    allow_add: bool = True,
    engine: str = "state",
    backend: str = "auto",
    lockstep: bool = True,
    adaptive_growth: bool = False,
    skew: "cost_model.SkewModel | None" = None,
    recorder=None,
) -> RefineResult:
    """Hill-climb refinement of ``etg``'s placement (and instance counts).

    Args:
      etg: schedule to refine (not mutated).
      cluster: the heterogeneous cluster.
      max_rounds: maximum number of applied moves.
      tol: minimum throughput improvement for a move to be applied.
      allow_add: when False, only count-preserving moves (RELOCATE/SWAP)
        are considered.
      engine: ``"state"`` (incremental ScheduleState deltas + batched
        scoring, default) or ``"reference"`` (original per-candidate
        copy-and-score path). Both produce identical results.
      backend: scoring backend for the state engine's batched closed-form
        evaluator — ``"auto"`` (default: the bit-exact NumPy reference
        below the calibrated dispatch crossover, the jitted JAX kernel for
        large sweeps such as big-cluster RELOCATE+SWAP chunks; see
        benchmarks/bench_dispatch.py), ``"numpy"`` (always the reference
        floats), or ``"jax"`` (always the jitted float64 kernel, ~1e-15
        relative agreement). Ignored by the reference engine.
      lockstep: explore greedy growth chains in depth-lockstep sweeps (4
        per round regardless of component count, default) instead of one
        m-row sweep per chain step. Identical results either way; the
        sequential path is the benchmark baseline.
      adaptive_growth: keep extending growth chains past the reference
        menu's depth 4 while their closed-form score strictly improves
        (one extra sweep per depth), offering GROW k>4 and PAIRGROW
        (a, b>2) candidates the fixed menu cannot see. Off by default —
        the reference engine has no adaptive menu, so the golden
        equivalence contract covers the default; lockstep and sequential
        explorers produce identical adaptive results (tested). State
        engine only.
      skew: optional ``cost_model.SkewModel`` — every candidate (and the
        incumbent) scores with the skew-aware per-instance utilization
        bound instead of the eq. 6 even split, so growth offers on a
        component whose instances are skew-saturated cannot report
        even-split gains. State engine only; forces NumPy scoring.
      recorder: optional ``repro.obs.TraceRecorder``. When enabled, the
        climb runs under a ``refine`` span with one ``refine.round`` span
        per applied move (state engine), and the recorder is *activated*
        for the duration so every closed-form backend resolution during
        scoring lands in its dispatch log. ``None`` (or a
        ``NullRecorder``) adds no work to the climb.
    """
    rec = recorder if recorder is not None and recorder.enabled else None
    if engine == "state":
        if rec is None:
            return _refine_state(
                etg, cluster, max_rounds, tol, allow_add, backend, lockstep,
                adaptive_growth, skew,
            )
        with rec.activate(), rec.span(
            "refine", cat="refine", engine=engine, backend=backend
        ) as sp:
            result = _refine_state(
                etg, cluster, max_rounds, tol, allow_add, backend, lockstep,
                adaptive_growth, skew, recorder=rec,
            )
            sp["args"]["applied_moves"] = len(result.moves)
            sp["args"]["throughput"] = float(result.throughput)
        return result
    if engine != "reference":
        raise ValueError(f"unknown engine {engine!r}; use 'state' or 'reference'")
    if adaptive_growth:
        raise ValueError("adaptive_growth requires engine='state'")
    if skew is not None:
        raise ValueError("skew requires engine='state'")
    if rec is None:
        return _refine_reference(etg, cluster, max_rounds, tol, allow_add)
    with rec.activate(), rec.span(
        "refine", cat="refine", engine=engine, backend=backend
    ) as sp:
        result = _refine_reference(etg, cluster, max_rounds, tol, allow_add)
        sp["args"]["applied_moves"] = len(result.moves)
        sp["args"]["throughput"] = float(result.throughput)
    return result


# --------------------------------------------------------------- reference


def _refine_reference(
    etg: ExecutionGraph,
    cluster: Cluster,
    max_rounds: int,
    tol: float,
    allow_add: bool,
) -> RefineResult:
    """Original implementation: one ``ExecutionGraph`` copy + scalar
    ``max_stable_rate`` per candidate move. O(T·m + T²) copies per round."""
    current = etg.copy()
    best = _score(current, cluster)
    moves: list[str] = []
    m = cluster.n_machines
    n = current.utg.n_components

    for _ in range(max_rounds):
        best_move: tuple[float, str, ExecutionGraph] | None = None

        def consider(cand: ExecutionGraph, desc: str) -> None:
            nonlocal best_move
            s = _score(cand, cluster)
            if s > best + tol and (best_move is None or s > best_move[0]):
                best_move = (s, desc, cand)

        # RELOCATE: every instance to every other machine.
        for c in range(n):
            for k in range(int(current.n_instances[c])):
                src = int(current.assignment[c][k])
                for w in range(m):
                    if w == src:
                        continue
                    cand = current.copy()
                    cand.assignment[c] = cand.assignment[c].copy()
                    cand.assignment[c][k] = w
                    consider(cand, f"relocate c{c}#{k} m{src}->m{w}")

        # SWAP: instances of different components on different machines.
        flat = [
            (c, k, int(current.assignment[c][k]))
            for c in range(n)
            for k in range(int(current.n_instances[c]))
        ]
        for a in range(len(flat)):
            ca, ka, wa = flat[a]
            for b in range(a + 1, len(flat)):
                cb, kb, wb = flat[b]
                if wa == wb or ca == cb:
                    continue
                cand = current.copy()
                cand.assignment[ca] = cand.assignment[ca].copy()
                cand.assignment[cb] = cand.assignment[cb].copy()
                cand.assignment[ca][ka] = wb
                cand.assignment[cb][kb] = wa
                consider(cand, f"swap c{ca}#{ka}<->c{cb}#{kb}")

        if allow_add:
            # ADD: one more instance of any component on any machine.
            for c in range(n):
                for w in range(m):
                    consider(current.with_new_instance(c, w), f"add c{c}->m{w}")
            # GROW: k instances of one component at once, placed greedily —
            # the eq. 6 re-split means gains often appear only at specific
            # counts, invisible to single adds (e.g. 2 extra instances so a
            # fast machine carries 2 of N chunks).
            def greedy_grow(base, adds):
                cand = base
                for c in adds:
                    step_best = None
                    for w in range(m):
                        trial = cand.with_new_instance(c, w)
                        sc = _score(trial, cluster)
                        if step_best is None or sc > step_best[0]:
                            step_best = (sc, trial)
                    cand = step_best[1]
                return cand

            for c in range(n):
                for k in (2, 3, 4):
                    consider(greedy_grow(current, [c] * k), f"grow c{c}x{k}")
            # PAIRGROW: components often need to grow *together* — the eq. 6
            # re-split creates valleys between (x, y) and (x+a, y+b) that
            # per-component moves cannot cross.
            for ci in range(n):
                for cj in range(ci + 1, n):
                    for a, b in ((1, 1), (2, 1), (1, 2), (2, 2)):
                        adds = [ci] * a + [cj] * b
                        consider(greedy_grow(current, adds),
                                 f"pairgrow c{ci}x{a}+c{cj}x{b}")
            # DROP: remove an instance (keeps >= 1 per component).
            for c in range(n):
                if int(current.n_instances[c]) < 2:
                    continue
                for k in range(int(current.n_instances[c])):
                    cand = current.copy()
                    cand.n_instances = cand.n_instances.copy()
                    cand.n_instances[c] -= 1
                    cand.assignment[c] = np.delete(cand.assignment[c], k)
                    consider(cand, f"drop c{c}#{k}")

        if best_move is None:
            break
        best, desc, current = best_move
        moves.append(desc)

    rate, thpt = max_stable_rate(current, cluster)
    return RefineResult(etg=current, rate=rate, throughput=thpt, moves=moves)


# ------------------------------------------------------------ state engine


class _GrowCursor:
    """Flat task->machine row + block offsets threaded through a greedy
    growth chain, so each step avoids rebuilding them from the state."""

    __slots__ = ("row", "offsets")

    def __init__(self, row: np.ndarray, offsets: np.ndarray):
        self.row = row
        self.offsets = offsets

    def copy(self) -> "_GrowCursor":
        # Steps rebind (never mutate) row/offsets, so a shallow copy is a
        # valid fork point.
        return _GrowCursor(self.row, self.offsets)


class _GrowChain:
    """One greedy growth chain: its current exported row, block offsets and
    instance-count vector, plus the placements/scores of every step so far.

    After j steps, ``scores[j - 1]`` is the closed-form throughput of the
    j-step prefix and ``placements[:j]`` is the move that realizes it —
    uniform across single-component chains (ADD/GROW) and pair chains
    (PAIRGROW), which fork from a single chain's prefix.
    """

    __slots__ = ("row", "offsets", "n_inst", "placements", "scores")

    def __init__(self, row: np.ndarray, offsets: np.ndarray, n_inst: np.ndarray):
        self.row = row
        self.offsets = offsets
        self.n_inst = n_inst
        self.placements: list[tuple[int, int]] = []
        self.scores: list[float] = []

    def fork(self) -> "_GrowChain":
        # Steps rebind row/offsets and copy-on-write n_inst, so forking a
        # prefix shares the arrays and copies only the Python lists.
        child = _GrowChain(self.row, self.offsets, self.n_inst.copy())
        child.placements = list(self.placements)
        child.scores = list(self.scores)
        return child


def _grow_step(
    state: ScheduleState, c: int, backend: str, cur: _GrowCursor
) -> tuple[float, int]:
    """One greedy growth step: score adding an instance of ``c`` on every
    machine (one batched sweep), apply the winner to ``state`` and ``cur``.

    Matches the reference ``greedy_grow`` inner loop exactly: strict-``>``
    first-max over machines in index order is ``np.argmax`` on the batch.
    """
    m = state.cluster.n_machines
    row, offsets = cur.row, cur.offsets
    pos = int(offsets[c + 1])  # append at end of c's block
    T = row.shape[0]
    tm = np.empty((m, T + 1), dtype=np.int64)
    tm[:, :pos] = row[:pos]
    tm[:, pos] = np.arange(m)
    tm[:, pos + 1 :] = row[pos:]
    n_new = state.n_instances.copy()
    n_new[c] += 1
    _, scores = state.score_task_machine_batch(tm, n_new, backend=backend)
    w = int(np.argmax(scores))
    state.add_instance(c, w)
    cur.row = tm[w]
    new_off = offsets.copy()
    new_off[c + 1 :] += 1
    cur.offsets = new_off
    return float(scores[w]), w


def _lockstep_extend(
    state: ScheduleState,
    chains: list[_GrowChain],
    comps: list[int],
    backend: str,
) -> None:
    """One lockstep depth: score every live chain's next greedy step in a
    single per-row-count sweep and apply each chain's winner.

    Chain i appends one instance of ``comps[i]``; its m candidate rows are
    column inserts on its own row, and the whole depth scores as one
    ``score_task_machine_batch`` call with a (B, n) count matrix (B =
    len(chains) * m). Rows are scored independently and each chain's winner
    is the strict first-max over its own contiguous m rows in machine
    order, so scores and winners are bit-identical to stepping the chains
    one ``_grow_step`` sweep at a time.
    """
    if not chains:
        return
    m = state.cluster.n_machines
    T = int(chains[0].row.shape[0])
    k = len(chains)
    comps_arr = np.asarray(comps, dtype=np.int64)
    base = np.stack([ch.row for ch in chains])           # (k, T)
    pos = np.array(
        [int(ch.offsets[c + 1]) for ch, c in zip(chains, comps)],
        dtype=np.int64,
    )  # append at end of each chain's grown block
    counts = np.stack([ch.n_inst for ch in chains])      # (k, n)
    counts[np.arange(k), comps_arr] += 1
    # Insert one column at pos[i]: source column j-1 right of the insert, j
    # left of it; the insert column itself is overwritten with the machine
    # index, so its clipped source value is irrelevant.
    cols = np.arange(T + 1)
    src = np.clip(cols[None, :] - (cols[None, :] > pos[:, None]), 0, max(T - 1, 0))
    tm = np.repeat(np.take_along_axis(base, src, axis=1), m, axis=0)
    tm[np.arange(k * m), np.repeat(pos, m)] = np.tile(np.arange(m), k)
    n_rows = np.repeat(counts, m, axis=0)
    _, scores = state.score_task_machine_batch(tm, n_rows, backend=backend)
    winners = scores.reshape(k, m).argmax(axis=1)
    for i, (ch, c) in enumerate(zip(chains, comps)):
        w = int(winners[i])
        ch.row = tm[i * m + w]
        new_off = ch.offsets.copy()
        new_off[c + 1 :] += 1
        ch.offsets = new_off
        ch.n_inst[c] += 1
        ch.placements.append((c, w))
        ch.scores.append(float(scores[i * m + w]))


def _adaptive_live(chains: list[tuple[_GrowChain, int]]) -> list[tuple[_GrowChain, int]]:
    """Chains that keep extending: last step strictly improved, cap not hit.

    The stopping rule both explorers share — a chain whose deepest step did
    not strictly beat the one before it has crossed its eq. 6 re-split
    valley floor and stops.
    """
    return [
        (ch, c)
        for ch, c in chains
        if len(ch.scores) < _ADAPTIVE_GROW_CAP and ch.scores[-1] > ch.scores[-2]
    ]


def _adaptive_extend_lockstep(
    state: ScheduleState,
    singles: list[_GrowChain],
    pair_a: dict,
    pair_b: dict,
    pairs: list[tuple[int, int]],
    backend: str,
) -> None:
    """Depth-adaptive continuation: extend every still-improving chain one
    step per sweep until none improves.

    Chains at different depths carry different task totals, so each
    iteration groups live chains by row length and runs one per-row-count
    sweep per group — still O(depth) sweeps per round, independent of
    component count.
    """
    live = [(singles[c], c) for c in range(len(singles))]
    live += [(pair_a[p], p[1]) for p in pairs]
    live += [(pair_b[p], p[1]) for p in pairs]
    while True:
        live = _adaptive_live(live)
        if not live:
            return
        groups: dict[int, list[tuple[_GrowChain, int]]] = {}
        for ch, c in live:
            groups.setdefault(int(ch.row.shape[0]), []).append((ch, c))
        for length in sorted(groups):
            _lockstep_extend(
                state,
                [ch for ch, _ in groups[length]],
                [c for _, c in groups[length]],
                backend,
            )


def _growth_chains_lockstep(
    state: ScheduleState,
    base_tm: np.ndarray,
    offsets: np.ndarray,
    n_inst: np.ndarray,
    backend: str,
    adaptive: bool = False,
) -> tuple[list[_GrowChain], dict, dict, list[tuple[int, int]]]:
    """Explore every greedy growth chain in four depth-lockstep sweeps.

    Single chains (one per component, 4 steps each: ADD + GROW k=2/3/4) and
    pair chains (PAIRGROW (a, b) forks off the single chain's a-step
    prefix, then adds cj) advance together: every chain at depth d has the
    same task total T + d, so one rectangular per-row-count sweep scores
    all of them. A refine round's growth exploration is 4 sweeps total,
    independent of component count — versus ~4n + 4·C(n,2) m-row sweeps
    for the sequential path (``_growth_chains_sequential``).
    """
    n = state.utg.n_components
    pairs = [(ci, cj) for ci in range(n) for cj in range(ci + 1, n)]
    singles = [_GrowChain(base_tm, offsets, n_inst.copy()) for _ in range(n)]
    # Depth 1: each single chain's first step (the ADD candidate).
    _lockstep_extend(state, singles, list(range(n)), backend)
    # PAIRGROW (1, b) forks off the 1-step prefix before depth 2 extends it.
    pair_a = {p: singles[p[0]].fork() for p in pairs}
    # Depth 2: singles (GROW k=2) + first cj of every (1, b) pair chain.
    _lockstep_extend(
        state,
        singles + [pair_a[p] for p in pairs],
        list(range(n)) + [cj for _, cj in pairs],
        backend,
    )
    # PAIRGROW (2, b) forks off the 2-step prefix before depth 3.
    pair_b = {p: singles[p[0]].fork() for p in pairs}
    # Depth 3: singles (GROW k=3), second cj of (1, b), first cj of (2, b).
    _lockstep_extend(
        state,
        singles + [pair_a[p] for p in pairs] + [pair_b[p] for p in pairs],
        list(range(n)) + [cj for _, cj in pairs] * 2,
        backend,
    )
    # Depth 4: singles (GROW k=4) + second cj of (2, b).
    _lockstep_extend(
        state,
        singles + [pair_b[p] for p in pairs],
        list(range(n)) + [cj for _, cj in pairs],
        backend,
    )
    if adaptive:
        _adaptive_extend_lockstep(state, singles, pair_a, pair_b, pairs, backend)
    return singles, pair_a, pair_b, pairs


def _growth_chains_sequential(
    state: ScheduleState,
    base_tm: np.ndarray,
    offsets: np.ndarray,
    n_inst: np.ndarray,
    backend: str,
    adaptive: bool = False,
) -> tuple[list[_GrowChain], dict, dict, list[tuple[int, int]]]:
    """Sequential chain exploration (one m-row sweep per step).

    The pre-lockstep state-engine path, kept for the
    ``refine(..., lockstep=False)`` escape hatch and as the benchmark
    baseline the lockstep speedup is measured against
    (benchmarks/bench_refine.py). Scores and winners are bit-identical to
    the lockstep path — rows score independently either way.
    """
    n = state.utg.n_components
    pairs = [(ci, cj) for ci in range(n) for cj in range(ci + 1, n)]
    singles = []
    forks: list[dict[int, _GrowCursor]] = []
    for c in range(n):
        snap = state.snapshot()
        cur = _GrowCursor(base_tm, offsets)
        ch = _GrowChain(base_tm, offsets, n_inst.copy())
        fk: dict[int, _GrowCursor] = {}
        for step in range(1, 5):
            sc, w = _grow_step(state, c, backend, cur)
            ch.placements.append((c, w))
            ch.scores.append(sc)
            ch.n_inst[c] += 1
            if step <= 2:
                fk[step] = cur.copy()
        while adaptive and _adaptive_live([(ch, c)]):
            sc, w = _grow_step(state, c, backend, cur)
            ch.placements.append((c, w))
            ch.scores.append(sc)
            ch.n_inst[c] += 1
        ch.row, ch.offsets = cur.row, cur.offsets
        state.restore(snap)
        singles.append(ch)
        forks.append(fk)
    pair_a: dict[tuple[int, int], _GrowChain] = {}
    pair_b: dict[tuple[int, int], _GrowChain] = {}
    for ci, cj in pairs:
        ci_chain = singles[ci]
        for prefix, out in ((1, pair_a), (2, pair_b)):
            snap0 = state.snapshot()
            for c, w in ci_chain.placements[:prefix]:
                state.add_instance(c, w)
            cur = forks[ci][prefix].copy()
            ch = _GrowChain(cur.row, cur.offsets, n_inst.copy())
            ch.placements = list(ci_chain.placements[:prefix])
            ch.scores = list(ci_chain.scores[:prefix])
            ch.n_inst[ci] += prefix
            for _ in range(2):
                sc, w = _grow_step(state, cj, backend, cur)
                ch.placements.append((cj, w))
                ch.scores.append(sc)
                ch.n_inst[cj] += 1
            while adaptive and _adaptive_live([(ch, cj)]):
                sc, w = _grow_step(state, cj, backend, cur)
                ch.placements.append((cj, w))
                ch.scores.append(sc)
                ch.n_inst[cj] += 1
            ch.row, ch.offsets = cur.row, cur.offsets
            state.restore(snap0)
            out[(ci, cj)] = ch
    return singles, pair_a, pair_b, pairs


def _refine_state(
    etg: ExecutionGraph,
    cluster: Cluster,
    max_rounds: int,
    tol: float,
    allow_add: bool,
    backend: str,
    lockstep: bool = True,
    adaptive_growth: bool = False,
    skew=None,
    recorder=None,
) -> RefineResult:
    """Incremental-engine hill climb: identical decisions, batched scoring.

    Per round, every move family is expressed as edits on the flattened
    (T,) task->machine row exported from ``ScheduleState`` and scored in
    vectorized ``max_stable_rate_batch`` sweeps — one sweep covers all
    RELOCATE+SWAP candidates, four depth-lockstep per-row-count sweeps
    cover every growth chain (ADD/GROW/PAIRGROW), and one more covers all
    DROP candidates: ~6 sweeps per round. Candidate scores are
    bit-identical to the reference engine's scalar scoring (same
    ``max_stable_rate_batch`` row computation), and winners are selected
    with the same strict-``>`` first-max semantics in the same enumeration
    order, so both engines apply the same move sequence. Applying a move is
    an O(m) ``ScheduleState`` delta; growth exploration carries candidate
    rows/counts per chain, never mutating the live state.
    """
    state = ScheduleState.from_etg(etg, cluster, skew=skew)
    if skew is None:
        best = _score(state.to_etg(), cluster)
    else:
        # The incumbent must score under the same skew-aware bound as the
        # candidates, or offers get compared against the even-split score.
        best = float(
            state.score_task_machine_batch(state.task_machine()[None, :])[1][0]
        )
    moves: list[str] = []
    m = cluster.n_machines
    n = state.utg.n_components

    for round_idx in range(max_rounds):
        # Per-round profiling span (opened/closed manually so the
        # convergence `break` below can close it without reindenting the
        # whole round body under a `with`).
        round_span = sp = None
        if recorder is not None:
            round_span = recorder.span("refine.round", cat="refine", round=round_idx)
            sp = round_span.__enter__()
        best_move: tuple[float, str, "function"] | None = None

        def offer(score: float, desc: str, apply_fn) -> None:
            nonlocal best_move
            if score > best + tol and (best_move is None or score > best_move[0]):
                best_move = (score, desc, apply_fn)

        base_tm = state.task_machine()
        offsets = state.component_offsets()
        T = int(base_tm.shape[0])
        # Copy: growth exploration below mutates state.n_instances in place
        # before snapshot/restore swaps in a fresh array.
        n_inst = state.n_instances.copy()
        comp_of = np.repeat(np.arange(n), n_inst)

        # RELOCATE + SWAP share the template (counts unchanged): candidates
        # are 1-2 column edits on the base row, scored in one sweep. Within
        # the concatenated [relocate..., swap...] order, np.argmax is the
        # reference's first strictly-greater winner.
        W = np.tile(np.arange(m), (T, 1))
        keep = (W != base_tm[:, None]).ravel()
        reloc_pos = np.repeat(np.arange(T), m)[keep]
        reloc_w = W.ravel()[keep]
        a_idx, b_idx = np.triu_indices(T, 1)
        pair_ok = (comp_of[a_idx] != comp_of[b_idx]) & (
            base_tm[a_idx] != base_tm[b_idx]
        )
        swap_a, swap_b = a_idx[pair_ok], b_idx[pair_ok]
        b1, b2 = reloc_pos.size, swap_a.size
        # Each candidate = two column writes (a relocate writes one column
        # twice), so construction chunks alongside scoring.
        pos_a = np.concatenate([reloc_pos, swap_a])
        val_a = np.concatenate([reloc_w, base_tm[swap_b]])
        pos_b = np.concatenate([reloc_pos, swap_b])
        val_b = np.concatenate([reloc_w, base_tm[swap_a]])
        scores = np.empty(b1 + b2, dtype=np.float64)
        chunk = _effective_chunk(cluster, n)
        for start in range(0, b1 + b2, chunk):
            stop = min(start + chunk, b1 + b2)
            tm = np.tile(base_tm, (stop - start, 1))
            rows = np.arange(stop - start)
            tm[rows, pos_a[start:stop]] = val_a[start:stop]
            tm[rows, pos_b[start:stop]] = val_b[start:stop]
            scores[start:stop] = state.score_task_machine_batch(
                tm, n_inst, backend=backend
            )[1]
        if b1 + b2:
            i = int(np.argmax(scores))
            s = float(scores[i])
            if i < b1:
                p, w = int(reloc_pos[i]), int(reloc_w[i])
                c = int(comp_of[p])
                k, src = p - int(offsets[c]), int(base_tm[p])
                offer(
                    s,
                    f"relocate c{c}#{k} m{src}->m{w}",
                    lambda c=c, k=k, w=w: state.relocate_instance(c, k, w),
                )
            else:
                pa, pb = int(swap_a[i - b1]), int(swap_b[i - b1])
                ca, cb = int(comp_of[pa]), int(comp_of[pb])
                ka, kb = pa - int(offsets[ca]), pb - int(offsets[cb])
                offer(
                    s,
                    f"swap c{ca}#{ka}<->c{cb}#{kb}",
                    lambda ca=ca, ka=ka, cb=cb, kb=kb: state.swap_instances(
                        ca, ka, cb, kb
                    ),
                )

        if allow_add:
            def apply_adds(placements):
                for c, w in placements:
                    state.add_instance(c, w)

            # Greedy growth is deterministic, so the reference's independent
            # greedy_grow re-runs traverse shared prefixes: one 4-step chain
            # per component yields the ADD candidate (step 1) and the
            # GROW k=2/3/4 candidates (steps 2-4); PAIRGROW forks off the
            # first one or two steps of the first component's chain. The
            # lockstep explorer advances every chain together — 4
            # per-row-count sweeps per round regardless of component count;
            # the sequential explorer steps chains one m-row sweep at a
            # time. Both produce bit-identical chain scores. Offers follow
            # the reference enumeration order (ADD..., GROW..., PAIRGROW...,
            # DROP...), which matters for exact-tie breaking under the
            # strict-> first-max rule.
            explore = (
                _growth_chains_lockstep if lockstep else _growth_chains_sequential
            )
            singles, pair_a, pair_b, pairs = explore(
                state, base_tm, offsets, n_inst, backend, adaptive_growth
            )
            # ADD: the reference's first-max over machines is exactly the
            # chain's first greedy step (same scores, same argmax).
            for c in range(n):
                ch = singles[c]
                offer(
                    ch.scores[0],
                    f"add c{c}->m{ch.placements[0][1]}",
                    lambda p=ch.placements[:1]: apply_adds(p),
                )
            # GROW: k instances of one component at once — the eq. 6
            # re-split means gains often appear only at specific counts,
            # invisible to single adds. Adaptive chains extend the menu
            # past k=4 for as deep as their scores kept improving.
            for c in range(n):
                ch = singles[c]
                for k in range(2, len(ch.scores) + 1):
                    offer(
                        ch.scores[k - 1],
                        f"grow c{c}x{k}",
                        lambda p=ch.placements[:k]: apply_adds(p),
                    )
            # PAIRGROW: components often need to grow *together* — the
            # eq. 6 re-split creates valleys between (x, y) and
            # (x+a, y+b) that per-component moves cannot cross. The (a, b)
            # combo is the (a + b)-step prefix of the (a, ·) pair chain.
            for ci, cj in pairs:
                pa, pb = pair_a[(ci, cj)], pair_b[(ci, cj)]
                for (a, b), ch in (
                    ((1, 1), pa),
                    ((2, 1), pb),
                    ((1, 2), pa),
                    ((2, 2), pb),
                ):
                    offer(
                        ch.scores[a + b - 1],
                        f"pairgrow c{ci}x{a}+c{cj}x{b}",
                        lambda p=ch.placements[: a + b]: apply_adds(p),
                    )
                # Adaptive extension of the pair menu: (a, b > 2) combos
                # for as deep as each pair chain kept improving.
                max_b = max(len(pa.scores) - 1, len(pb.scores) - 2)
                for b in range(3, max_b + 1):
                    for a, ch in ((1, pa), (2, pb)):
                        if len(ch.scores) - a >= b:
                            offer(
                                ch.scores[a + b - 1],
                                f"pairgrow c{ci}x{a}+c{cj}x{b}",
                                lambda p=ch.placements[: a + b]: apply_adds(p),
                            )
            # DROP: which instance to delete, over every component with
            # >= 2 instances — column removals on the base row, all scored
            # in one per-row-count sweep (winner still picked per component
            # to preserve the reference offer order).
            drop_rows: list[np.ndarray] = []
            drop_counts: list[np.ndarray] = []
            drop_span: list[tuple[int, int]] = []
            for c in range(n):
                nk = int(n_inst[c])
                if nk < 2:
                    continue
                cols = np.arange(T - 1)
                idx = cols[None, :] + (
                    cols[None, :] >= (int(offsets[c]) + np.arange(nk))[:, None]
                )
                n_new = n_inst.copy()
                n_new[c] -= 1
                drop_rows.append(base_tm[idx])
                drop_counts.append(np.tile(n_new, (nk, 1)))
                drop_span.append((c, nk))
            if drop_rows:
                _, sd_all = state.score_task_machine_batch(
                    np.concatenate(drop_rows, axis=0),
                    np.concatenate(drop_counts, axis=0),
                    backend=backend,
                )
                start = 0
                for c, nk in drop_span:
                    sd = sd_all[start : start + nk]
                    start += nk
                    k = int(np.argmax(sd))
                    offer(
                        float(sd[k]),
                        f"drop c{c}#{k}",
                        lambda c=c, k=k: state.drop_instance(c, k),
                    )

        if best_move is None:
            if round_span is not None:
                sp["args"]["move"] = None
                round_span.__exit__(None, None, None)
            break
        best, desc, apply_fn = best_move
        apply_fn()
        moves.append(desc)
        if round_span is not None:
            sp["args"]["move"] = desc
            sp["args"]["score"] = float(best)
            round_span.__exit__(None, None, None)

    final = state.to_etg()
    rate, thpt = max_stable_rate(final, cluster, skew=skew)
    return RefineResult(etg=final, rate=rate, throughput=thpt, moves=moves)
