"""Local-search refinement of a schedule (beyond-paper enhancement).

The paper's Algorithm 2 only ever *adds* instances; it can never rebalance
earlier placement decisions, so on profiles where task "chunks" pack
awkwardly it terminates at a local optimum measurably below the exhaustive
optimum. This pass closes that gap with a hill climb over three move types,
each scored by the closed-form maximum stable throughput
(``cost_model.max_stable_rate`` — O(T) per candidate, no simulation):

* RELOCATE — move one instance to a different machine;
* SWAP     — exchange the machines of two instances of different components;
* ADD      — grow one component by one instance on some machine;
* DROP     — remove an instance of a component with >= 2 instances (undoes
             over-provisioning that only burns MET overhead).

The climb applies the single best improving move until no move improves
throughput by more than ``tol`` (first-improvement would also work; best-
improvement keeps the trace short and deterministic). Complexity per round
is O(T·m + T²) stable-rate evaluations, each O(T) — trivially fast for
benchmark-scale graphs and still fast for the large-scale scenarios.

This module is *not* part of the faithful reproduction; benchmarks report
"proposed" (faithful Alg. 1+2) and "proposed+refine" separately.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost_model import max_stable_rate
from repro.core.graph import ExecutionGraph
from repro.core.profiles import Cluster

__all__ = ["RefineResult", "refine"]


@dataclasses.dataclass(frozen=True)
class RefineResult:
    etg: ExecutionGraph
    rate: float
    throughput: float
    moves: list[str]


def _score(etg: ExecutionGraph, cluster: Cluster) -> float:
    return max_stable_rate(etg, cluster)[1]


def refine(
    etg: ExecutionGraph,
    cluster: Cluster,
    max_rounds: int = 200,
    tol: float = 1e-9,
    allow_add: bool = True,
) -> RefineResult:
    current = etg.copy()
    best = _score(current, cluster)
    moves: list[str] = []
    m = cluster.n_machines
    n = current.utg.n_components

    for _ in range(max_rounds):
        best_move: tuple[float, str, ExecutionGraph] | None = None

        def consider(cand: ExecutionGraph, desc: str) -> None:
            nonlocal best_move
            s = _score(cand, cluster)
            if s > best + tol and (best_move is None or s > best_move[0]):
                best_move = (s, desc, cand)

        # RELOCATE: every instance to every other machine.
        for c in range(n):
            for k in range(int(current.n_instances[c])):
                src = int(current.assignment[c][k])
                for w in range(m):
                    if w == src:
                        continue
                    cand = current.copy()
                    cand.assignment[c] = cand.assignment[c].copy()
                    cand.assignment[c][k] = w
                    consider(cand, f"relocate c{c}#{k} m{src}->m{w}")

        # SWAP: instances of different components on different machines.
        flat = [
            (c, k, int(current.assignment[c][k]))
            for c in range(n)
            for k in range(int(current.n_instances[c]))
        ]
        for a in range(len(flat)):
            ca, ka, wa = flat[a]
            for b in range(a + 1, len(flat)):
                cb, kb, wb = flat[b]
                if wa == wb or ca == cb:
                    continue
                cand = current.copy()
                cand.assignment[ca] = cand.assignment[ca].copy()
                cand.assignment[cb] = cand.assignment[cb].copy()
                cand.assignment[ca][ka] = wb
                cand.assignment[cb][kb] = wa
                consider(cand, f"swap c{ca}#{ka}<->c{cb}#{kb}")

        if allow_add:
            # ADD: one more instance of any component on any machine.
            for c in range(n):
                for w in range(m):
                    consider(current.with_new_instance(c, w), f"add c{c}->m{w}")
            # GROW: k instances of one component at once, placed greedily —
            # the eq. 6 re-split means gains often appear only at specific
            # counts, invisible to single adds (e.g. 2 extra instances so a
            # fast machine carries 2 of N chunks).
            def greedy_grow(base, adds):
                cand = base
                for c in adds:
                    step_best = None
                    for w in range(m):
                        trial = cand.with_new_instance(c, w)
                        sc = _score(trial, cluster)
                        if step_best is None or sc > step_best[0]:
                            step_best = (sc, trial)
                    cand = step_best[1]
                return cand

            for c in range(n):
                for k in (2, 3, 4):
                    consider(greedy_grow(current, [c] * k), f"grow c{c}x{k}")
            # PAIRGROW: components often need to grow *together* — the eq. 6
            # re-split creates valleys between (x, y) and (x+a, y+b) that
            # per-component moves cannot cross.
            for ci in range(n):
                for cj in range(ci + 1, n):
                    for a, b in ((1, 1), (2, 1), (1, 2), (2, 2)):
                        adds = [ci] * a + [cj] * b
                        consider(greedy_grow(current, adds),
                                 f"pairgrow c{ci}x{a}+c{cj}x{b}")
            # DROP: remove an instance (keeps >= 1 per component).
            for c in range(n):
                if int(current.n_instances[c]) < 2:
                    continue
                for k in range(int(current.n_instances[c])):
                    cand = current.copy()
                    cand.n_instances = cand.n_instances.copy()
                    cand.n_instances[c] -= 1
                    cand.assignment[c] = np.delete(cand.assignment[c], k)
                    consider(cand, f"drop c{c}#{k}")

        if best_move is None:
            break
        best, desc, current = best_move
        moves.append(desc)

    rate, thpt = max_stable_rate(current, cluster)
    return RefineResult(etg=current, rate=rate, throughput=thpt, moves=moves)
