"""Local-search refinement of a schedule (beyond-paper enhancement).

The paper's Algorithm 2 only ever *adds* instances; it can never rebalance
earlier placement decisions, so on profiles where task "chunks" pack
awkwardly it terminates at a local optimum measurably below the exhaustive
optimum. This pass closes that gap with a hill climb over these move types,
each scored by the closed-form maximum stable throughput (paper eq. 5/6 are
linear in the topology input rate, so no simulation is needed):

* RELOCATE — move one instance to a different machine;
* SWAP     — exchange the machines of two instances of different components;
* ADD      — grow one component by one instance on some machine;
* GROW     — grow one component by k instances at once, placed greedily;
* PAIRGROW — grow two components together (crosses eq. 6 re-split valleys);
* DROP     — remove an instance of a component with >= 2 instances (undoes
             over-provisioning that only burns MET overhead).

The climb applies the single best improving move until no move improves
throughput by more than ``tol`` (first-improvement would also work; best-
improvement keeps the trace short and deterministic).

Engines
-------
``engine="state"`` (default) runs the climb on the incremental
``ScheduleState`` engine: moves are O(m) count-matrix deltas with
snapshot/restore rollback (no ``ExecutionGraph`` copies), and each round's
candidate set is scored through vectorized ``max_stable_rate_batch`` calls
— candidate placements are exported as (B, T) task->machine matrices, so
every candidate's score is bit-identical to the reference path's scalar
``max_stable_rate`` and the two engines provably choose the same moves.
``engine="reference"`` keeps the original copy-and-score implementation as
the semantic reference for the golden equivalence tests
(``tests/test_sched_equivalence.py``).

This module is *not* part of the faithful reproduction; benchmarks report
"proposed" (faithful Alg. 1+2) and "proposed+refine" separately. See
docs/architecture.md for the engine design and docs/api.md for usage.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost_model import max_stable_rate
from repro.core.graph import ExecutionGraph
from repro.core.profiles import Cluster
from repro.core.schedule_state import ScheduleState

__all__ = ["RefineResult", "refine"]

# Candidate rows scored per vectorized sweep; bounds the (chunk, T) batch
# memory on large clusters without changing results (rows are independent).
_SCORE_CHUNK = 16_384


@dataclasses.dataclass(frozen=True)
class RefineResult:
    etg: ExecutionGraph
    rate: float
    throughput: float
    moves: list[str]


def _score(etg: ExecutionGraph, cluster: Cluster) -> float:
    return max_stable_rate(etg, cluster)[1]


def refine(
    etg: ExecutionGraph,
    cluster: Cluster,
    max_rounds: int = 200,
    tol: float = 1e-9,
    allow_add: bool = True,
    engine: str = "state",
    backend: str = "numpy",
) -> RefineResult:
    """Hill-climb refinement of ``etg``'s placement (and instance counts).

    Args:
      etg: schedule to refine (not mutated).
      cluster: the heterogeneous cluster.
      max_rounds: maximum number of applied moves.
      tol: minimum throughput improvement for a move to be applied.
      allow_add: when False, only count-preserving moves (RELOCATE/SWAP)
        are considered.
      engine: ``"state"`` (incremental ScheduleState deltas + batched
        scoring, default) or ``"reference"`` (original per-candidate
        copy-and-score path). Both produce identical results.
      backend: scoring backend for the state engine's batched closed-form
        evaluator — ``"numpy"`` (default; bit-identical to the reference)
        or ``"jax"`` (jitted float64, ~1e-15 relative agreement; worthwhile
        only for very large candidate batches). Ignored by the reference
        engine.
    """
    if engine == "state":
        return _refine_state(etg, cluster, max_rounds, tol, allow_add, backend)
    if engine != "reference":
        raise ValueError(f"unknown engine {engine!r}; use 'state' or 'reference'")
    return _refine_reference(etg, cluster, max_rounds, tol, allow_add)


# --------------------------------------------------------------- reference


def _refine_reference(
    etg: ExecutionGraph,
    cluster: Cluster,
    max_rounds: int,
    tol: float,
    allow_add: bool,
) -> RefineResult:
    """Original implementation: one ``ExecutionGraph`` copy + scalar
    ``max_stable_rate`` per candidate move. O(T·m + T²) copies per round."""
    current = etg.copy()
    best = _score(current, cluster)
    moves: list[str] = []
    m = cluster.n_machines
    n = current.utg.n_components

    for _ in range(max_rounds):
        best_move: tuple[float, str, ExecutionGraph] | None = None

        def consider(cand: ExecutionGraph, desc: str) -> None:
            nonlocal best_move
            s = _score(cand, cluster)
            if s > best + tol and (best_move is None or s > best_move[0]):
                best_move = (s, desc, cand)

        # RELOCATE: every instance to every other machine.
        for c in range(n):
            for k in range(int(current.n_instances[c])):
                src = int(current.assignment[c][k])
                for w in range(m):
                    if w == src:
                        continue
                    cand = current.copy()
                    cand.assignment[c] = cand.assignment[c].copy()
                    cand.assignment[c][k] = w
                    consider(cand, f"relocate c{c}#{k} m{src}->m{w}")

        # SWAP: instances of different components on different machines.
        flat = [
            (c, k, int(current.assignment[c][k]))
            for c in range(n)
            for k in range(int(current.n_instances[c]))
        ]
        for a in range(len(flat)):
            ca, ka, wa = flat[a]
            for b in range(a + 1, len(flat)):
                cb, kb, wb = flat[b]
                if wa == wb or ca == cb:
                    continue
                cand = current.copy()
                cand.assignment[ca] = cand.assignment[ca].copy()
                cand.assignment[cb] = cand.assignment[cb].copy()
                cand.assignment[ca][ka] = wb
                cand.assignment[cb][kb] = wa
                consider(cand, f"swap c{ca}#{ka}<->c{cb}#{kb}")

        if allow_add:
            # ADD: one more instance of any component on any machine.
            for c in range(n):
                for w in range(m):
                    consider(current.with_new_instance(c, w), f"add c{c}->m{w}")
            # GROW: k instances of one component at once, placed greedily —
            # the eq. 6 re-split means gains often appear only at specific
            # counts, invisible to single adds (e.g. 2 extra instances so a
            # fast machine carries 2 of N chunks).
            def greedy_grow(base, adds):
                cand = base
                for c in adds:
                    step_best = None
                    for w in range(m):
                        trial = cand.with_new_instance(c, w)
                        sc = _score(trial, cluster)
                        if step_best is None or sc > step_best[0]:
                            step_best = (sc, trial)
                    cand = step_best[1]
                return cand

            for c in range(n):
                for k in (2, 3, 4):
                    consider(greedy_grow(current, [c] * k), f"grow c{c}x{k}")
            # PAIRGROW: components often need to grow *together* — the eq. 6
            # re-split creates valleys between (x, y) and (x+a, y+b) that
            # per-component moves cannot cross.
            for ci in range(n):
                for cj in range(ci + 1, n):
                    for a, b in ((1, 1), (2, 1), (1, 2), (2, 2)):
                        adds = [ci] * a + [cj] * b
                        consider(greedy_grow(current, adds),
                                 f"pairgrow c{ci}x{a}+c{cj}x{b}")
            # DROP: remove an instance (keeps >= 1 per component).
            for c in range(n):
                if int(current.n_instances[c]) < 2:
                    continue
                for k in range(int(current.n_instances[c])):
                    cand = current.copy()
                    cand.n_instances = cand.n_instances.copy()
                    cand.n_instances[c] -= 1
                    cand.assignment[c] = np.delete(cand.assignment[c], k)
                    consider(cand, f"drop c{c}#{k}")

        if best_move is None:
            break
        best, desc, current = best_move
        moves.append(desc)

    rate, thpt = max_stable_rate(current, cluster)
    return RefineResult(etg=current, rate=rate, throughput=thpt, moves=moves)


# ------------------------------------------------------------ state engine


class _GrowCursor:
    """Flat task->machine row + block offsets threaded through a greedy
    growth chain, so each step avoids rebuilding them from the state."""

    __slots__ = ("row", "offsets")

    def __init__(self, row: np.ndarray, offsets: np.ndarray):
        self.row = row
        self.offsets = offsets

    def copy(self) -> "_GrowCursor":
        # Steps rebind (never mutate) row/offsets, so a shallow copy is a
        # valid fork point.
        return _GrowCursor(self.row, self.offsets)


def _grow_step(
    state: ScheduleState, c: int, backend: str, cur: _GrowCursor
) -> tuple[float, int]:
    """One greedy growth step: score adding an instance of ``c`` on every
    machine (one batched sweep), apply the winner to ``state`` and ``cur``.

    Matches the reference ``greedy_grow`` inner loop exactly: strict-``>``
    first-max over machines in index order is ``np.argmax`` on the batch.
    """
    m = state.cluster.n_machines
    row, offsets = cur.row, cur.offsets
    pos = int(offsets[c + 1])  # append at end of c's block
    T = row.shape[0]
    tm = np.empty((m, T + 1), dtype=np.int64)
    tm[:, :pos] = row[:pos]
    tm[:, pos] = np.arange(m)
    tm[:, pos + 1 :] = row[pos:]
    n_new = state.n_instances.copy()
    n_new[c] += 1
    _, scores = state.score_task_machine_batch(tm, n_new, backend=backend)
    w = int(np.argmax(scores))
    state.add_instance(c, w)
    cur.row = tm[w]
    new_off = offsets.copy()
    new_off[c + 1 :] += 1
    cur.offsets = new_off
    return float(scores[w]), w


def _refine_state(
    etg: ExecutionGraph,
    cluster: Cluster,
    max_rounds: int,
    tol: float,
    allow_add: bool,
    backend: str,
) -> RefineResult:
    """Incremental-engine hill climb: identical decisions, batched scoring.

    Per round, every move family is expressed as edits on the flattened
    (T,) task->machine row exported from ``ScheduleState`` and scored in
    vectorized ``max_stable_rate_batch`` sweeps — one sweep covers all
    RELOCATE+SWAP candidates, one per component covers ADD (and DROP), and
    each greedy growth step is one m-row sweep. Candidate scores are
    bit-identical to the reference engine's scalar scoring (same
    ``max_stable_rate_batch`` row computation), and winners are selected
    with the same strict-``>`` first-max semantics in the same enumeration
    order, so both engines apply the same move sequence. Applying a move is
    an O(m) ``ScheduleState`` delta; greedy growth exploration rolls back
    via snapshot/restore instead of copying graphs.
    """
    state = ScheduleState.from_etg(etg, cluster)
    best = _score(state.to_etg(), cluster)
    moves: list[str] = []
    m = cluster.n_machines
    n = state.utg.n_components

    for _ in range(max_rounds):
        best_move: tuple[float, str, "function"] | None = None

        def offer(score: float, desc: str, apply_fn) -> None:
            nonlocal best_move
            if score > best + tol and (best_move is None or score > best_move[0]):
                best_move = (score, desc, apply_fn)

        base_tm = state.task_machine()
        offsets = state.component_offsets()
        T = int(base_tm.shape[0])
        # Copy: growth exploration below mutates state.n_instances in place
        # before snapshot/restore swaps in a fresh array.
        n_inst = state.n_instances.copy()
        comp_of = np.repeat(np.arange(n), n_inst)

        # RELOCATE + SWAP share the template (counts unchanged): candidates
        # are 1-2 column edits on the base row, scored in one sweep. Within
        # the concatenated [relocate..., swap...] order, np.argmax is the
        # reference's first strictly-greater winner.
        W = np.tile(np.arange(m), (T, 1))
        keep = (W != base_tm[:, None]).ravel()
        reloc_pos = np.repeat(np.arange(T), m)[keep]
        reloc_w = W.ravel()[keep]
        a_idx, b_idx = np.triu_indices(T, 1)
        pair_ok = (comp_of[a_idx] != comp_of[b_idx]) & (
            base_tm[a_idx] != base_tm[b_idx]
        )
        swap_a, swap_b = a_idx[pair_ok], b_idx[pair_ok]
        b1, b2 = reloc_pos.size, swap_a.size
        # Each candidate = two column writes (a relocate writes one column
        # twice), so construction chunks alongside scoring.
        pos_a = np.concatenate([reloc_pos, swap_a])
        val_a = np.concatenate([reloc_w, base_tm[swap_b]])
        pos_b = np.concatenate([reloc_pos, swap_b])
        val_b = np.concatenate([reloc_w, base_tm[swap_a]])
        scores = np.empty(b1 + b2, dtype=np.float64)
        for start in range(0, b1 + b2, _SCORE_CHUNK):
            stop = min(start + _SCORE_CHUNK, b1 + b2)
            tm = np.tile(base_tm, (stop - start, 1))
            rows = np.arange(stop - start)
            tm[rows, pos_a[start:stop]] = val_a[start:stop]
            tm[rows, pos_b[start:stop]] = val_b[start:stop]
            scores[start:stop] = state.score_task_machine_batch(
                tm, n_inst, backend=backend
            )[1]
        if b1 + b2:
            i = int(np.argmax(scores))
            s = float(scores[i])
            if i < b1:
                p, w = int(reloc_pos[i]), int(reloc_w[i])
                c = int(comp_of[p])
                k, src = p - int(offsets[c]), int(base_tm[p])
                offer(
                    s,
                    f"relocate c{c}#{k} m{src}->m{w}",
                    lambda c=c, k=k, w=w: state.relocate_instance(c, k, w),
                )
            else:
                pa, pb = int(swap_a[i - b1]), int(swap_b[i - b1])
                ca, cb = int(comp_of[pa]), int(comp_of[pb])
                ka, kb = pa - int(offsets[ca]), pb - int(offsets[cb])
                offer(
                    s,
                    f"swap c{ca}#{ka}<->c{cb}#{kb}",
                    lambda ca=ca, ka=ka, cb=cb, kb=kb: state.swap_instances(
                        ca, ka, cb, kb
                    ),
                )

        if allow_add:
            def apply_adds(placements):
                for c, w in placements:
                    state.add_instance(c, w)

            # Greedy growth is deterministic, so the reference's independent
            # greedy_grow re-runs traverse shared prefixes: one 4-step chain
            # per component yields the ADD candidate (step 1) and the
            # GROW k=2/3/4 candidates (steps 2-4); PAIRGROW reuses the first
            # one or two steps of the first component's chain. Chains are
            # explored on the live state with snapshot/restore rollback.
            # Offers still follow the reference enumeration order
            # (ADD..., GROW..., PAIRGROW..., DROP...), which matters for
            # exact-tie breaking under the strict-> first-max rule.
            chains: list[
                tuple[dict[int, float], list[tuple[int, int]], dict[int, _GrowCursor]]
            ] = []
            for c in range(n):
                snap = state.snapshot()
                cur = _GrowCursor(base_tm, offsets)
                chain: list[tuple[int, int]] = []
                chain_scores: dict[int, float] = {}
                forks: dict[int, _GrowCursor] = {}
                for step in range(1, 5):
                    sc, w = _grow_step(state, c, backend, cur)
                    chain.append((c, w))
                    chain_scores[step] = sc
                    if step <= 2:
                        forks[step] = cur.copy()
                state.restore(snap)
                chains.append((chain_scores, chain, forks))
            # ADD: the reference's first-max over machines is exactly the
            # chain's first greedy step (same scores, same argmax).
            for c in range(n):
                chain_scores, chain, _ = chains[c]
                offer(
                    chain_scores[1],
                    f"add c{c}->m{chain[0][1]}",
                    lambda p=chain[:1]: apply_adds(p),
                )
            # GROW: k instances of one component at once — the eq. 6
            # re-split means gains often appear only at specific counts,
            # invisible to single adds.
            for c in range(n):
                chain_scores, chain, _ = chains[c]
                for k in (2, 3, 4):
                    offer(
                        chain_scores[k],
                        f"grow c{c}x{k}",
                        lambda p=chain[:k]: apply_adds(p),
                    )
            # PAIRGROW: components often need to grow *together* — the
            # eq. 6 re-split creates valleys between (x, y) and
            # (x+a, y+b) that per-component moves cannot cross.
            for ci in range(n):
                for cj in range(ci + 1, n):
                    snap0 = state.snapshot()
                    _, ci_chain, forks = chains[ci]
                    apply_adds(ci_chain[:1])               # [ci] (shared prefix)
                    cur = forks[1].copy()
                    snap1 = state.snapshot()
                    sc11, w = _grow_step(state, cj, backend, cur)
                    p11 = ci_chain[:1] + [(cj, w)]
                    sc12, w = _grow_step(state, cj, backend, cur)
                    p12 = p11 + [(cj, w)]
                    state.restore(snap1)
                    apply_adds(ci_chain[1:2])              # [ci, ci]
                    cur = forks[2].copy()
                    sc21, w = _grow_step(state, cj, backend, cur)
                    p21 = ci_chain[:2] + [(cj, w)]
                    sc22, w = _grow_step(state, cj, backend, cur)
                    p22 = p21 + [(cj, w)]
                    state.restore(snap0)
                    for (a, b), (sc_ab, p_ab) in (
                        ((1, 1), (sc11, p11)),
                        ((2, 1), (sc21, p21)),
                        ((1, 2), (sc12, p12)),
                        ((2, 2), (sc22, p22)),
                    ):
                        offer(
                            sc_ab,
                            f"pairgrow c{ci}x{a}+c{cj}x{b}",
                            lambda p=p_ab: apply_adds(p),
                        )
            # DROP: per component with >= 2 instances, one sweep over which
            # instance to delete (column removal on the base row).
            for c in range(n):
                nk = int(n_inst[c])
                if nk < 2:
                    continue
                cols = np.arange(T - 1)
                idx = cols[None, :] + (
                    cols[None, :] >= (int(offsets[c]) + np.arange(nk))[:, None]
                )
                tmd = base_tm[idx]
                n_new = n_inst.copy()
                n_new[c] -= 1
                _, sd = state.score_task_machine_batch(tmd, n_new, backend=backend)
                k = int(np.argmax(sd))
                offer(
                    float(sd[k]),
                    f"drop c{c}#{k}",
                    lambda c=c, k=k: state.drop_instance(c, k),
                )

        if best_move is None:
            break
        best, desc, apply_fn = best_move
        apply_fn()
        moves.append(desc)

    final = state.to_etg()
    rate, thpt = max_stable_rate(final, cluster)
    return RefineResult(etg=final, rate=rate, throughput=thpt, moves=moves)
