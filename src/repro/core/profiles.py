"""Profiling tables: e_ij, MET_ij per (task type, machine type) — paper §5.2.

The paper's pre-process profiling runs every task type on every machine type
at its saturation point and records:

* ``e_ij``   — average per-tuple execution time (seconds) of task type i on
               machine type j (Table 3);
* ``MET_ij`` — Storm's miscellaneous (framework) execution overhead, in CPU
               utilization points, recovered from eq. 5 at the saturation
               measurement;
* ``alpha_i`` — tuple division ratio per component (part of profiling data).

Units, faithful to the paper: TCU (task CPU utilization) is in *percent of
one machine's CPU* (0..100); e_ij · IR has units (seconds/tuple) ×
(tuples/second) × 100 ⇒ e_ij below are stored as "CPU-percent per
(tuple/second)" = seconds × 100. Table 3 lists e_ij in raw seconds; the
conversion by ×100 happens here once so that eq. 5 reads exactly
``TCU = e * IR + MET`` against a 100-point machine budget.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "Profile",
    "Cluster",
    "paper_profile",
    "paper_cluster",
    "rack_distance_matrix",
    "PAPER_E_TABLE3",
]

# Table 3 (seconds per tuple): rows = task types (lowCompute, midCompute,
# highCompute), columns = machine types (Machine1 Pentium, Machine2 Core i3,
# Machine3 Core i5).
#
# NOTE: Table 3 reads counter-intuitively (the Pentium shows the *smallest*
# per-tuple time). We reproduce the table verbatim — the algorithm only needs
# consistency between profiling and simulation, and we keep the paper's
# numbers as ground truth.
PAPER_E_TABLE3 = np.array(
    [
        [0.0581, 0.1070, 0.0916],  # lowCompute
        [0.1030, 0.1844, 0.1680],  # midCompute
        [0.1915, 0.3449, 0.3207],  # highCompute
    ]
)

# Per-machine-type miscellaneous Storm overhead (CPU points). The paper does
# not tabulate MET; it is recovered per (i, j) during profiling. We model it
# as a small per-machine-type constant, consistent with "independent of input
# rate".
PAPER_MET = np.array([1.5, 1.0, 1.2])

# Spout per-tuple emission cost (seconds): spouts generate rather than
# process; tiny but nonzero so spout placement matters slightly.
SPOUT_E = np.array([0.004, 0.006, 0.005])


@dataclasses.dataclass(frozen=True)
class Profile:
    """Profiling data P for a (task-type × machine-type) universe.

    Attributes:
      e: (n_task_types, n_machine_types) CPU-percent per unit input rate
         (i.e. seconds-per-tuple × 100).
      met: (n_task_types, n_machine_types) constant overhead in CPU points.
      type_names: task type names.
      machine_type_names: machine type names.
      mem: optional (n_task_types,) per-instance memory demand (memory
        units, rate-independent — an operator's working set does not grow
        with throughput). ``None`` (default) means memory is not modelled:
        every scoring path takes exactly the scalar-CPU code today's
        goldens pin (the R-Storm resource-vector extension, PAPERS.md).
    """

    e: np.ndarray
    met: np.ndarray
    type_names: tuple[str, ...]
    machine_type_names: tuple[str, ...]
    mem: np.ndarray | None = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "e", np.asarray(self.e, dtype=np.float64))
        object.__setattr__(self, "met", np.asarray(self.met, dtype=np.float64))
        if self.e.shape != self.met.shape:
            raise ValueError("e and met must have the same shape")
        if np.any(self.e < 0) or np.any(self.met < 0):
            raise ValueError("profiling constants must be non-negative")
        if self.mem is not None:
            mem = np.asarray(self.mem, dtype=np.float64)
            object.__setattr__(self, "mem", mem)
            if mem.shape != (self.e.shape[0],):
                raise ValueError("mem must be (n_task_types,)")
            if np.any(mem < 0):
                raise ValueError("memory demands must be non-negative")

    def with_mem(self, mem: np.ndarray) -> "Profile":
        """Same profiling tables plus a per-task-type memory demand vector."""
        return dataclasses.replace(self, mem=np.asarray(mem, dtype=np.float64))

    @property
    def n_task_types(self) -> int:
        return self.e.shape[0]

    @property
    def n_machine_types(self) -> int:
        return self.e.shape[1]


@dataclasses.dataclass(frozen=True)
class Cluster:
    """A concrete heterogeneous cluster: machine i has type machine_types[i].

    ``capacity`` is the per-machine CPU budget (the paper's MAC starting
    value, 100 points per machine).

    Resource-vector extension (R-Storm / Eidenbenz & Locher, PAPERS.md) —
    all three fields default to "not modelled", and with the defaults every
    scoring path is bit-identical to the scalar-CPU cost model:

    * ``mem_capacity`` — optional (m,) per-machine memory capacity. Paired
      with ``Profile.mem`` it becomes a *hard* constraint: a placement
      whose summed per-machine memory demand exceeds some machine's
      capacity is infeasible at any rate.
    * ``distance`` — optional (m, m) network distance matrix (same machine
      0, same rack 1, cross-rack k; must be non-negative with a zero
      diagonal). Inter-machine stream traffic is charged to both endpoint
      machines as extra CPU load, linear in the topology input rate, so
      R* keeps its closed form (``cost_model.network_unit_load``).
    * ``net_penalty`` — CPU points charged per (tuple/second × distance
      unit) on each endpoint of a cross-machine stream.
    """

    machine_types: np.ndarray
    capacity: np.ndarray
    profile: Profile
    mem_capacity: np.ndarray | None = None
    distance: np.ndarray | None = None
    net_penalty: float = 1.0

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "machine_types", np.asarray(self.machine_types, dtype=np.int64)
        )
        object.__setattr__(self, "capacity", np.asarray(self.capacity, dtype=np.float64))
        if self.machine_types.ndim != 1:
            raise ValueError("machine_types must be 1-D")
        if self.capacity.shape != self.machine_types.shape:
            raise ValueError("capacity must align with machine_types")
        if np.any(self.machine_types < 0) or np.any(
            self.machine_types >= self.profile.n_machine_types
        ):
            raise ValueError("machine type index out of profile range")
        if self.mem_capacity is not None:
            mem_capacity = np.asarray(self.mem_capacity, dtype=np.float64)
            object.__setattr__(self, "mem_capacity", mem_capacity)
            if mem_capacity.shape != self.machine_types.shape:
                raise ValueError("mem_capacity must align with machine_types")
            if np.any(mem_capacity < 0):
                raise ValueError("mem_capacity must be non-negative")
        if self.distance is not None:
            m = self.machine_types.shape[0]
            distance = np.asarray(self.distance, dtype=np.float64)
            object.__setattr__(self, "distance", distance)
            if distance.shape != (m, m):
                raise ValueError("distance must be (n_machines, n_machines)")
            if np.any(distance < 0):
                raise ValueError("distances must be non-negative")
            if np.any(np.diagonal(distance) != 0.0):
                raise ValueError("same-machine distance must be 0")
            if float(self.net_penalty) < 0.0:
                raise ValueError("net_penalty must be non-negative")

    @property
    def n_machines(self) -> int:
        return int(self.machine_types.shape[0])

    # ------------------------------------------------- resource predicates

    @property
    def has_memory(self) -> bool:
        """True when the memory hard constraint is active (demand *and*
        capacity modelled); otherwise memory never masks a placement."""
        return self.mem_capacity is not None and self.profile.mem is not None

    @property
    def has_network(self) -> bool:
        """True when a distance matrix is attached (the cut-traffic CPU
        term participates in scoring)."""
        return self.distance is not None

    @property
    def has_resources(self) -> bool:
        return self.has_memory or self.has_network

    def with_capacity(
        self, capacity: np.ndarray, mem_capacity: np.ndarray | None = None
    ) -> "Cluster":
        """Same machines, different per-machine capacity vector.

        The streaming runtime's drift scenarios (machine slowdown/removal)
        re-score placements against the *instantaneous* capacity; a removed
        machine is capacity 0.0 (the closed form then scores any placement
        with fixed MET on it as infeasible). Distance / memory / penalty
        fields are carried over unchanged; pass ``mem_capacity`` to
        substitute a residual memory vector as well (the multi-tenant
        residual view).
        """
        capacity = np.asarray(capacity, dtype=np.float64)
        if capacity.shape != self.machine_types.shape:
            raise ValueError("capacity must align with machine_types")
        return dataclasses.replace(
            self,
            capacity=capacity,
            mem_capacity=self.mem_capacity if mem_capacity is None else mem_capacity,
        )

    def with_resources(
        self,
        mem_capacity: np.ndarray | None = None,
        distance: np.ndarray | None = None,
        net_penalty: float | None = None,
    ) -> "Cluster":
        """Attach (or replace) resource-vector fields; None keeps a field."""
        return dataclasses.replace(
            self,
            mem_capacity=self.mem_capacity if mem_capacity is None else np.asarray(
                mem_capacity, dtype=np.float64
            ),
            distance=self.distance if distance is None else np.asarray(
                distance, dtype=np.float64
            ),
            net_penalty=self.net_penalty if net_penalty is None else float(net_penalty),
        )

    def without_network(self) -> "Cluster":
        """Distance-blind view: same machines/memory, no cut-traffic term
        (benchmark baseline for network-aware vs CPU-only placement)."""
        return dataclasses.replace(self, distance=None, net_penalty=1.0)

    def subcluster(
        self, machines: np.ndarray, capacity: np.ndarray | None = None
    ) -> "Cluster":
        """Restriction to ``machines`` (index array), carrying every
        resource field — the distance matrix restricts to the kept rows and
        columns. Used by the runtime controller's alive-subcluster replans.
        """
        machines = np.asarray(machines, dtype=np.int64)
        return Cluster(
            machine_types=self.machine_types[machines],
            capacity=self.capacity[machines] if capacity is None else capacity,
            profile=self.profile,
            mem_capacity=(
                None if self.mem_capacity is None else self.mem_capacity[machines]
            ),
            distance=(
                None
                if self.distance is None
                else self.distance[np.ix_(machines, machines)]
            ),
            net_penalty=self.net_penalty,
        )

    def e_for(self, task_types: np.ndarray) -> np.ndarray:
        """(len(task_types), n_machines) e matrix for concrete machines."""
        return self.profile.e[np.asarray(task_types)][:, self.machine_types]

    def met_for(self, task_types: np.ndarray) -> np.ndarray:
        return self.profile.met[np.asarray(task_types)][:, self.machine_types]

    def mem_for(self, task_types: np.ndarray) -> np.ndarray:
        """(len(task_types),) per-instance memory demand (zeros when memory
        is not modelled — machine-independent, unlike ``e_for``)."""
        task_types = np.asarray(task_types)
        if self.profile.mem is None:
            return np.zeros(task_types.shape, dtype=np.float64)
        return self.profile.mem[task_types]


def rack_distance_matrix(
    rack_of: np.ndarray,
    same_rack: float = 1.0,
    cross_rack: float = 2.0,
) -> np.ndarray:
    """(m, m) distance matrix from a per-machine rack id vector.

    The R-Storm distance model: same machine 0, same rack ``same_rack``
    (default 1), different racks ``cross_rack`` (default 2 — pass the
    paper-calibrated k for the actual fabric). Symmetric, zero diagonal.
    """
    rack_of = np.asarray(rack_of, dtype=np.int64)
    if rack_of.ndim != 1:
        raise ValueError("rack_of must be 1-D")
    same = rack_of[:, None] == rack_of[None, :]
    dist = np.where(same, float(same_rack), float(cross_rack))
    np.fill_diagonal(dist, 0.0)
    return dist


def paper_profile() -> Profile:
    """Task types: 0=spout, 1=lowCompute, 2=midCompute, 3=highCompute."""
    e_seconds = np.concatenate([SPOUT_E[None, :], PAPER_E_TABLE3], axis=0)
    e = e_seconds * 100.0  # CPU points per (tuple/second)
    met = np.broadcast_to(PAPER_MET[None, :], e.shape).copy()
    met[0] *= 0.5  # spouts carry less framework overhead
    return Profile(
        e=e,
        met=met,
        type_names=("spout", "lowCompute", "midCompute", "highCompute"),
        machine_type_names=("pentium", "core_i3", "core_i5"),
    )


def paper_cluster(
    counts: tuple[int, int, int] = (1, 1, 1), profile: Profile | None = None
) -> Cluster:
    """The paper's worker cluster: Machine1 Pentium, Machine2/4 i3, Machine3 i5.

    §6.1 uses three worker nodes (one i3 is the master). ``counts`` gives the
    number of machines per type — (1, 1, 1) is the paper's worker set;
    Table 4 scenarios use (2,2,2), (10,10,10), (20,70,90).
    """
    profile = profile or paper_profile()
    types = np.concatenate(
        [np.full(c, t, dtype=np.int64) for t, c in enumerate(counts)]
    )
    return Cluster(
        machine_types=types,
        capacity=np.full(types.shape, 100.0),
        profile=profile,
    )
