"""Core: faithful reproduction of the paper's scheduling algorithms.

Public API:
  graphs:      UserGraph, ExecutionGraph, linear/diamond/star topologies
  profiling:   Profile, Cluster, paper_profile, paper_cluster
  prediction:  predict (eq. 5/6)
  simulator:   simulate, simulate_batch, measured_tcu (§6.3 ground truth)
  schedulers:  schedule (Alg. 1+2), round_robin_schedule, optimal_schedule,
               refine (beyond-paper hill climb)
  metrics:     weighted_utilization, prediction_accuracy, gain_ratio
"""

from repro.core.cost_model import (
    Prediction,
    SkewModel,
    component_rates,
    instance_rates,
    max_stable_rate,
    max_stable_rate_batch,
    network_unit_load,
    predict,
    resource_operands,
)
from repro.core.first_assignment import first_assignment
from repro.core.graph import (
    ExecutionGraph,
    FieldsGrouping,
    UserGraph,
    diamond_topology,
    keyed_rolling_count_topology,
    linear_topology,
    rolling_count_topology,
    star_topology,
    unique_visitor_topology,
    wide_fanout_topology,
)
from repro.core.maximize_throughput import Schedule, maximize_throughput, schedule
from repro.core.metrics import (
    fairness_levels,
    gain_ratio,
    jain_index,
    per_machine_utilization,
    prediction_accuracy,
    weighted_utilization,
)
from repro.core.optimal import OptimalResult, optimal_schedule, placement_score
from repro.core.profiles import (
    Cluster,
    Profile,
    paper_cluster,
    paper_profile,
    rack_distance_matrix,
)
from repro.core.refine import RefineResult, refine
from repro.core.round_robin import round_robin_schedule
from repro.core.schedule_state import ScheduleState
from repro.core.simulator import SimResult, measured_tcu, simulate, simulate_batch

__all__ = [
    "Prediction",
    "component_rates",
    "instance_rates",
    "predict",
    "first_assignment",
    "ExecutionGraph",
    "FieldsGrouping",
    "SkewModel",
    "UserGraph",
    "diamond_topology",
    "keyed_rolling_count_topology",
    "linear_topology",
    "rolling_count_topology",
    "star_topology",
    "unique_visitor_topology",
    "wide_fanout_topology",
    "Schedule",
    "ScheduleState",
    "maximize_throughput",
    "schedule",
    "fairness_levels",
    "gain_ratio",
    "jain_index",
    "per_machine_utilization",
    "prediction_accuracy",
    "weighted_utilization",
    "OptimalResult",
    "optimal_schedule",
    "placement_score",
    "RefineResult",
    "refine",
    "max_stable_rate",
    "max_stable_rate_batch",
    "network_unit_load",
    "resource_operands",
    "Cluster",
    "Profile",
    "paper_cluster",
    "paper_profile",
    "rack_distance_matrix",
    "round_robin_schedule",
    "SimResult",
    "measured_tcu",
    "simulate",
    "simulate_batch",
]
