"""Optimal scheduler — exhaustive search over the design space (paper §3, §6).

The paper's brute-force baseline enumerates every (instance-count vector,
placement) combination, evaluates the overall throughput of each, and keeps
the best. The paper reports ~18 hours for 27 405 possibilities on a 4-socket
Xeon server; our beyond-paper speedup comes from three observations:

1. Instances of one component are interchangeable, so a placement is fully
   described by *how many* instances of each component land on each machine —
   a composition of N_i into m parts — collapsing the m^N assignment space
   into a multiset space.
2. The paper's objective (max throughput s.t. no machine over-utilized) is
   linear in the topology input rate, so each placement's score — its
   *maximum stable throughput* — has a closed form (``max_stable_rate``);
   no iterative simulation is needed to score a candidate.
3. All placements sharing an instance-count vector score in one vectorized
   batch (``max_stable_rate_batch``).
4. Machines of one type (and capacity) are interchangeable, so only one
   canonical representative per within-type permutation class needs
   scoring (``prune_symmetry``) — the rest are duplicates by symmetry.

See benchmarks/bench_sched_speed.py for the resulting wall-time comparison.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence

import numpy as np

from repro.core.cost_model import max_stable_rate, max_stable_rate_batch
from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = ["OptimalResult", "optimal_schedule", "placement_score"]


def placement_score(etg: ExecutionGraph, cluster: Cluster) -> float:
    """Score of a placement: its maximum stable throughput (paper eq. 2)."""
    _, thpt = max_stable_rate(etg, cluster)
    return float(thpt)


def _compositions(total: int, parts: int) -> Iterator[tuple[int, ...]]:
    """All ways to write ``total`` as an ordered sum of ``parts`` >= 0 ints."""
    if parts == 1:
        yield (total,)
        return
    for head in range(total + 1):
        for rest in _compositions(total - head, parts - 1):
            yield (head, *rest)


def _counts_to_assignment(counts: Sequence[int]) -> np.ndarray:
    """(m,) per-machine instance counts -> flat machine index list."""
    out: list[int] = []
    for w, c in enumerate(counts):
        out.extend([w] * int(c))
    return np.asarray(out, dtype=np.int64)


def _symmetry_runs(cluster: Cluster) -> list[tuple[int, int]]:
    """Maximal runs [start, end) of consecutive identical machines.

    Machines with the same type and capacity are interchangeable: permuting
    them permutes a placement without changing its score. Only runs of
    length >= 2 matter.
    """
    key = list(zip(cluster.machine_types.tolist(), cluster.capacity.tolist()))
    runs: list[tuple[int, int]] = []
    start = 0
    for w in range(1, cluster.n_machines + 1):
        if w == cluster.n_machines or key[w] != key[start]:
            if w - start >= 2:
                runs.append((start, w))
            start = w
    return runs


def _is_canonical(combo: tuple[tuple[int, ...], ...], runs: list[tuple[int, int]]) -> bool:
    """Keep one representative per machine-permutation equivalence class.

    ``combo[c][w]`` is the number of component-c instances on machine w.
    Within each run of identical machines, require the joint per-machine
    columns (count vectors across all components) to be lexicographically
    non-increasing; every equivalence class under within-run permutations
    contains exactly one such representative.
    """
    for start, end in runs:
        prev = tuple(counts[start] for counts in combo)
        for w in range(start + 1, end):
            col = tuple(counts[w] for counts in combo)
            if col > prev:
                return False
            prev = col
    return True


@dataclasses.dataclass(frozen=True)
class OptimalResult:
    etg: ExecutionGraph
    rate: float
    throughput: float
    candidates_evaluated: int


def optimal_schedule(
    utg: UserGraph,
    cluster: Cluster,
    max_total_tasks: int,
    max_per_machine: int | None = None,
    batch_size: int = 8192,
    prune_symmetry: bool = True,
) -> OptimalResult:
    """Exhaustive search. Exponential — only for small benchmark topologies.

    Args:
      utg: the user topology.
      cluster: the heterogeneous cluster.
      max_total_tasks: cap on sum of instances (the paper's eq. 1 bound,
        ``sum k_j``).
      max_per_machine: optional per-machine k_j cap on simultaneous tasks.
      batch_size: placements scored per vectorized sweep.
      prune_symmetry: machines of one type (and capacity) are
        interchangeable for scoring, so only canonical representatives of
        each within-type permutation class are evaluated — on the paper's
        3-type clusters this shrinks the candidate space combinatorially
        (roughly by ``prod_types c_t!`` on spread-out placements). The
        winning canonical placement *is* a concrete placement; disabling
        this re-enumerates every symmetric duplicate (for tests/audits).
    """
    n = utg.n_components
    m = cluster.n_machines
    runs = _symmetry_runs(cluster) if prune_symmetry else []
    best_etg: ExecutionGraph | None = None
    best_thpt = -1.0
    evaluated = 0

    # Enumerate instance-count vectors: each component >= 1 (paper constraint).
    for extra in _compositions_upto(max_total_tasks - n, n):
        n_inst = np.asarray(extra, dtype=np.int64) + 1
        template = ExecutionGraph(
            utg=utg,
            n_instances=n_inst,
            assignment=[np.zeros(int(k), dtype=np.int64) for k in n_inst],
        )
        # Per-component placement options as per-machine count vectors.
        per_comp_opts = [list(_compositions(int(k), m)) for k in n_inst]
        flat_batch: list[np.ndarray] = []

        def flush() -> None:
            nonlocal best_etg, best_thpt, evaluated
            if not flat_batch:
                return
            tm = np.stack(flat_batch, axis=0)
            _, thpt = max_stable_rate_batch(template, cluster, tm)
            evaluated += tm.shape[0]
            top = int(np.argmax(thpt))
            if float(thpt[top]) > best_thpt:
                best_thpt = float(thpt[top])
                assignment, off = [], 0
                for k in n_inst:
                    assignment.append(tm[top, off : off + int(k)].copy())
                    off += int(k)
                best_etg = ExecutionGraph(
                    utg=utg, n_instances=n_inst.copy(), assignment=assignment
                )
            flat_batch.clear()

        for combo in itertools.product(*per_comp_opts):
            if runs and not _is_canonical(combo, runs):
                continue
            if max_per_machine is not None:
                per_machine = np.sum(np.asarray(combo), axis=0)
                if np.any(per_machine > max_per_machine):
                    continue
            flat = np.concatenate([_counts_to_assignment(c) for c in combo])
            flat_batch.append(flat)
            if len(flat_batch) >= batch_size:
                flush()
        flush()

    if best_etg is None:
        raise ValueError("design space empty — raise max_total_tasks")
    rate, thpt = max_stable_rate(best_etg, cluster)
    return OptimalResult(
        etg=best_etg,
        rate=float(rate),
        throughput=float(thpt),
        candidates_evaluated=evaluated,
    )


def _compositions_upto(budget: int, parts: int) -> Iterator[tuple[int, ...]]:
    """All non-negative integer vectors of length ``parts`` with sum <= budget."""
    for total in range(budget + 1):
        yield from _compositions(total, parts)
