"""Optimal scheduler — exhaustive search over the design space (paper §3, §6).

The paper's brute-force baseline enumerates every (instance-count vector,
placement) combination, evaluates the overall throughput of each, and keeps
the best. The paper reports ~18 hours for 27 405 possibilities on a 4-socket
Xeon server; our beyond-paper speedup comes from five observations:

1. Instances of one component are interchangeable, so a placement is fully
   described by *how many* instances of each component land on each machine —
   a composition of N_i into m parts — collapsing the m^N assignment space
   into a multiset space.
2. The paper's objective (max throughput s.t. no machine over-utilized) is
   linear in the topology input rate, so each placement's score — its
   *maximum stable throughput* — has a closed form (``max_stable_rate``);
   no iterative simulation is needed to score a candidate.
3. All placements sharing an instance-count vector score in one vectorized
   batch (``max_stable_rate_batch``).
4. Machines of one type (and capacity) are interchangeable, so only one
   canonical representative per within-type permutation class needs
   scoring (``prune_symmetry``) — the rest are duplicates by symmetry.
5. The closed form also bounds a whole composition class from above
   without enumerating it (``prune_bound``): relaxing the per-machine
   constraints to their aggregate sum — and each component to its best
   single machine — gives an O(n·m) R* upper bound, so classes that
   cannot strictly beat the running best are skipped entirely.

Engines
-------
``engine="state"`` (default) enumerates each composition class as a dense
(B, n, m) count tensor — product indices, the canonical-symmetry filter and
the per-machine cap run as chunked NumPy array ops, and the counts convert
to (B, T) task->machine rows in one cumsum trick — so the only remaining
per-candidate Python is none at all. ``engine="reference"`` keeps the
original per-candidate ``itertools.product`` loop as the semantic
reference. Both score through the same ``max_stable_rate_batch`` rows and
select winners with identical first-strict-max semantics, so they return
identical results (asserted in ``tests/test_sched_equivalence.py``).

See benchmarks/bench_sched_speed.py and benchmarks/bench_refine.py for the
resulting wall-time comparisons, and docs/architecture.md for the design.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterator, Sequence

import numpy as np

from repro.core.cost_model import (
    component_rates,
    max_stable_rate,
    max_stable_rate_batch,
)
from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = ["OptimalResult", "optimal_schedule", "placement_score"]

# Relative inflation applied to the closed-form class bound before pruning:
# the bound math is exact in real arithmetic, so this only has to absorb
# float rounding between the bound's reductions and the scorer's (1e-15
# scale) — a pruned class then provably cannot contain a strict improvement.
_BOUND_SLACK = 1e-12


def _class_bound(
    n_inst: np.ndarray,
    cir_unit: np.ndarray,
    e_cm: np.ndarray,
    met_cm: np.ndarray,
    capacity: np.ndarray,
    mem_c: np.ndarray | None = None,
    mem_capacity: np.ndarray | None = None,
) -> float:
    """Upper bound on max stable throughput over *all* placements with
    instance counts ``n_inst`` — no enumeration, O(n·m).

    Two closed-form relaxations of ``R* = min_w (cap_w - met_w) / var_w``
    (both ignore that tasks compete for the same machines, so they can only
    over-estimate):

    * **aggregate** — summing the per-machine feasibility constraints gives
      ``R <= (Σ cap_w - Σ met_w) / Σ var_w``; lower-bounding each task's
      fixed/variable contribution by its cheapest machine keeps it an upper
      bound.
    * **per-task** — any task of component c lands on *some* machine w, and
      that machine's constraint alone gives
      ``R <= (cap_w - met_cw) / (e_cw · u_c)``; the best case is the max
      over machines, and every component must satisfy its own, so the min
      over components bounds R.

    On resource-vector clusters the hard memory constraint enters as two
    more valid relaxations (``mem_c`` per-instance demand, ``mem_capacity``
    per machine): a class whose aggregate memory demand exceeds the
    cluster's total memory — or one of whose components fits on no machine
    even alone — is infeasible at any rate. The cut-traffic term is
    *ignored*: network load only ever adds to the variable coefficient, so
    a net-blind bound remains an upper bound on the generalized objective.

    Returns the bounded throughput (``R_ub * Σ_c CIR_c(1)``), inflated by
    ``_BOUND_SLACK``; ``inf`` when unbounded, ``0.0`` when the class is
    infeasible at any rate (some component's fixed MET alone exceeds every
    machine's capacity, or total fixed MET exceeds total capacity).
    """
    u = cir_unit / n_inst                               # (n,) per-task rate
    total_met_min = float((n_inst * met_cm.min(axis=1)).sum())
    sum_cap = float(capacity.sum())
    if sum_cap < total_met_min:
        return 0.0
    total_var_min = float((n_inst * (e_cm.min(axis=1) * u)).sum())
    r_agg = (
        np.inf
        if total_var_min <= 0.0
        else (sum_cap - total_met_min) / total_var_min
    )
    head = capacity[None, :] - met_cm                   # (n, m)
    ok = head >= 0.0
    if mem_c is not None:
        if float((n_inst * mem_c).sum()) > float(mem_capacity.sum()):
            return 0.0  # aggregate memory demand exceeds the cluster's
        ok &= mem_c[:, None] <= mem_capacity[None, :]   # (n, m)
    if not np.all(ok.any(axis=1)):
        return 0.0  # some component fits on no machine even alone
    var = e_cm * u[:, None]                             # (n, m)
    with np.errstate(divide="ignore", over="ignore"):
        lim = np.where(var > 0.0, head / np.maximum(var, 1e-300), np.inf)
    lim = np.where(ok, lim, -np.inf)
    r_ub = min(r_agg, float(lim.max(axis=1).min()))
    if not np.isfinite(r_ub):
        return np.inf
    return r_ub * float(cir_unit.sum()) * (1.0 + _BOUND_SLACK)


def placement_score(etg: ExecutionGraph, cluster: Cluster) -> float:
    """Score of a placement: its maximum stable throughput (paper eq. 2)."""
    _, thpt = max_stable_rate(etg, cluster)
    return float(thpt)


def _ordered_classes(
    utg: UserGraph,
    max_total_tasks: int,
    prune_bound: bool,
    cir_unit: np.ndarray,
    e_cm: np.ndarray,
    met_cm: np.ndarray,
    capacity: np.ndarray,
    mem_c: np.ndarray | None = None,
    mem_capacity: np.ndarray | None = None,
) -> list[tuple[int, np.ndarray, float]]:
    """Composition classes as (original rank, n_inst, bound) in processing
    order.

    With the beam bound active, classes are visited **best-bound-first**
    (stable descending sort on the closed-form bound): the strongest
    classes establish a high running best immediately, and because bounds
    are sorted the search can stop at the first class whose bound cannot
    beat it — every remaining class is pruned in one step. Without the
    bound, the original enumeration order is kept (bounds are +inf).

    The original rank rides along for tie-breaking: the reported optimum
    is the same candidate the original-order search reports (see the
    acceptance rule in the engines), so reordering is invisible in
    results — only ``candidates_evaluated``/``classes_pruned`` move.
    """
    n = utg.n_components
    vecs = [
        np.asarray(extra, dtype=np.int64) + 1
        for extra in _compositions_upto(max_total_tasks - n, n)
    ]
    if not prune_bound:
        return [(i, v, np.inf) for i, v in enumerate(vecs)]
    bounds = np.array(
        [
            _class_bound(v, cir_unit, e_cm, met_cm, capacity, mem_c, mem_capacity)
            for v in vecs
        ]
    )
    order = np.argsort(-bounds, kind="stable")
    return [(int(i), vecs[i], float(bounds[i])) for i in order]


def _incumbent_seed(
    utg: UserGraph,
    cluster: Cluster,
    max_total_tasks: int,
    max_per_machine: int | None,
    backend: str,
) -> tuple[ExecutionGraph, float] | None:
    """``schedule()+refine()`` as the search's initial lower bound.

    The heuristic pipeline's result is a real placement, so its throughput
    is a valid incumbent — classes the bound proves can't beat it are
    pruned before the first candidate is scored. Only used when the
    incumbent actually lies inside the search space (instance budget and
    per-machine cap), otherwise seeding could report an optimum the space
    doesn't contain.
    """
    from repro.core.maximize_throughput import schedule
    from repro.core.refine import refine

    sched = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0)
    # The caller's backend is forwarded so backend="numpy" keeps the seed
    # throughput (and hence the prune boundary and the golden candidate
    # counts) bit-identical across hosts.
    inc = refine(sched.etg, cluster, backend=backend)
    if inc.etg.total_tasks > max_total_tasks:
        return None
    if max_per_machine is not None:
        per_machine = np.bincount(
            inc.etg.task_machine(), minlength=cluster.n_machines
        )
        if np.any(per_machine > max_per_machine):
            return None
    return inc.etg, float(inc.throughput)


def _compositions(total: int, parts: int) -> Iterator[tuple[int, ...]]:
    """All ways to write ``total`` as an ordered sum of ``parts`` >= 0 ints."""
    if parts == 1:
        yield (total,)
        return
    for head in range(total + 1):
        for rest in _compositions(total - head, parts - 1):
            yield (head, *rest)


def _counts_to_assignment(counts: Sequence[int]) -> np.ndarray:
    """(m,) per-machine instance counts -> flat machine index list."""
    out: list[int] = []
    for w, c in enumerate(counts):
        out.extend([w] * int(c))
    return np.asarray(out, dtype=np.int64)


def _symmetry_runs(cluster: Cluster) -> list[tuple[int, int]]:
    """Maximal runs [start, end) of consecutive identical machines.

    Machines with the same type and capacity are interchangeable: permuting
    them permutes a placement without changing its score. Only runs of
    length >= 2 matter.
    """
    key = list(zip(cluster.machine_types.tolist(), cluster.capacity.tolist()))
    runs: list[tuple[int, int]] = []
    start = 0
    for w in range(1, cluster.n_machines + 1):
        if w == cluster.n_machines or key[w] != key[start]:
            if w - start >= 2:
                runs.append((start, w))
            start = w
    return runs


def _is_canonical(combo: tuple[tuple[int, ...], ...], runs: list[tuple[int, int]]) -> bool:
    """Keep one representative per machine-permutation equivalence class.

    ``combo[c][w]`` is the number of component-c instances on machine w.
    Within each run of identical machines, require the joint per-machine
    columns (count vectors across all components) to be lexicographically
    non-increasing; every equivalence class under within-run permutations
    contains exactly one such representative.
    """
    for start, end in runs:
        prev = tuple(counts[start] for counts in combo)
        for w in range(start + 1, end):
            col = tuple(counts[w] for counts in combo)
            if col > prev:
                return False
            prev = col
    return True


def _canonical_mask(
    counts: np.ndarray, runs: list[tuple[int, int]]
) -> np.ndarray:
    """Vectorized ``_is_canonical`` over a (B, n, m) count tensor.

    A chain is non-increasing iff every adjacent column pair is; a column
    pair violates iff the first component where they differ increases.
    """
    B = counts.shape[0]
    keep = np.ones(B, dtype=bool)
    for start, end in runs:
        for w in range(start + 1, end):
            diff = counts[:, :, w] - counts[:, :, w - 1]     # (B, n)
            nz = diff != 0
            has = nz.any(axis=1)
            first = np.argmax(nz, axis=1)
            sign = diff[np.arange(B), first]
            keep &= ~(has & (sign > 0))
    return keep


def _counts_to_task_machine(counts: np.ndarray, n_inst: np.ndarray) -> np.ndarray:
    """(B, n, m) per-machine counts -> (B, T) flat machine rows (eq. 3 order).

    Per component, task j of the block lands on the number of machines whose
    cumulative count is <= j — a vectorized run-length decode that matches
    ``_counts_to_assignment``'s machine-major expansion exactly.
    """
    blocks = []
    for c in range(n_inst.shape[0]):
        k = int(n_inst[c])
        cums = counts[:, c, :].cumsum(axis=1)                # (B, m)
        j = np.arange(k)
        blocks.append((cums[:, None, :] <= j[None, :, None]).sum(axis=2))
    return np.concatenate(blocks, axis=1).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class OptimalResult:
    etg: ExecutionGraph
    rate: float
    throughput: float
    candidates_evaluated: int
    classes_pruned: int = 0


def optimal_schedule(
    utg: UserGraph,
    cluster: Cluster,
    max_total_tasks: int,
    max_per_machine: int | None = None,
    batch_size: int = 8192,
    prune_symmetry: bool = True,
    prune_bound: bool = True,
    engine: str = "state",
    backend: str = "auto",
    seed_incumbent: bool = True,
) -> OptimalResult:
    """Exhaustive search. Exponential — only for small benchmark topologies.

    Args:
      utg: the user topology.
      cluster: the heterogeneous cluster.
      max_total_tasks: cap on sum of instances (the paper's eq. 1 bound,
        ``sum k_j``).
      max_per_machine: optional per-machine k_j cap on simultaneous tasks.
      batch_size: placements scored per vectorized sweep.
      prune_symmetry: machines of one type (and capacity) are
        interchangeable for scoring, so only canonical representatives of
        each within-type permutation class are evaluated — on the paper's
        3-type clusters this shrinks the candidate space combinatorially
        (roughly by ``prod_types c_t!`` on spread-out placements). The
        winning canonical placement *is* a concrete placement; disabling
        this re-enumerates every symmetric duplicate (for tests/audits).
      prune_bound: skip whole composition classes whose closed-form R* beam
        bound (``_class_bound``: aggregate-capacity and per-task
        relaxations) cannot beat the best throughput found so far — no
        candidate of a pruned class is ever enumerated. Classes are
        visited best-bound-first and the search stops at the first class
        whose bound falls below the running best (every later class is
        pruned wholesale); an original-rank tie-break keeps the reported
        placement identical to the original-order search's. Exact: the
        returned optimum is unchanged, and under bit-exact scoring
        (``backend="numpy"``, or ``"auto"`` below the per-regime dispatch
        crossovers — every test scenario) both engines prune identically so
        ``candidates_evaluated`` still matches. The engines chunk sweeps
        differently, so if ``"auto"`` resolves JAX for some sweeps (very
        large classes clearing the element floor + machine gate) their
        ~1e-15 scores may break exact ties differently. ``classes_pruned``
        on the result counts the skips.
      engine: ``"state"`` (vectorized enumeration + filters, default) or
        ``"reference"`` (original per-candidate loop). Identical results.
      backend: closed-form scoring backend forwarded to
        ``max_stable_rate_batch`` — ``"auto"`` (default: NumPy below the
        regime's calibrated dispatch crossover, scatter-free JAX above),
        ``"numpy"`` (the reference floats), or ``"jax"`` (jitted float64,
        ~1e-15 agreement).
      seed_incumbent: start the beam bound from ``schedule()+refine()``'s
        throughput (a valid lower bound — it is a real placement) so
        pruning bites from the very first class. Only applies with
        ``prune_bound``, and only when the incumbent lies inside the
        search space (instance budget + per-machine cap); the reported
        optimum is unchanged either way.
    """
    if engine == "state":
        return _optimal_state(
            utg, cluster, max_total_tasks, max_per_machine, batch_size,
            prune_symmetry, prune_bound, backend, seed_incumbent,
        )
    if engine != "reference":
        raise ValueError(f"unknown engine {engine!r}; use 'state' or 'reference'")
    n = utg.n_components
    m = cluster.n_machines
    runs = _symmetry_runs(cluster) if prune_symmetry else []
    cir_unit = component_rates(utg, 1.0)
    e_cm = cluster.profile.e[utg.component_types][:, cluster.machine_types]
    met_cm = cluster.profile.met[utg.component_types][:, cluster.machine_types]
    mem_c = (
        cluster.profile.mem[utg.component_types] if cluster.has_memory else None
    )
    best_etg: ExecutionGraph | None = None
    best_thpt = -1.0
    best_rank = np.inf
    evaluated = 0
    pruned_classes = 0
    if prune_bound and seed_incumbent:
        seeded = _incumbent_seed(utg, cluster, max_total_tasks, max_per_machine, backend)
        if seeded is not None:
            best_etg, best_thpt = seeded

    # Composition classes (each component >= 1, the paper constraint),
    # best-bound-first when the beam bound is on.
    ordered = _ordered_classes(
        utg, max_total_tasks, prune_bound, cir_unit, e_cm, met_cm,
        cluster.capacity, mem_c, cluster.mem_capacity,
    )
    for pos, (rank, n_inst, bound) in enumerate(ordered):
        if prune_bound and bound < best_thpt:
            # Bounds are sorted descending: every remaining class is out.
            pruned_classes += len(ordered) - pos
            break
        template = ExecutionGraph(
            utg=utg,
            n_instances=n_inst,
            assignment=[np.zeros(int(k), dtype=np.int64) for k in n_inst],
        )
        # Per-component placement options as per-machine count vectors.
        per_comp_opts = [list(_compositions(int(k), m)) for k in n_inst]
        flat_batch: list[np.ndarray] = []

        def flush() -> None:
            nonlocal best_etg, best_thpt, best_rank, evaluated
            if not flat_batch:
                return
            tm = np.stack(flat_batch, axis=0)
            _, thpt = max_stable_rate_batch(template, cluster, tm, backend=backend)
            evaluated += tm.shape[0]
            top = int(np.argmax(thpt))
            # Strict improvement, or an exact tie from an earlier original
            # rank: the winner is the same candidate the original-order
            # search reports, so best-bound-first reordering (and the
            # incumbent seed) never changes the returned placement.
            if float(thpt[top]) > best_thpt or (
                float(thpt[top]) == best_thpt and rank < best_rank
            ):
                best_thpt = float(thpt[top])
                best_rank = rank
                assignment, off = [], 0
                for k in n_inst:
                    assignment.append(tm[top, off : off + int(k)].copy())
                    off += int(k)
                best_etg = ExecutionGraph(
                    utg=utg, n_instances=n_inst.copy(), assignment=assignment
                )
            flat_batch.clear()

        for combo in itertools.product(*per_comp_opts):
            if runs and not _is_canonical(combo, runs):
                continue
            if max_per_machine is not None:
                per_machine = np.sum(np.asarray(combo), axis=0)
                if np.any(per_machine > max_per_machine):
                    continue
            flat = np.concatenate([_counts_to_assignment(c) for c in combo])
            flat_batch.append(flat)
            if len(flat_batch) >= batch_size:
                flush()
        flush()

    if best_etg is None:
        raise ValueError("design space empty — raise max_total_tasks")
    rate, thpt = max_stable_rate(best_etg, cluster)
    return OptimalResult(
        etg=best_etg,
        rate=float(rate),
        throughput=float(thpt),
        candidates_evaluated=evaluated,
        classes_pruned=pruned_classes,
    )


def _optimal_state(
    utg: UserGraph,
    cluster: Cluster,
    max_total_tasks: int,
    max_per_machine: int | None,
    batch_size: int,
    prune_symmetry: bool,
    prune_bound: bool,
    backend: str,
    seed_incumbent: bool,
) -> OptimalResult:
    """Vectorized engine: dense count tensors per composition class.

    For each instance-count vector, candidate placements are rows of the
    cross product of per-component composition tables. Chunks of product
    indices unravel (C order — the same order ``itertools.product`` walks)
    into (B, n, m) count tensors; the canonical filter and per-machine cap
    are boolean masks; survivors convert to (B, T) rows and score in one
    ``max_stable_rate_batch`` sweep per chunk. Scores are row-independent
    and winners are first strict maxima, so chunk boundaries cannot change
    the result and the returned placement, score and
    ``candidates_evaluated`` match the reference engine exactly (both
    engines also apply the same ``_class_bound`` skips at the same class
    boundaries with identical running bests).
    """
    n = utg.n_components
    m = cluster.n_machines
    runs = _symmetry_runs(cluster) if prune_symmetry else []
    cir_unit = component_rates(utg, 1.0)
    e_cm = cluster.profile.e[utg.component_types][:, cluster.machine_types]
    met_cm = cluster.profile.met[utg.component_types][:, cluster.machine_types]
    mem_c = (
        cluster.profile.mem[utg.component_types] if cluster.has_memory else None
    )
    best_etg: ExecutionGraph | None = None
    best_thpt = -1.0
    best_rank = np.inf
    evaluated = 0
    pruned_classes = 0
    if prune_bound and seed_incumbent:
        seeded = _incumbent_seed(utg, cluster, max_total_tasks, max_per_machine, backend)
        if seeded is not None:
            best_etg, best_thpt = seeded

    ordered = _ordered_classes(
        utg, max_total_tasks, prune_bound, cir_unit, e_cm, met_cm,
        cluster.capacity, mem_c, cluster.mem_capacity,
    )
    for pos, (rank, n_inst, bound) in enumerate(ordered):
        if prune_bound and bound < best_thpt:
            pruned_classes += len(ordered) - pos
            break
        template = ExecutionGraph(
            utg=utg,
            n_instances=n_inst,
            assignment=[np.zeros(int(k), dtype=np.int64) for k in n_inst],
        )
        opts = [
            np.asarray(list(_compositions(int(k), m)), dtype=np.int64)
            for k in n_inst
        ]
        sizes = [o.shape[0] for o in opts]
        total = math.prod(sizes)  # Python int: exact for huge spaces
        for start in range(0, total, batch_size):
            idx = np.arange(start, min(start + batch_size, total))
            sel = np.unravel_index(idx, sizes)
            counts = np.stack(
                [opts[c][sel[c]] for c in range(n)], axis=1
            )  # (B, n, m)
            keep = np.ones(idx.size, dtype=bool)
            if runs:
                keep &= _canonical_mask(counts, runs)
            if max_per_machine is not None:
                keep &= (counts.sum(axis=1) <= max_per_machine).all(axis=1)
            counts = counts[keep]
            if counts.shape[0] == 0:
                continue
            tm = _counts_to_task_machine(counts, n_inst)
            _, thpt = max_stable_rate_batch(template, cluster, tm, backend=backend)
            evaluated += tm.shape[0]
            top = int(np.argmax(thpt))
            # Same acceptance rule as the reference engine: strict
            # improvement, or an exact tie from an earlier original rank.
            if float(thpt[top]) > best_thpt or (
                float(thpt[top]) == best_thpt and rank < best_rank
            ):
                best_thpt = float(thpt[top])
                best_rank = rank
                assignment, off = [], 0
                for k in n_inst:
                    assignment.append(tm[top, off : off + int(k)].copy())
                    off += int(k)
                best_etg = ExecutionGraph(
                    utg=utg, n_instances=n_inst.copy(), assignment=assignment
                )

    if best_etg is None:
        raise ValueError("design space empty — raise max_total_tasks")
    rate, thpt = max_stable_rate(best_etg, cluster)
    return OptimalResult(
        etg=best_etg,
        rate=float(rate),
        throughput=float(thpt),
        candidates_evaluated=evaluated,
        classes_pruned=pruned_classes,
    )


def _compositions_upto(budget: int, parts: int) -> Iterator[tuple[int, ...]]:
    """All non-negative integer vectors of length ``parts`` with sum <= budget."""
    for total in range(budget + 1):
        yield from _compositions(total, parts)
