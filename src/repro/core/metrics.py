"""Evaluation metrics: overall throughput, weighted utilization (eq. 7/8),
prediction accuracy (Fig. 6), throughput/utilization difference ratio
(Table 5).
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import ExecutionGraph
from repro.core.profiles import Cluster
from repro.core.simulator import SimResult

__all__ = [
    "per_machine_utilization",
    "weighted_utilization",
    "prediction_accuracy",
    "gain_ratio",
    "fairness_levels",
    "jain_index",
]


def per_machine_utilization(
    machine: np.ndarray, tcu: np.ndarray, n_machines: int
) -> np.ndarray:
    """(m,) utilization per machine: sum of hosted tasks' TCU.

    The one accumulation shared by eq. 7's weighting, the simulator readout
    and the streaming runtime's windowed metrics, so "machine utilization"
    means the same reduction everywhere. ``np.bincount`` accumulates
    sequentially in input order exactly like ``np.add.at`` (the streaming
    fingerprint goldens pin the bit-identity) but without the per-element
    ufunc dispatch — this runs three times per executor window.
    """
    return np.bincount(
        machine, weights=np.asarray(tcu, dtype=np.float64), minlength=n_machines
    )


def weighted_utilization(
    etg: ExecutionGraph, cluster: Cluster, sim: SimResult
) -> float:
    """Overall utilization U (eq. 7) with machine-type weights x_i (eq. 8).

    Weights favor machine types with more processing capability: for each
    *component type* c present in the topology and machine type t,
    ``x_{tc} = (1/e_{ct}) / sum_k (1/e_{ck})``; a machine type's weight is the
    sum over component types, and U is the weighted mean of the per-type
    average utilizations (normalized so weights sum to 1).
    """
    # Component types present (C <= n in the paper's notation); skip spouts.
    ctypes = np.unique(etg.utg.component_types)
    ctypes = ctypes[ctypes != 0] if (ctypes == 0).any() and len(ctypes) > 1 else ctypes
    mtypes = np.unique(cluster.machine_types)

    e = cluster.profile.e[np.ix_(ctypes, mtypes)]  # (C, T)
    inv = 1.0 / e
    x_ct = inv / inv.sum(axis=1, keepdims=True)    # eq. 8 per component type
    x_t = x_ct.sum(axis=0)                         # eq. 8 summed over C
    x_t = x_t / x_t.sum()

    util = per_machine_utilization(etg.task_machine(), sim.tcu, cluster.n_machines)
    u_bar = np.array(
        [util[cluster.machine_types == t].mean() for t in mtypes]
    )
    return float((x_t * u_bar).sum())              # eq. 7


def prediction_accuracy(predicted: np.ndarray, measured: np.ndarray) -> float:
    """Accuracy as 100 - mean absolute error in CPU points (both on 0..100).

    The paper reports ">92% accuracy" with max error < 8 points; we report
    100 minus the mean absolute difference between predicted and measured
    TCU, matching that reading.
    """
    predicted = np.asarray(predicted, dtype=np.float64)
    measured = np.asarray(measured, dtype=np.float64)
    return float(100.0 - np.abs(predicted - measured).mean())


def gain_ratio(
    thpt_ours: float, thpt_default: float, util_ours: float, util_default: float
) -> float:
    """Table 5 ratio: (throughput gain %) / (utilization gain %).

    > 1 means the proposed scheduler converts extra utilization into
    disproportionately more throughput (efficiency, not just busyness).
    """
    diff_thpt = (thpt_ours - thpt_default) / thpt_default * 100.0
    diff_util = (util_ours - util_default) / util_default * 100.0
    if diff_util == 0.0:
        return float("inf") if diff_thpt > 0 else 1.0
    return float(diff_thpt / diff_util)


def fairness_levels(
    rates: np.ndarray, targets: np.ndarray, priorities: np.ndarray | None = None
) -> np.ndarray:
    """(N,) weighted fairness level per tenant: ``(R/R_target) / priority``.

    The quantity the multi-tenant water-filling loop leximin-maximizes
    (Ghaderi et al.'s weighted max-min objective on satisfaction ratios);
    equal levels mean every tenant gets capacity proportional to
    ``priority * target``.
    """
    rates = np.asarray(rates, dtype=np.float64)
    targets = np.asarray(targets, dtype=np.float64)
    if priorities is None:
        priorities = np.ones_like(targets)
    return rates / (targets * np.asarray(priorities, dtype=np.float64))


def jain_index(values: np.ndarray) -> float:
    """Jain's fairness index of a nonnegative allocation vector:
    ``(sum x)^2 / (N * sum x^2)`` — 1.0 when perfectly even, 1/N when one
    tenant holds everything. Reported by the multi-tenant benchmark over
    the per-tenant fairness levels.
    """
    x = np.asarray(values, dtype=np.float64)
    denom = x.size * float((x * x).sum())
    if denom == 0.0:
        return 1.0
    return float(x.sum()) ** 2 / denom
