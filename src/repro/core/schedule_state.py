"""Incremental scheduling engine: flat ScheduleState + closed-form stepping.

The reference implementation of Algorithm 2 (``maximize_throughput`` in
``maximize_throughput.py``) re-derives everything from the ``ExecutionGraph``
on every iteration: ``predict`` walks all T tasks, ``with_new_instance``
copies the whole graph, and ``_grow_component`` runs a full greedy placement
attempt for *every* candidate target count — on the paper's large scenario
(20/70/90 machines, 478 tasks) that is ~600k O(m) numpy calls and ~25 s of
wall clock for 46 algorithm iterations.

This module rebuilds the hot path around three observations (see
docs/architecture.md for the full derivation):

1. **Flat structure-of-arrays state.** Instances of one component on one
   machine are indistinguishable, so the whole schedule collapses to an
   (n_components, n_machines) count matrix plus per-component instance
   totals. Adding an instance is an O(m) delta (the eq. 6 re-split touches
   only the grown component's row); rollback to the last stable schedule is
   a cheap snapshot/restore instead of a deep graph copy.

2. **Closed-form rate stepping.** eq. 5/6 are linear in the topology input
   rate R, so per-machine utilization is ``met_load + R * var_load`` with
   rate-independent coefficients and the binding machine's maximum stable
   rate has the closed form ``R* = min_w (cap_w - met_w) / var_w``. The
   raise loop jumps through its geometric schedule comparing against R*
   (O(1) per step after one O(m) reduction per structural change) instead
   of re-predicting all T tasks per step. Iterations within a relative
   float-uncertainty band of R* are decided by *exact rational
   arithmetic* on the cached linear coefficients (``fractions.Fraction``
   over the per-machine ``met_load``/``var_load`` floats), so the
   feasibility boundary is a hard number — no heuristic re-check band
   (the golden equivalence suite remains the gate that boundary
   decisions agree with the reference's per-task summation in practice).
   Trace semantics (one trace entry per Algorithm-2 iteration) are
   preserved.

3. **Closed-form growth feasibility.** Inside ``_grow_component`` the new
   chunk TCU is a fixed per-machine value, so greedy placement of k new
   instances succeeds iff ``sum_w max(0, floor(avail_w / tcu_w) - counts_w)
   >= k`` — no per-instance simulation needed to *reject* a target count.
   The scan over candidate targets becomes one vectorized (n_targets, m)
   computation; the exact reference greedy (same lexsort tie-breaking)
   runs only for the first target the closed form admits, preserving the
   reference placement order exactly.

The engine is selected via ``schedule(..., engine="incremental")`` (the
default); ``engine="reference"`` runs the original path. Golden tests in
``tests/test_sched_equivalence.py`` assert both produce identical final
``(rate, n_instances, assignment)`` across topologies and cluster sizes.
"""

from __future__ import annotations

from fractions import Fraction

import numpy as np

from repro.core import cost_model
from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = ["ScheduleState", "maximize_throughput_incremental"]

# Relative half-width of the float pre-filter around the closed-form R*.
# Rates outside the band are decided by the float comparison alone (the
# float R* is within a few ulps of the exact rational value, far inside
# 1e-9 relative); rates inside the band are decided exactly, in rational
# arithmetic over the cached linear coefficients (`feasible_linear_exact`).
_RSTAR_GUARD = 1e-9


class ScheduleState:
    """Flat, incrementally-updatable schedule state (structure of arrays).

    Instead of per-instance objects, the state stores:

    * ``n_instances``   (n,)   — instance count per component;
    * ``comp_counts``   (n, m) — instances of component c on machine w;
    * ``assignment``    list of per-component machine-index lists, in the
      order instances were added (preserves ``with_new_instance`` append
      semantics so the final ETG is byte-identical to the reference path);
    * cached profile slices ``e_cm``/``met_cm`` (n, m) for the concrete
      cluster, and the unit-rate component input rates ``cir_unit`` (n,).

    Per-machine accumulators ``met_load`` and ``var_load`` (d util / d R)
    are derived from the count matrix in O(n·m) and cached; structural
    mutations invalidate the cache. All mutation is O(m) per added
    instance.
    """

    __slots__ = (
        "utg",
        "cluster",
        "n_instances",
        "assignment",
        "comp_counts",
        "e_cm",
        "met_cm",
        "cir_unit",
        "mem_c",
        "skew",
        "_met_load",
        "_var_load",
        "_mem_load",
        "_net_load",
    )

    def __init__(
        self,
        utg: UserGraph,
        cluster: Cluster,
        etg: ExecutionGraph,
        skew: "cost_model.SkewModel | None" = None,
    ):
        self.utg = utg
        self.cluster = cluster
        self.n_instances = etg.n_instances.copy()
        self.assignment = [list(map(int, a)) for a in etg.assignment]
        n, m = utg.n_components, cluster.n_machines
        ttypes = utg.component_types
        self.e_cm = cluster.profile.e[ttypes][:, cluster.machine_types]
        self.met_cm = cluster.profile.met[ttypes][:, cluster.machine_types]
        self.cir_unit = cost_model.component_rates(utg, 1.0)
        self.mem_c = cluster.profile.mem[ttypes] if cluster.has_memory else None
        if skew is not None and skew.utg is not utg:
            raise ValueError("skew model was built for a different topology")
        self.skew = skew
        self.comp_counts = np.zeros((n, m), dtype=np.int64)
        for c, machines in enumerate(self.assignment):
            for w in machines:
                self.comp_counts[c, w] += 1
        self._met_load: np.ndarray | None = None
        self._var_load: np.ndarray | None = None
        self._mem_load: np.ndarray | None = None
        self._net_load: np.ndarray | None = None

    @classmethod
    def from_etg(
        cls,
        etg: ExecutionGraph,
        cluster: Cluster,
        skew: "cost_model.SkewModel | None" = None,
    ) -> "ScheduleState":
        return cls(etg.utg, cluster, etg, skew=skew)

    # ------------------------------------------------------------- loads

    @property
    def met_load(self) -> np.ndarray:
        """(m,) fixed (rate-independent) MET load per machine."""
        if self._met_load is None:
            self._met_load = (self.met_cm * self.comp_counts).sum(axis=0)
        return self._met_load

    def _skew_variable_load(self, cir: np.ndarray) -> np.ndarray:
        """(m,) variable load for a per-component input-rate vector,
        accumulated per instance: keyed components at their realized key
        shares, shuffle components at the exact even split. The single
        skew accumulation both ``var_load`` and ``utilization`` use."""
        var = np.zeros(self.cluster.n_machines, dtype=np.float64)
        for c in range(self.utg.n_components):
            nk = int(self.n_instances[c])
            frac = self.skew.instance_fractions(c, nk)
            w = np.asarray(self.assignment[c], dtype=np.int64)
            ir = np.full(nk, cir[c] / nk) if frac is None else cir[c] * frac
            np.add.at(var, w, self.e_cm[c, w] * ir)
        return var

    @property
    def var_load(self) -> np.ndarray:
        """(m,) d utilization / d rate per machine at the current structure."""
        if self._var_load is None:
            if self.skew is None:
                per_unit = self.cir_unit / self.n_instances
                self._var_load = (
                    self.e_cm * self.comp_counts * per_unit[:, None]
                ).sum(axis=0)
            else:
                # Keyed components: instances are no longer interchangeable
                # (each handles its own key share), so accumulate per
                # instance instead of per (component, machine) count.
                self._var_load = self._skew_variable_load(self.cir_unit)
        return self._var_load

    @property
    def mem_load(self) -> np.ndarray:
        """(m,) resident memory per machine (rate-independent hard resource).

        Accumulated per task via ``np.add.at`` so the floats match the batch
        scorer's memory-mask accumulation exactly. Zeros on clusters without
        a memory model.
        """
        if self._mem_load is None:
            load = np.zeros(self.cluster.n_machines, dtype=np.float64)
            if self.mem_c is not None:
                comp = np.repeat(
                    np.arange(self.utg.n_components), self.n_instances
                )
                np.add.at(load, self.task_machine(), self.mem_c[comp])
            self._mem_load = load
        return self._mem_load

    @property
    def net_load(self) -> np.ndarray:
        """(m,) d network-load / d rate per machine — the cut-traffic term.

        ``cost_model.network_unit_load`` on the current placement (the same
        operands the batch scorer uses, so incremental and batched scores
        agree). Recomputed lazily after structural mutations, like the
        other load caches. Zeros on distance-free clusters.
        """
        if self._net_load is None:
            if not self.cluster.has_network:
                self._net_load = np.zeros(
                    self.cluster.n_machines, dtype=np.float64
                )
            else:
                comp = np.repeat(
                    np.arange(self.utg.n_components), self.n_instances
                )
                if self.skew is None:
                    unit_ir = (self.cir_unit / self.n_instances)[comp]
                else:
                    unit_ir = self.skew.per_task_unit_ir(self.n_instances)
                self._net_load = cost_model.network_unit_load(
                    self.task_machine()[None, :],
                    comp,
                    unit_ir,
                    self.utg.alpha,
                    self.cir_unit,
                    self.utg.edges,
                    self.cluster.distance,
                    self.cluster.net_penalty,
                )[0]
        return self._net_load

    def utilization(self, rate: float) -> np.ndarray:
        """(m,) predicted machine utilization at topology input rate ``rate``.

        Uses the same eq. 6 propagation as the reference (``component_rates``
        at the actual rate, not ``cir_unit * rate``) so per-chunk TCUs match
        the reference floats exactly; the per-machine summation is collapsed
        from per-task to per-component, which can differ from the
        reference's ``np.add.at`` accumulation in the last ulp. With a skew
        model, keyed components accumulate per instance at their realized
        key shares (the skew-aware utilization bound).
        """
        cir = cost_model.component_rates(self.utg, rate)
        if self.skew is not None:
            util = self.met_load + self._skew_variable_load(cir)
        else:
            per_inst = cir / self.n_instances
            util = self.met_load + (
                self.e_cm * self.comp_counts * per_inst[:, None]
            ).sum(axis=0)
        if self.cluster.has_network:
            util = util + rate * self.net_load
        return util

    def feasible(self, rate: float) -> bool:
        """Reference feasibility: every machine's MAC >= 0 at ``rate``."""
        return bool(np.all(self.cluster.capacity - self.utilization(rate) >= 0.0))

    def max_stable_rate(self) -> float:
        """Closed-form R* = min_w (cap_w - met_w) / (var_w + net_w).

        Paper eq. 5 linearity; the cut-traffic term is linear in R too, so
        folding ``net_load`` into the variable coefficient keeps the closed
        form exact. Memory is rate-independent, so an over-memory machine
        makes the placement infeasible at any rate (R* = 0).
        """
        head = self.cluster.capacity - self.met_load
        if np.any(head < 0.0):
            return 0.0
        if self.cluster.has_memory and np.any(
            self.mem_load > self.cluster.mem_capacity
        ):
            return 0.0
        var = self.var_load
        if self.cluster.has_network:
            var = var + self.net_load
        with np.errstate(divide="ignore"):
            limits = np.where(var > 0.0, head / np.maximum(var, 1e-300), np.inf)
        return float(max(np.min(limits), 0.0))

    def max_stable_rate_exact(self) -> "Fraction | None":
        """Exact rational R* of the linear load model (``None`` = unbounded).

        Treats the cached float coefficients as exact rationals, so
        ``rate`` is stable iff ``Fraction(rate) <= max_stable_rate_exact()``
        — the feasibility boundary is a hard number, with no float-rounding
        band around it. A negative result means the rate-independent load
        alone (MET, or the hard memory constraint) already exceeds some
        machine's capacity. The cut-traffic coefficient enters the rational
        arithmetic exactly (``Fraction(var) + Fraction(net)``).
        """
        if self.cluster.has_memory and np.any(
            self.mem_load > self.cluster.mem_capacity
        ):
            return Fraction(-1)
        best: Fraction | None = None
        for cap_w, met_w, var_w, net_w in zip(
            self.cluster.capacity.tolist(),
            self.met_load.tolist(),
            self.var_load.tolist(),
            self._net_list(),
        ):
            head = Fraction(cap_w) - Fraction(met_w)
            var = Fraction(var_w) + Fraction(net_w)
            if var > 0:
                lim = head / var
            elif head < 0:
                return Fraction(-1)
            else:
                continue
            if best is None or lim < best:
                best = lim
        return best

    def _net_list(self) -> list[float]:
        """Per-machine cut-traffic coefficients for the exact paths (all
        zeros on distance-free clusters, without touching the cache)."""
        if not self.cluster.has_network:
            return [0.0] * self.cluster.n_machines
        return self.net_load.tolist()

    def feasible_linear_exact(self, rate: float) -> bool:
        """Exact feasibility of the linear model at ``rate``.

        Evaluates ``met_load_w + rate * var_load_w <= cap_w`` per machine in
        rational arithmetic over the cached float coefficients — the
        arbiter for rates inside the float pre-filter band around R*.
        """
        return self.first_over_machine_exact(rate) is None

    def first_over_machine_exact(self, rate: float) -> "int | None":
        """First machine (reference index order) over capacity at ``rate``
        under the exact linear model, or ``None`` if every machine fits.
        A machine over its memory capacity is over at any rate."""
        r = Fraction(rate)
        mem_over = (
            self.mem_load > self.cluster.mem_capacity
            if self.cluster.has_memory
            else None
        )
        for w, (cap_w, met_w, var_w, net_w) in enumerate(
            zip(
                self.cluster.capacity.tolist(),
                self.met_load.tolist(),
                self.var_load.tolist(),
                self._net_list(),
            )
        ):
            if mem_over is not None and mem_over[w]:
                return w
            util = Fraction(met_w) + r * (Fraction(var_w) + Fraction(net_w))
            if util > Fraction(cap_w):
                return w
        return None

    # --------------------------------------------------------- mutation

    def add_instance(self, component: int, machine: int) -> None:
        """O(m) delta update: append one instance of ``component`` on ``machine``."""
        self.comp_counts[component, machine] += 1
        self.n_instances[component] += 1
        self.assignment[component].append(int(machine))
        self._met_load = None
        self._var_load = None
        self._mem_load = None
        self._net_load = None

    def relocate_instance(self, component: int, k: int, machine: int) -> None:
        """O(1) delta: move instance (component, k) to ``machine``.

        Instance counts are unchanged, so the per-instance split (eq. 6) is
        untouched — only two entries of the count matrix move.
        """
        src = self.assignment[component][k]
        self.comp_counts[component, src] -= 1
        self.comp_counts[component, machine] += 1
        self.assignment[component][k] = int(machine)
        self._met_load = None
        self._var_load = None
        self._mem_load = None
        self._net_load = None

    def swap_instances(self, ca: int, ka: int, cb: int, kb: int) -> None:
        """O(1) delta: exchange the machines of instances (ca, ka) and (cb, kb)."""
        wa = self.assignment[ca][ka]
        wb = self.assignment[cb][kb]
        self.relocate_instance(ca, ka, wb)
        self.relocate_instance(cb, kb, wa)

    def drop_instance(self, component: int, k: int) -> None:
        """O(m) delta: remove instance (component, k); the component's stream
        re-splits over the remaining instances (eq. 6)."""
        if int(self.n_instances[component]) < 2:
            raise ValueError("every component needs >= 1 instance (paper constraint)")
        w = self.assignment[component].pop(k)
        self.comp_counts[component, w] -= 1
        self.n_instances[component] -= 1
        self._met_load = None
        self._var_load = None
        self._mem_load = None
        self._net_load = None

    def evacuate_machines(self, dead: np.ndarray, rate: float) -> int:
        """Relocate every instance hosted on a ``dead``-masked machine.

        A hill climb scoring closed-form throughput cannot escape the
        0-throughput plateau when *several* instances sit on a dead (or
        draining) machine — no single move restores feasibility — so such
        machines are drained greedily first: each stranded instance moves
        to the feasible non-dead machine with the least chunk TCU (ties
        toward most remaining head, ``_greedy_place``'s rule), and
        ``refine`` polishes from there. Returns the number of relocations.
        The same primitive serves machine *failure* (capacity already 0)
        and planned *drain* (capacity-notice scale-in: pass the mask of
        machines dead in the lookahead capacity).
        """
        from repro.core.maximize_throughput import _least_tcu_machine

        dead = np.asarray(dead, dtype=bool)
        if not dead.any():
            return 0
        cir = cost_model.component_rates(self.utg, rate)
        per_inst = cir / self.n_instances
        util = self.utilization(rate)
        mem = self.mem_load.copy() if self.cluster.has_memory else None
        moves = 0
        for c in range(self.utg.n_components):
            tcu_w = self.e_cm[c] * per_inst[c] + self.met_cm[c]
            for k, w in enumerate(self.assignment[c]):
                if not dead[w]:
                    continue
                # Dead machines get -inf head so the shared rule never
                # picks them; when nothing fits, least-overloaded alive.
                head = np.where(dead, -np.inf, self.cluster.capacity - util - tcu_w)
                if mem is not None:
                    # Machines the instance's memory would not fit on are
                    # masked out of the fit rule; the nothing-fits fallback
                    # stays least-overloaded-alive (memory-blind — refine
                    # cannot polish from a stranded instance).
                    fit_head = np.where(
                        mem + self.mem_c[c] > self.cluster.mem_capacity,
                        -np.inf,
                        head,
                    )
                else:
                    fit_head = head
                target = _least_tcu_machine(tcu_w, fit_head)
                if target is None:
                    target = int(np.argmax(head))
                self.relocate_instance(c, k, target)
                util[w] -= tcu_w[w]
                util[target] += tcu_w[target]
                if mem is not None:
                    mem[w] -= self.mem_c[c]
                    mem[target] += self.mem_c[c]
                moves += 1
        return moves

    # ------------------------------------------------------ batch export

    def task_machine(self) -> np.ndarray:
        """(T,) flattened machine per task (paper eq. 3 order), for use as the
        base row when building candidate batches for ``max_stable_rate_batch``."""
        flat: list[int] = []
        for machines in self.assignment:
            flat.extend(machines)
        return np.asarray(flat, dtype=np.int64)

    def component_offsets(self) -> np.ndarray:
        """(n+1,) start offset of each component's block in the flattened
        task order; ``offsets[c] + k`` is the column of instance (c, k)."""
        return np.concatenate(
            [np.zeros(1, dtype=np.int64), np.cumsum(self.n_instances)]
        )

    def template_etg(self, n_instances: np.ndarray | None = None) -> ExecutionGraph:
        """Shape-only ETG for batched scoring (assignment is a placeholder).

        ``max_stable_rate_batch`` reads only the UTG and instance counts from
        its template — candidate placements come in as (B, T) rows — so the
        export is O(n), no deep copy of the real assignment.
        """
        if n_instances is None:
            n_instances = self.n_instances
        n_instances = np.asarray(n_instances, dtype=np.int64)
        return ExecutionGraph(
            utg=self.utg,
            n_instances=n_instances.copy(),
            assignment=[np.zeros(int(k), dtype=np.int64) for k in n_instances],
        )

    def score_task_machine_batch(
        self,
        task_machine: np.ndarray,
        n_instances: np.ndarray | None = None,
        backend: str = "numpy",
    ) -> tuple[np.ndarray, np.ndarray]:
        """Closed-form (rate, throughput) of B exported candidate placements.

        Bit-identical to ``cost_model.max_stable_rate_batch`` on a template
        with the same instance counts — both call the one shared
        ``closed_form_rates`` core with identical per-task gathers — but
        skips per-call ``ExecutionGraph`` construction and the Python eq. 6
        walk by reusing the cached ``e_cm``/``met_cm``/``cir_unit`` slices.
        This is the scoring entry point behind the refine/optimal batch
        engines.

        Args:
          task_machine: (B, T') candidate rows, T' = sum of each row's
            instance counts (every row must share one task total).
          n_instances: per-component counts for the candidates — a shared
            (n,) vector (defaults to the current state's counts; pass a
            modified vector for ADD/DROP/GROW-style candidates), or a
            (B, n) matrix giving every row its *own* counts (lockstep
            growth chains batching different components' next steps into
            one sweep). Per-row scores are bit-identical to scoring each
            row against its own shared-count template.
          backend: ``"numpy"`` (reference floats), ``"jax"`` (jitted
            float64 scatter-free closed form, ~1e-15 relative agreement;
            falls back to NumPy when JAX is unavailable), or ``"auto"``
            (JAX above the regime's calibrated element-count crossover,
            machine-count gated on CPU — skew rows dispatch under the
            ``"skew"`` regime; the jitted kernel is skew-agnostic).
        """
        n_inst = self.n_instances if n_instances is None else np.asarray(
            n_instances, dtype=np.int64
        )
        n = self.utg.n_components
        task_machine = np.asarray(task_machine, dtype=np.int64)
        if task_machine.ndim != 2:
            raise ValueError("task_machine must be (B, sum(n_instances))")
        from repro.core.simulator import resolve_closed_form_backend

        n_machines = self.cluster.capacity.shape[0]
        if self.skew is not None:
            # Skew-aware scoring: keyed components' unit IR comes from the
            # realized per-instance fractions; the gathers below feed the
            # same closed-form core either backend runs.
            if n_inst.ndim == 2:
                if n_inst.shape != (task_machine.shape[0], n):
                    raise ValueError("per-row n_instances must be (B, n)")
                comp, _ = cost_model.per_row_task_maps(
                    self.cir_unit, n_inst, task_machine.shape[1]
                )
                unit_ir = self.skew.per_row_unit_ir(n_inst)
                gather_comp = comp
            else:
                comp = np.repeat(np.arange(n), n_inst)
                if task_machine.shape[1] != comp.shape[0]:
                    raise ValueError("task_machine must be (B, sum(n_instances))")
                unit_ir = self.skew.per_task_unit_ir(n_inst)
                gather_comp = comp[None, :]
            net_var, mem, mem_cap = self._resource_operands(
                task_machine, comp, unit_ir
            )
            if (
                resolve_closed_form_backend(
                    backend,
                    task_machine.size,
                    regime="skew",
                    n_machines=n_machines,
                    site="score_task_machine_batch",
                )
                == "jax"
            ):
                from repro.core.sim_jax import closed_form_rates_jax

                return closed_form_rates_jax(
                    task_machine,
                    comp,
                    unit_ir,
                    self.e_cm,
                    self.met_cm,
                    self.cluster.capacity,
                    net_var=net_var,
                    mem=mem,
                    mem_capacity=mem_cap,
                )
            e = self.e_cm[gather_comp, task_machine]
            met = self.met_cm[gather_comp, task_machine]
            return cost_model.closed_form_rates(
                task_machine, e, met, unit_ir, self.cluster.capacity,
                net_var=net_var, mem=mem, mem_capacity=mem_cap,
            )
        if n_inst.ndim == 2:
            if n_inst.shape != (task_machine.shape[0], n):
                raise ValueError("per-row n_instances must be (B, n)")
            comp, unit_ir = cost_model.per_row_task_maps(
                self.cir_unit, n_inst, task_machine.shape[1]
            )                                             # each (B, T)
            gather_comp = comp
        else:
            comp = np.repeat(np.arange(n), n_inst)
            if task_machine.shape[1] != comp.shape[0]:
                raise ValueError("task_machine must be (B, sum(n_instances))")
            # Per-component division then gather: per-element operands match
            # instance_rates()' per-task division exactly, so floats agree.
            unit_ir = (self.cir_unit / n_inst)[comp]
            gather_comp = comp[None, :]
        net_var, mem, mem_cap = self._resource_operands(
            task_machine, comp, unit_ir
        )
        if (
            resolve_closed_form_backend(
                backend,
                task_machine.size,
                regime="per_row" if n_inst.ndim == 2 else "shared",
                n_machines=n_machines,
                site="score_task_machine_batch",
            )
            == "jax"
        ):
            from repro.core.sim_jax import closed_form_rates_jax

            return closed_form_rates_jax(
                task_machine,
                comp,
                unit_ir,
                self.e_cm,
                self.met_cm,
                self.cluster.capacity,
                net_var=net_var,
                mem=mem,
                mem_capacity=mem_cap,
            )
        e = self.e_cm[gather_comp, task_machine]          # (B, T)
        met = self.met_cm[gather_comp, task_machine]
        return cost_model.closed_form_rates(
            task_machine, e, met, unit_ir, self.cluster.capacity,
            net_var=net_var, mem=mem, mem_capacity=mem_cap,
        )

    def _resource_operands(
        self,
        task_machine: np.ndarray,
        comp: np.ndarray,
        unit_ir: np.ndarray,
    ) -> tuple[np.ndarray | None, np.ndarray | None, np.ndarray | None]:
        """Resource-vector extras for a candidate batch — all ``None`` on
        scalar-CPU clusters so default scoring stays byte-identical."""
        if not self.cluster.has_resources:
            return None, None, None
        return cost_model.resource_operands(
            self.cluster,
            task_machine,
            comp,
            unit_ir,
            self.utg.alpha,
            self.cir_unit,
            self.utg.edges,
            self.utg.component_types,
        )

    def snapshot(self) -> tuple:
        return (
            self.n_instances.copy(),
            self.comp_counts.copy(),
            [list(a) for a in self.assignment],
        )

    def restore(self, snap: tuple) -> None:
        self.n_instances = snap[0].copy()
        self.comp_counts = snap[1].copy()
        self.assignment = [list(a) for a in snap[2]]
        self._met_load = None
        self._var_load = None
        self._mem_load = None
        self._net_load = None

    def to_etg(self) -> ExecutionGraph:
        return ExecutionGraph(
            utg=self.utg,
            n_instances=self.n_instances.copy(),
            assignment=[np.asarray(a, dtype=np.int64) for a in self.assignment],
        )


def _grow_component_fast(
    state: ScheduleState,
    component: int,
    rate: float,
    max_extra: int | None = None,
) -> int:
    """Incremental equivalent of the reference ``_grow_component``.

    Scans candidate target counts with the closed-form per-machine capacity
    bound (one vectorized (n_targets, m) pass), then runs the exact greedy
    (``_greedy_place``, the same code path as the reference engine) for
    admitted targets only. Mutates ``state`` in place on success.

    Returns the number of instances added (0 if no target packs).
    """
    from repro.core.maximize_throughput import _greedy_place

    cluster = state.cluster
    cap = cluster.capacity
    m = cluster.n_machines
    n0 = int(state.n_instances[component])
    cir_vec = cost_model.component_rates(state.utg, rate)
    cir = cir_vec[component]
    e_row = state.e_cm[component]
    met_row = state.met_cm[component]
    existing_counts = state.comp_counts[component]

    # Machine load from everything except this component (its variable part
    # re-splits with the new count; reference subtracts the same quantity).
    per_inst = cir_vec / state.n_instances
    util = state.met_load + (
        state.e_cm * state.comp_counts * per_inst[:, None]
    ).sum(axis=0)
    if cluster.has_network:
        # Current cut-traffic load enters the head as a fixed charge (the
        # grown component's own re-split is approximated as unchanged —
        # the main loop re-scores the true generalized R* after growth).
        util = util + rate * state.net_load
    own_tcu = e_row * (cir / n0) + met_row
    base_load = util - existing_counts * own_tcu

    # Hard memory constraint: at most floor(room / mem_c) new instances per
    # machine (no float slack — memory infeasibility cannot be admitted;
    # under-counting an exact fit by one is merely conservative).
    mem_new = None
    if cluster.has_memory and float(state.mem_c[component]) > 0.0:
        mem_room = np.maximum(cluster.mem_capacity - state.mem_load, 0.0)
        mem_new = np.floor(mem_room / float(state.mem_c[component]))

    max_target = n0 + (max_extra if max_extra is not None else max(2 * n0, 2 * m, 16))
    targets = np.arange(n0 + 1, max_target + 1)
    if targets.size == 0:
        return 0

    # Closed-form packing bound: with a fixed per-machine chunk TCU, greedy
    # placement order cannot change how many chunks fit, so target t packs
    # iff sum_w max(0, floor(avail_w / tcu_w(t)) - counts_w) >= t - n0.
    # The +1e-9 slack absorbs the reference's repeated-addition rounding;
    # admitted targets are confirmed by the exact greedy below.
    tcu_t = e_row[None, :] * (cir / targets)[:, None] + met_row[None, :]
    avail = cap - base_load
    with np.errstate(divide="ignore", invalid="ignore"):
        fit = np.floor(avail[None, :] / tcu_t + 1e-9)
    fit = np.where(np.isfinite(fit), fit, 0.0)
    # A zero-cost chunk (e == met == 0 for this type pair) fits without
    # bound on any machine that is not already over capacity.
    unlimited = (tcu_t <= 0.0) & (avail[None, :] >= 0.0)
    fit = np.where(unlimited, float(max_target), fit)
    n_new_w = np.clip(fit - existing_counts[None, :], 0.0, None)
    if mem_new is not None:
        n_new_w = np.minimum(n_new_w, mem_new[None, :])
    n_new = n_new_w.sum(axis=1)
    admitted = targets[n_new >= (targets - n0)]

    for target in admitted:
        target = int(target)
        per_ir = cir / target
        tcu = e_row * per_ir + met_row
        placed = _greedy_place(
            cap, base_load, existing_counts, tcu, target - n0, max_new=mem_new
        )
        if placed is None:
            continue
        for w in placed:
            state.add_instance(component, w)
        return len(placed)
    return 0


def _hottest_component(state: ScheduleState, machine: int, rate: float) -> int:
    """Component owning the hottest task on ``machine`` (reference semantics).

    All instances of a component on one machine share one TCU, and tasks are
    ordered component-major, so the reference ``argmax`` over per-task TCUs
    reduces to a first-max argmax over per-component TCUs.
    """
    cir = cost_model.component_rates(state.utg, rate)
    per_inst = cir / state.n_instances
    tcu_c = state.e_cm[:, machine] * per_inst + state.met_cm[:, machine]
    present = state.comp_counts[:, machine] > 0
    return int(np.argmax(np.where(present, tcu_c, -np.inf)))


def maximize_throughput_incremental(
    etg: ExecutionGraph,
    cluster: Cluster,
    r0: float,
    rate_epsilon: float = 1.0,
    max_iters: int = 100_000,
):
    """Algorithm 2 with the incremental engine; reference control flow."""
    # Imported here, not at module level: maximize_throughput imports this
    # module lazily, and keeping both imports function-local makes the
    # non-cycle obvious regardless of which module loads first.
    from repro.core.maximize_throughput import Schedule

    state = ScheduleState.from_etg(etg, cluster)
    scale = 1.0
    current_rate = float(r0)
    final_snap = state.snapshot()
    final_rate = 0.0
    trace: list[tuple[int, str, float]] = []
    # Closed-form R* for the current structure; None = needs recompute.
    rstar: float | None = None

    it = 0
    while it < max_iters:
        it += 1
        if rstar is None:
            rstar = state.max_stable_rate()
        # Closed-form feasibility: far from R* the float comparison alone
        # decides (float R* is within ulps of the exact rational value);
        # inside the pre-filter band, exact rational arithmetic over the
        # linear coefficients is the arbiter — no heuristic re-check.
        if current_rate <= rstar * (1.0 - _RSTAR_GUARD):
            feasible = True
        elif current_rate >= rstar * (1.0 + _RSTAR_GUARD):
            feasible = False
        else:
            feasible = state.feasible_linear_exact(current_rate)
        if feasible:
            final_snap = state.snapshot()
            final_rate = current_rate
            increment = current_rate / scale
            if increment < rate_epsilon:
                trace.append((it, "terminate", current_rate))
                break
            current_rate += increment
            trace.append((it, "raise_rate", current_rate))
            continue
        # Over-utilization: hottest task on the first over-utilized machine
        # (reference index order) under the same linear model; the exact
        # rational scan runs only when float rounding hides the machine.
        var = state.var_load
        if cluster.has_network:
            var = var + state.net_load
        head = cluster.capacity - (state.met_load + current_rate * var)
        over_idx = np.flatnonzero(head < 0.0)
        if over_idx.size:
            over_w = int(over_idx[0])
        else:
            exact_w = state.first_over_machine_exact(current_rate)
            over_w = int(np.argmin(head)) if exact_w is None else exact_w
        component = _hottest_component(state, over_w, current_rate)
        added = _grow_component_fast(state, component, current_rate)
        if added:
            rstar = None
            trace.append((it, f"new_instance:c{component}x{added}", current_rate))
            continue
        # No candidate machine (reference lines 11-16).
        if current_rate > scale and final_rate > 0.0:
            scale *= 2.0
            state.restore(final_snap)
            rstar = None
            current_rate = final_rate + final_rate / scale
            trace.append((it, "backoff", current_rate))
            continue
        trace.append((it, "terminate", final_rate))
        break

    state.restore(final_snap)
    final_etg = state.to_etg()
    pred_final = cost_model.predict(final_etg, cluster, final_rate)
    return Schedule(
        etg=final_etg,
        rate=final_rate,
        predicted_throughput=pred_final.throughput,
        iterations=it,
        trace=trace,
    )
