"""Algorithm 1 — FirstAssignment (paper §5.3).

Takes the user topology graph and profiling data; emits the minimal
execution topology graph (one instance per component), each instance placed
on the machine with the least predicted TCU (eq. 5) at the initial topology
input rate R0, accounting for load already placed on each machine.
"""

from __future__ import annotations

import numpy as np

from repro.core import cost_model
from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = ["first_assignment"]


def first_assignment(utg: UserGraph, cluster: Cluster, r0: float) -> ExecutionGraph:
    """One instance per component, greedily placed by least predicted TCU.

    Components are visited in topological order so each component's input
    rate (eq. 6) is known before it is placed. Ties on TCU break toward the
    machine with the most remaining capacity so the minimal graph never
    stacks everything on one node.
    """
    cir = cost_model.component_rates(utg, r0)  # one instance each => IR = CIR
    util = np.zeros(cluster.n_machines, dtype=np.float64)
    placement = np.zeros(utg.n_components, dtype=np.int64)

    for i in utg.topo_order():
        ttype = int(utg.component_types[i])
        e_row = cluster.profile.e[ttype][cluster.machine_types]      # (m,)
        met_row = cluster.profile.met[ttype][cluster.machine_types]  # (m,)
        tcu = e_row * cir[i] + met_row                               # eq. 5
        mac_after = cluster.capacity - (util + tcu)
        # Least-TCU machine; among near-ties prefer max remaining capacity.
        order = np.lexsort((-mac_after, np.round(tcu, 9)))
        best = int(order[0])
        placement[i] = best
        util[best] += tcu[best]

    return ExecutionGraph(
        utg=utg,
        n_instances=np.ones(utg.n_components, dtype=np.int64),
        assignment=[np.array([placement[i]]) for i in range(utg.n_components)],
    )
