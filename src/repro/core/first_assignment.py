"""Algorithm 1 — FirstAssignment (paper §5.3).

Takes the user topology graph and profiling data; emits the minimal
execution topology graph (one instance per component), each instance placed
on the machine with the least predicted TCU (eq. 5) at the initial topology
input rate R0, accounting for load already placed on each machine.
"""

from __future__ import annotations

import numpy as np

from repro.core import cost_model
from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = ["first_assignment"]


def first_assignment(utg: UserGraph, cluster: Cluster, r0: float) -> ExecutionGraph:
    """One instance per component, greedily placed by least predicted TCU.

    Components are visited in topological order so each component's input
    rate (eq. 6) is known before it is placed. Ties on TCU break toward the
    machine with the most remaining capacity so the minimal graph never
    stacks everything on one node.
    """
    cir = cost_model.component_rates(utg, r0)  # one instance each => IR = CIR
    util = np.zeros(cluster.n_machines, dtype=np.float64)
    placement = np.zeros(utg.n_components, dtype=np.int64)
    # Hard memory constraint (resource-vector clusters): machines whose
    # remaining memory cannot hold the instance are masked out of the TCU
    # ranking; the scalar-CPU default never builds the mask, so its lexsort
    # keys are byte-identical to before.
    mem_used = (
        np.zeros(cluster.n_machines, dtype=np.float64)
        if cluster.has_memory
        else None
    )

    for i in utg.topo_order():
        ttype = int(utg.component_types[i])
        e_row = cluster.profile.e[ttype][cluster.machine_types]      # (m,)
        met_row = cluster.profile.met[ttype][cluster.machine_types]  # (m,)
        tcu = e_row * cir[i] + met_row                               # eq. 5
        mac_after = cluster.capacity - (util + tcu)
        tcu_key = np.round(tcu, 9)
        if mem_used is not None:
            mem_i = float(cluster.profile.mem[ttype])
            fits = mem_used + mem_i <= cluster.mem_capacity
            if fits.any():
                tcu_key = np.where(fits, tcu_key, np.inf)
            # else: nothing fits — fall through to the memory-blind rule
            # (the schedule is infeasible either way; R* masks it to 0).
        # Least-TCU machine; among near-ties prefer max remaining capacity.
        order = np.lexsort((-mac_after, tcu_key))
        best = int(order[0])
        placement[i] = best
        util[best] += tcu[best]
        if mem_used is not None:
            mem_used[best] += mem_i

    return ExecutionGraph(
        utg=utg,
        n_instances=np.ones(utg.n_components, dtype=np.int64),
        assignment=[np.array([placement[i]]) for i in range(utg.n_components)],
    )
