"""Topology graphs: user topology graph (UTG) and execution topology graph (ETG).

Faithful to the paper's model (Section 2.2):

* A *user topology graph* (UTG) is a DAG of components. Component 0 is by
  convention the spout (source); every other component is a bolt. Each
  component ``i`` has a *type* (indexing into the profiling tables) and a
  *tuple division ratio* ``alpha_i`` (eq. 6): the average ratio of output
  tuples to input tuples.

* An *execution topology graph* (ETG) fixes a parallelism degree
  ``n_instances[i] >= 1`` per component and an assignment of every instance
  to a machine.

Instances of component ``i`` are identified by the pair ``(i, k)`` with
``k < n_instances[i]``; a flattened global task index follows the paper's
eq. 3 ordering (all instances of component 0, then component 1, ...).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "FieldsGrouping",
    "UserGraph",
    "ExecutionGraph",
    "linear_topology",
    "diamond_topology",
    "star_topology",
    "rolling_count_topology",
    "keyed_rolling_count_topology",
    "unique_visitor_topology",
    "wide_fanout_topology",
]


@dataclasses.dataclass(frozen=True)
class FieldsGrouping:
    """Keyed routing spec for one edge (Storm's *fields grouping*).

    Tuples on the edge carry a key drawn from a Zipf-distributed key space:
    key k of ``n_keys`` has probability mass proportional to
    ``(k + 1) ** -zipf_s`` (``zipf_s = 0`` is uniform). Every key is pinned
    to one downstream instance by a deterministic hash→instance map, so a
    hot key concentrates load on a single instance — the within-operator
    imbalance the paper's eq. 6 even split cannot express.

    The spec is *structural*: which instance each key lands on (the hash
    values) is drawn at trace ``compile(seed)`` time like all other
    randomness (see ``runtime_stream.traces.KeyRealization``).

    ``state_per_tuple`` sizes the downstream operator's *keyed state*:
    state tuples retained per unit of the edge's tuple rate (a rolling
    counter keeps one window of per-key aggregates; a join keeps its
    buffered side). An instance's standing state is proportional to the
    key share it owns (``SkewModel.per_task_state``), so migrating a
    hot-key instance ships more state than a cold one. 0 (the default)
    means a stateless operator — migration stays priced by move count
    alone and the runtime behaves exactly as before.
    """

    edge: tuple[int, int]
    n_keys: int = 64
    zipf_s: float = 1.0
    state_per_tuple: float = 0.0

    def __post_init__(self) -> None:
        object.__setattr__(self, "edge", (int(self.edge[0]), int(self.edge[1])))
        if int(self.n_keys) < 1:
            raise ValueError("fields grouping needs at least one key")
        if not (float(self.zipf_s) >= 0.0):
            raise ValueError("zipf_s must be >= 0 (0 = uniform keys)")
        if not (float(self.state_per_tuple) >= 0.0):
            raise ValueError("state_per_tuple must be >= 0 (0 = stateless)")
        object.__setattr__(self, "n_keys", int(self.n_keys))
        object.__setattr__(self, "zipf_s", float(self.zipf_s))
        object.__setattr__(self, "state_per_tuple", float(self.state_per_tuple))


@dataclasses.dataclass(frozen=True)
class UserGraph:
    """The paper's UTG.

    Attributes:
      name: topology name (for reports).
      component_types: length-n int array; ``component_types[i]`` indexes the
        profiling table row for component i (its task *type*: e.g. lowCompute/
        midCompute/highCompute). The spout is component 0 and conventionally
        has its own type with near-zero cost.
      edges: list of (src, dst) component index pairs; must form a DAG with
        every non-spout component reachable from a spout.
      alpha: length-n float array, tuple division ratio per component
        (``OR = alpha * IR``). Spouts' alpha scales the injected rate.
      groupings: fields-grouped edges (``FieldsGrouping`` per keyed edge);
        every edge not listed uses shuffle grouping (the paper's default).
    """

    name: str
    component_types: np.ndarray
    edges: tuple[tuple[int, int], ...]
    alpha: np.ndarray
    groupings: tuple[FieldsGrouping, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "component_types", np.asarray(self.component_types, dtype=np.int64)
        )
        object.__setattr__(self, "alpha", np.asarray(self.alpha, dtype=np.float64))
        object.__setattr__(self, "edges", tuple((int(a), int(b)) for a, b in self.edges))
        object.__setattr__(self, "groupings", tuple(self.groupings))
        n = self.n_components
        if self.alpha.shape != (n,):
            raise ValueError(f"alpha must have shape ({n},), got {self.alpha.shape}")
        for a, b in self.edges:
            if not (0 <= a < n and 0 <= b < n):
                raise ValueError(f"edge ({a},{b}) out of range for {n} components")
            if a == b:
                raise ValueError("self-loops are not allowed (DAG)")
        seen: set[tuple[int, int]] = set()
        for g in self.groupings:
            if not isinstance(g, FieldsGrouping):
                raise ValueError("groupings must be FieldsGrouping instances")
            if g.edge not in self.edges:
                raise ValueError(f"fields grouping on unknown edge {g.edge}")
            if g.edge in seen:
                raise ValueError(f"duplicate grouping for edge {g.edge}")
            seen.add(g.edge)
        # Validate acyclicity + topological order computability.
        self.topo_order()

    @property
    def n_components(self) -> int:
        return int(self.component_types.shape[0])

    @property
    def sources(self) -> list[int]:
        """Components with no in-edges (spouts)."""
        indeg = np.zeros(self.n_components, dtype=np.int64)
        for _, b in self.edges:
            indeg[b] += 1
        return [i for i in range(self.n_components) if indeg[i] == 0]

    def parents(self, i: int) -> list[int]:
        return [a for a, b in self.edges if b == i]

    def children(self, i: int) -> list[int]:
        return [b for a, b in self.edges if a == i]

    def grouping(self, edge: tuple[int, int]) -> FieldsGrouping | None:
        """The fields grouping on ``edge``, or None (shuffle grouping)."""
        for g in self.groupings:
            if g.edge == edge:
                return g
        return None

    @property
    def keyed_components(self) -> list[int]:
        """Components with at least one fields-grouped in-edge, in index
        order — their per-instance input split departs from eq. 6."""
        return sorted({g.edge[1] for g in self.groupings})

    def with_groupings(self, *groupings: FieldsGrouping) -> "UserGraph":
        """Copy of this UTG with the given fields groupings (replaces any
        existing ones)."""
        return dataclasses.replace(self, groupings=tuple(groupings))

    def topo_order(self) -> list[int]:
        n = self.n_components
        indeg = np.zeros(n, dtype=np.int64)
        for _, b in self.edges:
            indeg[b] += 1
        order: list[int] = []
        stack = [i for i in range(n) if indeg[i] == 0]
        while stack:
            v = stack.pop()
            order.append(v)
            for c in self.children(v):
                indeg[c] -= 1
                if indeg[c] == 0:
                    stack.append(c)
        if len(order) != n:
            raise ValueError(f"topology '{self.name}' contains a cycle")
        return order


@dataclasses.dataclass
class ExecutionGraph:
    """The paper's ETG: instance counts + per-instance machine assignment.

    ``assignment[i]`` is an int array of length ``n_instances[i]`` whose k-th
    entry is the machine index hosting instance (i, k).
    """

    utg: UserGraph
    n_instances: np.ndarray
    assignment: list[np.ndarray]

    def __post_init__(self) -> None:
        self.n_instances = np.asarray(self.n_instances, dtype=np.int64)
        n = self.utg.n_components
        if self.n_instances.shape != (n,):
            raise ValueError("n_instances must have one entry per component")
        if np.any(self.n_instances < 1):
            raise ValueError("every component needs >= 1 instance (paper constraint)")
        if len(self.assignment) != n:
            raise ValueError("assignment must have one array per component")
        self.assignment = [np.asarray(a, dtype=np.int64) for a in self.assignment]
        for i, a in enumerate(self.assignment):
            if a.shape != (int(self.n_instances[i]),):
                raise ValueError(
                    f"component {i}: assignment length {a.shape} != "
                    f"n_instances {int(self.n_instances[i])}"
                )

    @property
    def total_tasks(self) -> int:
        return int(self.n_instances.sum())

    def copy(self) -> "ExecutionGraph":
        return ExecutionGraph(
            utg=self.utg,
            n_instances=self.n_instances.copy(),
            assignment=[a.copy() for a in self.assignment],
        )

    def task_component(self) -> np.ndarray:
        """Flattened map: global task index -> component index (paper eq. 3)."""
        return np.repeat(np.arange(self.utg.n_components), self.n_instances)

    def task_machine(self) -> np.ndarray:
        """Flattened map: global task index -> machine index."""
        if self.total_tasks == 0:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate(self.assignment)

    def component_offsets(self) -> np.ndarray:
        """(n+1,) start offset of each component's task block in the
        flattened eq. 3 order — the single owner of the block-layout rule
        (``offsets[c] + k`` is the flat index of instance (c, k))."""
        return np.concatenate(
            [np.zeros(1, dtype=np.int64), np.cumsum(self.n_instances)]
        )

    def with_new_instance(self, component: int, machine: int) -> "ExecutionGraph":
        new = self.copy()
        new.n_instances[component] += 1
        new.assignment[component] = np.concatenate(
            [new.assignment[component], np.array([machine], dtype=np.int64)]
        )
        return new


# ---------------------------------------------------------------------------
# Micro-Benchmark topologies (Fig. 5) and Storm-Benchmark topologies (Fig. 7).
#
# Component type indices follow repro.core.profiles:
#   0=spout, 1=lowCompute, 2=midCompute, 3=highCompute.
# The gray (measured) bolt in Fig. 5 is the highCompute bolt.
# ---------------------------------------------------------------------------

SPOUT, LOW, MID, HIGH = 0, 1, 2, 3


def linear_topology(alpha: float = 1.0) -> UserGraph:
    """spout -> low -> mid -> high (Fig. 5, Linear)."""
    return UserGraph(
        name="linear",
        component_types=np.array([SPOUT, LOW, MID, HIGH]),
        edges=((0, 1), (1, 2), (2, 3)),
        alpha=np.array([1.0, alpha, alpha, alpha]),
    )


def diamond_topology(alpha: float = 1.0) -> UserGraph:
    """spout fans out to low/mid/low, all feed high (Fig. 5, Diamond)."""
    return UserGraph(
        name="diamond",
        component_types=np.array([SPOUT, LOW, MID, LOW, HIGH]),
        edges=((0, 1), (0, 2), (0, 3), (1, 4), (2, 4), (3, 4)),
        alpha=np.array([1.0, alpha, alpha, alpha, alpha]),
    )


def star_topology(alpha: float = 1.0) -> UserGraph:
    """two spouts -> high -> two sinks (Fig. 5, Star)."""
    return UserGraph(
        name="star",
        component_types=np.array([SPOUT, SPOUT, HIGH, LOW, MID]),
        edges=((0, 2), (1, 2), (2, 3), (2, 4)),
        alpha=np.array([1.0, 1.0, alpha, alpha, alpha]),
    )


def rolling_count_topology() -> UserGraph:
    """Storm-Benchmark RollingCount: spout -> split(bolt1) -> rolling-count(bolt2).

    bolt1 (sentence split) is the compute-heavy stage and fans each sentence
    into several words (alpha > 1); the per-word rolling counter is light.
    """
    return UserGraph(
        name="rolling_count",
        component_types=np.array([SPOUT, HIGH, LOW]),
        edges=((0, 1), (1, 2)),
        alpha=np.array([1.0, 4.0, 1.0]),
    )


def keyed_rolling_count_topology(
    n_keys: int = 32, zipf_s: float = 1.2, state_per_tuple: float = 0.0
) -> UserGraph:
    """RollingCount with its word->counter edge fields-grouped.

    The canonical keyed-stream shape: the split bolt fans sentences into
    words (alpha > 1) and each word is pinned to one rolling counter by
    fields grouping, so a Zipf-hot word concentrates load on one counter
    instance — the load-imbalance scenario family of ROADMAP open item 3.
    ``state_per_tuple > 0`` gives the counter keyed state (its per-key
    rolling windows) so migrations ship state proportional to key share.
    """
    return rolling_count_topology().with_groupings(
        FieldsGrouping(
            edge=(1, 2), n_keys=n_keys, zipf_s=zipf_s,
            state_per_tuple=state_per_tuple,
        )
    )


def unique_visitor_topology() -> UserGraph:
    """Storm-Benchmark UniqueVisitor: spout -> view parse(bolt1) -> distinct(bolt2)."""
    return UserGraph(
        name="unique_visitor",
        component_types=np.array([SPOUT, HIGH, HIGH]),
        edges=((0, 1), (1, 2)),
        alpha=np.array([1.0, 1.0, 1.0]),
    )


def wide_fanout_topology(n_mid: int = 8) -> UserGraph:
    """Spout fanning out to ``n_mid`` bolts (types cycling low/mid/high),
    all feeding one low-compute sink.

    Beyond-paper stress shape for wide topologies: with n components a
    refine round explores n single growth chains plus 2·C(n, 2) pair
    forks, which is what the lockstep chain explorer batches (see
    docs/architecture.md). Used by the wide golden equivalence tests and
    benchmarks/bench_refine.py's wide scenario."""
    n = n_mid + 2
    types = np.array([SPOUT] + [1 + (i % 3) for i in range(n_mid)] + [LOW])
    edges = tuple((0, j) for j in range(1, n_mid + 1)) + tuple(
        (j, n - 1) for j in range(1, n_mid + 1)
    )
    return UserGraph(
        name=f"wide{n_mid}",
        component_types=types,
        edges=edges,
        alpha=np.ones(n),
    )
