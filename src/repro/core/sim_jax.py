"""JAX backend for the batched back-pressure simulator (§6.3).

Mirrors the NumPy fixed point in ``simulator.py`` — same damped iteration,
same topo-order propagation, same termination rule — but jitted and driven
by ``jax.lax.while_loop`` so thousands of candidate placements score in one
compiled sweep. The topology structure (component order, parent lists,
alphas) is baked in as static arguments while instance counts are dynamic
inputs, so each (topology, batch-shape) combination compiles once and is
re-used across rate sweeps, placement batches and instance-count vectors
of equal task total.

Rate propagation uses the sparse structure of the UTG directly: components'
tasks are contiguous in the flattened task order (paper eq. 3), so the
per-component gather/scatter reduces to static slices, and the parent sum
``CIR_b = sum alpha_a * PR_a`` unrolls over the (few) DAG edges. Everything
runs in float64 (via ``jax.experimental.enable_x64``) so the backends agree
to 1e-9; the NumPy path remains the reference and the fallback when JAX is
unavailable.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.graph import ExecutionGraph
from repro.core.profiles import Cluster

__all__ = ["simulate_batch_jax", "max_stable_rate_batch_jax", "closed_form_rates_jax"]

_MAX_ITERS = 200
_TOL = 1e-10


@functools.lru_cache(maxsize=None)
def _compiled_kernel(static: tuple):
    """Build + cache the jitted fixed-point kernel for one topology structure.

    ``static`` is a hashable description: (topo order, sources, parent
    tuples, alphas, component count). Instance counts and task maps are
    dynamic kernel inputs — see ``_static_descriptor``.
    """
    import jax
    import jax.numpy as jnp

    topo, sources, parents, alpha, n_comp = static
    src = frozenset(sources)

    @jax.jit
    def kernel(task_machine, comp, n_inst, e_cm, met_cm, capacity, r0):
        """Fixed point over machine scale factors s (B, m).

        ``r0`` is a (B,) per-candidate offered-rate vector (a scalar sweep
        broadcasts before the call), so one compiled sweep can score
        placements at heterogeneous rates.

        The task dimension is collapsed before the loop: all instances of a
        component on a machine are interchangeable, so the state inside the
        fixed point is the sparse count tensor ``counts`` (B, n, m) and the
        loop body is two einsum contractions plus the O(n) topo recurrence —
        no per-task gathers/scatters until the final readout.
        """
        B, T = task_machine.shape
        m = capacity.shape[0]
        rows = jnp.arange(B)[:, None]
        one = jnp.ones((), dtype=e_cm.dtype)
        counts = (
            jnp.zeros((B, n_comp, m), dtype=e_cm.dtype)
            .at[rows, comp[None, :], task_machine]
            .add(one)
        )
        ew = counts * e_cm[None, :, :]          # (B, n, m) variable-load weights
        met_load = jnp.einsum("bnm,nm->bm", counts, met_cm)
        head = jnp.maximum(capacity[None, :] - met_load, 0.0)

        def step(s):
            pr = [None] * n_comp
            per = [None] * n_comp
            for i in topo:
                if i in src:
                    cir_i = r0.astype(s.dtype)
                else:
                    cir_i = jnp.zeros((B,), dtype=s.dtype)
                    for p in parents[i]:
                        cir_i = cir_i + alpha[p] * pr[p]
                per[i] = cir_i / n_inst[i]
                s_sum = jnp.einsum("bm,bm->b", counts[:, i, :], s)
                pr[i] = per[i] * s_sum
            per_inst = jnp.stack(per, axis=1)    # (B, n)
            var_load = jnp.einsum("bn,bnm->bm", per_inst, ew)
            s_new = jnp.where(
                var_load > head, head / jnp.maximum(var_load, 1e-300), 1.0
            )
            return per_inst, s_new

        def body(carry):
            s, _, _, it = carry
            per_inst, s_new = step(s)
            delta = jnp.max(jnp.abs(s_new - s))
            return s_new, per_inst, delta, it + 1

        def cond(carry):
            _, _, delta, it = carry
            return (delta >= _TOL) & (it < _MAX_ITERS)

        s0 = jnp.ones((B, m), dtype=e_cm.dtype)
        carry = body((s0, jnp.zeros((B, n_comp), dtype=e_cm.dtype), jnp.inf, 0))
        s, per_inst, _, _ = jax.lax.while_loop(cond, body, carry)

        # Per-task readout, once. Matches the NumPy loop's exit state:
        # ``per_inst`` comes from the last propagation (previous s); ``s``
        # is the final converged factor.
        ir = per_inst[:, comp]                   # (B, T)
        e = e_cm[comp[None, :], task_machine]    # (B, T)
        met = met_cm[comp[None, :], task_machine]
        pr = ir * jnp.take_along_axis(s, task_machine, axis=1)
        tcu = e * pr + met
        util = jnp.zeros((B, m), dtype=e.dtype).at[rows, task_machine].add(tcu)
        return ir, pr, tcu, util, pr.sum(axis=1)

    return kernel


def _static_descriptor(etg: ExecutionGraph) -> tuple:
    """Hashable topology structure. Instance counts are *dynamic* kernel
    inputs, so every count vector of a topology with the same task total
    shares one compiled kernel (sweeps over thousands of count vectors
    retrace only when the task count T changes)."""
    utg = etg.utg
    return (
        tuple(utg.topo_order()),
        tuple(utg.sources),
        tuple(tuple(utg.parents(i)) for i in range(utg.n_components)),
        tuple(float(a) for a in utg.alpha),
        utg.n_components,
    )


def simulate_batch_jax(
    etg: ExecutionGraph,
    cluster: Cluster,
    task_machine: np.ndarray,
    r0,
):
    """JAX implementation of ``simulator.simulate_batch`` (same contract).

    ``r0`` may be a scalar or a (B,) per-candidate rate vector.
    """
    from jax.experimental import enable_x64

    # Imported here to avoid a cycle (simulator dispatches to this module).
    from repro.core.simulator import BatchSimResult

    utg = etg.utg
    comp = etg.task_component()
    task_machine = np.asarray(task_machine, dtype=np.int64)
    if task_machine.ndim != 2 or task_machine.shape[1] != comp.shape[0]:
        raise ValueError("task_machine must be (B, T)")
    r0 = np.asarray(r0, dtype=np.float64)
    if r0.ndim not in (0, 1) or (
        r0.ndim == 1 and r0.shape != (task_machine.shape[0],)
    ):
        raise ValueError("r0 must be a scalar or a (B,) vector")
    r0_b = np.broadcast_to(r0, (task_machine.shape[0],)).copy()
    if task_machine.shape[0] == 0:
        # Empty batch: the while-loop reductions are undefined over B=0, so
        # short-circuit with correctly-shaped empties (matches NumPy path).
        T, m = task_machine.shape[1], cluster.n_machines
        empty = np.zeros((0, T), dtype=np.float64)
        return BatchSimResult(
            ir=empty,
            pr=empty.copy(),
            tcu=empty.copy(),
            machine_util=np.zeros((0, m), dtype=np.float64),
            throughput=np.zeros(0, dtype=np.float64),
        )

    ttypes = utg.component_types
    e_cm = cluster.profile.e[ttypes][:, cluster.machine_types]      # (n, m)
    met_cm = cluster.profile.met[ttypes][:, cluster.machine_types]  # (n, m)

    kernel = _compiled_kernel(_static_descriptor(etg))
    n_inst = np.asarray(etg.n_instances, dtype=np.float64)
    with enable_x64():
        ir, pr, tcu, util, thpt = kernel(
            task_machine, comp, n_inst, e_cm, met_cm, cluster.capacity, r0_b
        )
    return BatchSimResult(
        ir=np.asarray(ir),
        pr=np.asarray(pr),
        tcu=np.asarray(tcu),
        machine_util=np.asarray(util),
        throughput=np.asarray(thpt),
    )


# ----------------------------------------------------- closed-form scoring


@functools.lru_cache(maxsize=4)
def _msr_kernel(per_row: bool = False, with_resources: bool = False):
    """Jitted closed-form max-stable-rate scorer (paper eq. 5 linearity).

    Mirrors ``cost_model.max_stable_rate_batch``'s NumPy math: per-machine
    utilization is ``met_w + R * var_w``, so the binding machine gives
    ``R* = min_w (cap_w - met_w) / var_w``.

    The per-machine accumulation is **scatter-free**: instead of XLA's
    scatter-add (serial scalar updates on CPU — 0.2-0.4x NumPy's
    ``np.add.at`` at every measured size, see BENCH_dispatch.json), the
    one-hot membership tensor is laid out (B, m, T) and both accumulators
    reduce over the innermost task axis, which XLA fuses into a vectorized
    compare-select-sum. The contraction does B*T*m element ops versus the
    scatter's B*T, so it wins only while the machine count stays small —
    exactly the regime ``simulator.resolve_closed_form_backend`` dispatches
    to it (the auto machine-count gate; NumPy keeps wide clusters).
    Summation association differs from NumPy's sequential ``np.add.at``, so
    agreement is ~1e-15 relative, not bit-exact — the NumPy backend stays
    the reference.

    Two cached variants: ``per_row=False`` takes shared (T,) ``comp`` /
    ``unit_ir`` maps (every row one instance-count vector — no point
    shipping B identical copies to the device); ``per_row=True`` takes
    (B, T) maps so rows may carry different count vectors (lockstep growth
    batches) or per-row skew-realized unit rates. ``capacity`` may be (m,)
    shared or (B, m) per-row (the multi-tenant batch scorer prices each
    row against its tenant's residual capacity); the rank difference is a
    trace-time constant, so both shapes share one cached variant.

    ``with_resources=True`` selects the resource-vector variant: three
    extra operands — ``net_var`` (B, m) cut-traffic load added to the
    variable coefficient, ``mem`` per-task memory demand and
    ``mem_capacity`` per-machine memory ceiling driving the hard
    feasibility mask (absent resource types are passed as zeros /
    +inf). Kept as separate cached kernels so scalar-CPU scoring never
    re-traces and executes byte-for-byte the legacy contraction.
    """
    import jax
    import jax.numpy as jnp

    def _accumulate(task_machine, comp, unit_ir, e_cm, met_cm, capacity):
        m = capacity.shape[-1]
        cmap = comp if per_row else comp[None, :]
        e = e_cm[cmap, task_machine]                 # (B, T)
        met = met_cm[cmap, task_machine]
        ev = e * (unit_ir if per_row else unit_ir[None, :])
        # One-hot contraction, (B, m, T) layout: membership of task t on
        # machine w, reduced over the innermost t axis. No scatter anywhere.
        onehot = (
            task_machine[:, None, :]
            == jnp.arange(m, dtype=task_machine.dtype)[None, :, None]
        )
        var_w = jnp.sum(jnp.where(onehot, ev[:, None, :], 0.0), axis=-1)
        met_w = jnp.sum(jnp.where(onehot, met[:, None, :], 0.0), axis=-1)
        return onehot, var_w, met_w

    def _finish(var_w, met_w, capacity, unit_ir, infeasible_extra=None):
        cap_b = capacity if capacity.ndim == 2 else capacity[None, :]
        head = cap_b - met_w
        infeasible = jnp.any(head < 0.0, axis=1)
        if infeasible_extra is not None:
            infeasible = infeasible | infeasible_extra
        limits = jnp.where(var_w > 0.0, head / jnp.maximum(var_w, 1e-300), jnp.inf)
        rates = jnp.clip(jnp.min(limits, axis=1), 0.0, None)
        rates = jnp.where(infeasible, 0.0, rates)
        thpt = rates * (unit_ir.sum(axis=1) if per_row else unit_ir.sum())
        return rates, thpt

    if not with_resources:

        @jax.jit
        def kernel(task_machine, comp, unit_ir, e_cm, met_cm, capacity):
            _, var_w, met_w = _accumulate(
                task_machine, comp, unit_ir, e_cm, met_cm, capacity
            )
            return _finish(var_w, met_w, capacity, unit_ir)

        return kernel

    @jax.jit
    def kernel_resources(
        task_machine, comp, unit_ir, e_cm, met_cm, capacity,
        net_var, mem, mem_capacity,
    ):
        onehot, var_w, met_w = _accumulate(
            task_machine, comp, unit_ir, e_cm, met_cm, capacity
        )
        var_w = var_w + net_var
        mem_bt = mem if mem.ndim == 2 else mem[None, :]
        mem_w = jnp.sum(jnp.where(onehot, mem_bt[:, None, :], 0.0), axis=-1)
        mem_cap_b = (
            mem_capacity if mem_capacity.ndim == 2 else mem_capacity[None, :]
        )
        over_mem = jnp.any(mem_w > mem_cap_b, axis=1)
        return _finish(var_w, met_w, capacity, unit_ir, infeasible_extra=over_mem)

    return kernel_resources


@functools.cache
def _use_pallas_scoring() -> bool:
    """Route closed-form scoring through the Pallas segmented-reduce kernel
    (``repro.kernels.sched_scoring``). On by default on TPU backends; force
    with ``REPRO_SCHED_SCORING_PALLAS=1`` (compiled) / ``=interpret``
    (interpreter — CPU-testable, slow) / ``=0`` (off)."""
    import os

    env = os.environ.get("REPRO_SCHED_SCORING_PALLAS")
    if env is not None:
        return env not in ("0", "")
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:
        return False


def closed_form_rates_jax(
    task_machine: np.ndarray,
    comp: np.ndarray,
    unit_ir: np.ndarray,
    e_cm: np.ndarray,
    met_cm: np.ndarray,
    capacity: np.ndarray,
    net_var: np.ndarray | None = None,
    mem: np.ndarray | None = None,
    mem_capacity: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """JAX twin of ``cost_model.closed_form_rates`` (scatter-free).

    ``comp`` / ``unit_ir`` may be (T,) shared maps or (B, T) per-row maps;
    each shape routes to its own cached kernel variant. ``capacity`` may be
    (m,) shared or (B, m) per-row. On TPU backends (or under
    ``REPRO_SCHED_SCORING_PALLAS``) the accumulation runs the Pallas
    segmented-reduce kernel instead of the XLA contraction — except for
    per-row capacity, which the Pallas kernel does not carry yet; those
    batches stay on the XLA contraction on every backend.

    Resource-vector extras (``net_var`` / ``mem`` / ``mem_capacity``) have
    the ``cost_model.closed_form_rates`` semantics: the cut-traffic column
    is added to the variable coefficient and memory is a hard feasibility
    mask. All-``None`` (the scalar-CPU default) runs the exact legacy
    kernels; absent resource types are filled with zeros / +inf for the
    resource variant.
    """
    import os

    from jax.experimental import enable_x64

    has_resources = (
        net_var is not None or mem is not None or mem_capacity is not None
    )
    if _use_pallas_scoring() and capacity.ndim == 1:
        from repro.kernels.sched_scoring.ops import closed_form_rates_sched

        interpret = os.environ.get("REPRO_SCHED_SCORING_PALLAS") == "interpret"
        return closed_form_rates_sched(
            task_machine, comp, unit_ir, e_cm, met_cm, capacity,
            impl="interpret" if interpret else "pallas",
            net_var=net_var, mem=mem, mem_capacity=mem_capacity,
        )
    if not has_resources:
        with enable_x64():
            rates, thpt = _msr_kernel(per_row=comp.ndim == 2)(
                task_machine, comp, unit_ir, e_cm, met_cm, capacity
            )
        return np.asarray(rates), np.asarray(thpt)
    B = task_machine.shape[0]
    m = capacity.shape[-1]
    if net_var is None:
        net_var = np.zeros((B, m), dtype=np.float64)
    if mem is None:
        mem = np.zeros(comp.shape[-1], dtype=np.float64)
        mem_capacity = np.full(m, np.inf, dtype=np.float64)
    with enable_x64():
        rates, thpt = _msr_kernel(per_row=comp.ndim == 2, with_resources=True)(
            task_machine, comp, unit_ir, e_cm, met_cm, capacity,
            net_var, mem, mem_capacity,
        )
    return np.asarray(rates), np.asarray(thpt)


def max_stable_rate_batch_jax(
    etg: ExecutionGraph,
    cluster: Cluster,
    task_machine: np.ndarray,
    n_instances: np.ndarray | None = None,
    skew=None,
) -> tuple[np.ndarray, np.ndarray]:
    """JAX backend for ``cost_model.max_stable_rate_batch`` (same contract,
    including the optional (B, n) per-row ``n_instances`` matrix and the
    optional ``skew`` model — skew rows score through the same jitted
    kernel, fed the skew-realized unit rates instead of the even split)."""
    from repro.core import cost_model

    utg = etg.utg
    task_machine = np.asarray(task_machine, dtype=np.int64)
    if task_machine.ndim != 2:
        raise ValueError("task_machine must be (B, T)")
    if skew is not None and skew.utg is not utg:
        raise ValueError("skew model was built for a different topology")
    if n_instances is not None:
        n_inst_bn = np.asarray(n_instances, dtype=np.int64)
        cir_unit = skew.cir_unit if skew is not None else (
            cost_model.component_rates(utg, 1.0)
        )
        comp, unit_ir = cost_model.per_row_task_maps(
            cir_unit, n_inst_bn, task_machine.shape[1]
        )
        if skew is not None:
            unit_ir = skew.per_row_unit_ir(n_inst_bn)
    else:
        comp = etg.task_component()
        if task_machine.shape[1] != comp.shape[0]:
            raise ValueError("task_machine must be (B, T)")
        unit_ir = (
            skew.per_task_unit_ir(etg.n_instances)
            if skew is not None
            else cost_model.instance_rates(etg, 1.0)
        )
    ttypes = utg.component_types
    e_cm = cluster.profile.e[ttypes][:, cluster.machine_types]
    met_cm = cluster.profile.met[ttypes][:, cluster.machine_types]
    net_var = mem = mem_cap = None
    if cluster.has_resources:
        cir_unit = skew.cir_unit if skew is not None else (
            cost_model.component_rates(utg, 1.0)
        )
        net_var, mem, mem_cap = cost_model.resource_operands(
            cluster, task_machine, comp, unit_ir, utg.alpha,
            cir_unit, utg.edges, ttypes,
        )
    return closed_form_rates_jax(
        task_machine, comp, unit_ir, e_cm, met_cm, cluster.capacity,
        net_var=net_var, mem=mem, mem_capacity=mem_cap,
    )
