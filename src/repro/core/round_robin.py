"""Storm's default scheduler: Round-Robin task assignment (paper §2.3).

The default scheduler maps executors to worker processes in a simple
round-robin over available slots, oblivious to machine computing power. The
user supplies the instance counts (in Storm the parallelism hints are part of
the submitted topology); for fair comparisons the benchmarks reuse the
instance counts discovered by the proposed scheduler (§6.3: "we first run our
algorithm to determine the number of instances for each component ... Now we
can fairly compare only the effectiveness of scheduling policies").
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = ["round_robin_schedule"]


def round_robin_schedule(
    utg: UserGraph,
    cluster: Cluster,
    n_instances: np.ndarray,
    start: int = 0,
) -> ExecutionGraph:
    """Assign tasks (in eq. 3 flattened order) cyclically over machines."""
    n_instances = np.asarray(n_instances, dtype=np.int64)
    total = int(n_instances.sum())
    order = (start + np.arange(total)) % cluster.n_machines
    assignment: list[np.ndarray] = []
    off = 0
    for i in range(utg.n_components):
        k = int(n_instances[i])
        assignment.append(order[off : off + k].copy())
        off += k
    return ExecutionGraph(utg=utg, n_instances=n_instances, assignment=assignment)
