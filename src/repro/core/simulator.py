"""Rate-based cluster simulator — the paper's §6.3 simulator, vectorized.

Given an ETG, a cluster and an offered topology input rate, compute the
*measured* steady state: per-task processing rates under machine saturation
and back-pressure, per-machine utilization, and overall throughput. This is
the ground truth that (a) the prediction model (eq. 5) is scored against
(Fig. 6), and (b) all three schedulers are compared on (Figs. 3/8/9/10).

Saturation model
----------------
A machine w hosting tasks with offered variable load ``sum_i e_i * IR_i``
and fixed overhead ``sum_i MET_i`` saturates when total demand exceeds its
capacity. Under overload the machine applies proportional fair throttling:
every hosted task processes at ``s_w * IR_i`` with

    s_w = clip((capacity_w - sum MET) / sum(e_i * IR_i), 0, 1).

Throttled output back-pressures downstream components (their input rate is
the *processed* upstream rate), which is the domino effect of §5.2. Because
saturation on one machine changes rates feeding other machines, the steady
state is a fixed point; demand scale factors decrease monotonically along
iterations, so a short damped fixed-point loop converges (we iterate to
convergence with a hard cap).

The batched variant evaluates B candidate placements that share one
instance-count vector in a single vectorized sweep — this is what makes the
exhaustive optimal scheduler tractable (the paper reports 18 hours for
27 405 placements; see benchmarks/bench_sched_speed.py).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.graph import ExecutionGraph
from repro.core.profiles import Cluster
from repro.obs.trace import record_dispatch

__all__ = ["SimResult", "simulate", "simulate_batch", "measured_tcu"]

_MAX_ITERS = 200
_TOL = 1e-10


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Steady state of the simulated cluster.

    Attributes:
      ir: (T,) offered per-task input rate (post-back-pressure).
      pr: (T,) processing rate actually achieved per task.
      tcu: (T,) occupied CPU per task at the steady state.
      machine_util: (m,) per-machine utilization (capped at capacity only by
        the throttling model itself).
      throughput: overall topology throughput = sum of task processing rates
        (the paper's throughput definition, eq. 2).
    """

    ir: np.ndarray
    pr: np.ndarray
    tcu: np.ndarray
    machine_util: np.ndarray
    throughput: float


def _flat_arrays(etg: ExecutionGraph, cluster: Cluster):
    comp = etg.task_component()
    machine = etg.task_machine()
    ttypes = etg.utg.component_types[comp]
    mtypes = cluster.machine_types[machine]
    e = cluster.profile.e[ttypes, mtypes]
    met = cluster.profile.met[ttypes, mtypes]
    return comp, machine, e, met


def simulate(etg: ExecutionGraph, cluster: Cluster, r0: float) -> SimResult:
    """Single-placement steady state (thin wrapper over the batched core)."""
    machine = etg.task_machine()[None, :]
    return simulate_batch(etg, cluster, machine, r0).row(0)


@dataclasses.dataclass(frozen=True)
class BatchSimResult:
    ir: np.ndarray            # (B, T)
    pr: np.ndarray            # (B, T)
    tcu: np.ndarray           # (B, T)
    machine_util: np.ndarray  # (B, m)
    throughput: np.ndarray    # (B,)

    def row(self, i: int) -> SimResult:
        """Single candidate row as a ``SimResult``."""
        return SimResult(
            ir=self.ir[i],
            pr=self.pr[i],
            tcu=self.tcu[i],
            machine_util=self.machine_util[i],
            throughput=float(self.throughput[i]),
        )


@functools.cache
def _jax_available() -> bool:
    # Memoized: failed imports are not cached by Python, so probing per
    # call would re-walk sys.path on every auto dispatch on JAX-less hosts.
    try:
        import jax  # noqa: F401

        return True
    except Exception:
        return False


# Per-regime element floors (B*T per sweep) below which "auto" never
# considers JAX for the closed-form scorers, calibrated by
# benchmarks/bench_dispatch.py (see BENCH_dispatch.json). Since the scorer
# went scatter-free (``sim_jax._msr_kernel``'s one-hot contraction — XLA's
# serial CPU scatter-add never won), the JAX path beats NumPy 2-6x on CPU
# for paper-realistic machine counts once the sweep amortizes dispatch.
# The floors sit above the largest sweep the golden refine/optimal suites
# issue (measured by instrumenting this resolver under the full tier-1 +
# slow runs: 98,304 shared / 8,800 per-row / 960 skew elements), so
# reference results stay bit-identical by construction; the bench's
# realistic scenarios (B*T >= ~230k at B=16384) clear them. The contraction does B*T*m work versus NumPy's B*T, so wide
# clusters flip the verdict — ``_AUTO_MAX_MACHINES`` gates those back to
# NumPy on CPU (accelerators keep parallel reductions, no gate). Skew rows
# run the same kernel (skew only changes the unit-rate values), sharing the
# measured per-row crossover. Recalibrate with bench_dispatch.py when the
# host changes; override via REPRO_CLOSED_FORM_JAX_THRESHOLD (all regimes)
# or REPRO_CLOSED_FORM_JAX_THRESHOLD_{SHARED,PER_ROW,SKEW}.
_CLOSED_FORM_AUTO_THRESHOLDS = {
    "shared": 131_072,
    "per_row": 65_536,
    "skew": 65_536,
}

# CPU-only machine-count gate for "auto": the dense contraction's B*T*m
# cost loses to NumPy's serial B*T scatter on wide clusters (measured 180
# machines: 0.03-0.4x across nine formulations). Bench large scenario (15
# machines) still wins, the stress scenario (180) documents the loss.
_AUTO_MAX_MACHINES = 32

# CPU-only work ceiling for "auto", in B*T*m products: past it the one-hot
# intermediates fall out of cache and the contraction collapses even on
# mid-width clusters (measured on the 15-machine scenario: 1.2-1.3x NumPy
# at 3.3M products, 0.35x at 13.3M). Between the floors and this ceiling
# the contraction wins at every measured grid point.
_AUTO_MAX_WORK = 6_000_000


@functools.cache
def _jax_accelerator_available() -> bool:
    """True iff JAX imports *and* its default backend is not the CPU."""
    if not _jax_available():
        return False
    try:
        import jax

        return jax.default_backend() != "cpu"
    except Exception:
        return False


def _closed_form_auto_threshold(regime: str = "shared") -> tuple[float, bool]:
    """Current "auto" crossover in elements for one scoring regime.

    Returns ``(threshold, overridden)``. ``REPRO_CLOSED_FORM_JAX_THRESHOLD``
    overrides every regime; ``REPRO_CLOSED_FORM_JAX_THRESHOLD_<REGIME>``
    (SHARED / PER_ROW / SKEW) wins over both for its regime. An env
    override also bypasses the machine-count gate (set one after
    recalibrating bench_dispatch.py on new hardware, or to force the JAX
    path in tests); otherwise the calibrated per-regime floor applies.
    """
    import os

    if regime not in _CLOSED_FORM_AUTO_THRESHOLDS:
        raise ValueError(f"unknown scoring regime {regime!r}")
    env = os.environ.get(f"REPRO_CLOSED_FORM_JAX_THRESHOLD_{regime.upper()}")
    if env is None:
        env = os.environ.get("REPRO_CLOSED_FORM_JAX_THRESHOLD")
    if env is not None:
        return float(env), True
    return float(_CLOSED_FORM_AUTO_THRESHOLDS[regime]), False


def resolve_closed_form_backend(
    backend: str,
    elements: int | None = None,
    regime: str = "shared",
    n_machines: int | None = None,
    site: str | None = None,
) -> str:
    """Validate + resolve a closed-form scoring backend request.

    Shared by ``cost_model.max_stable_rate_batch`` and
    ``ScheduleState.score_task_machine_batch`` so the backend-string
    contract, the ``"auto"`` dispatch heuristic and the graceful
    JAX-missing fallback live in one place (``simulate_batch`` keeps its own
    richer policy: its fixed-point loop has a different cost profile).

    Args:
      backend: ``"numpy"``, ``"jax"``, or ``"auto"`` (JAX iff the sweep
        clears the regime's calibrated element crossover and the cluster
        passes the machine-count gate — see ``_closed_form_auto_threshold``).
      elements: batch size in B*T elements; required for ``"auto"`` to ever
        pick JAX (``None`` resolves to NumPy — the safe reference).
      regime: which crossover table applies — ``"shared"`` ((T,) maps),
        ``"per_row"`` ((B, T) maps), or ``"skew"`` (realized fields-grouping
        rates; per-row shapes, separate calibration row in the bench).
      n_machines: cluster width for the CPU contraction gates (the dense
        one-hot does B*T*m work, so wide clusters and out-of-cache sweeps
        stay NumPy). ``None`` skips the gates; internal scoring call sites
        always pass it.
      site: caller label recorded in the observability dispatch log
        (``repro.obs``); no effect on resolution.
    """
    requested = backend
    if backend not in ("numpy", "jax", "auto"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "auto":
        threshold, overridden = _closed_form_auto_threshold(regime)
        if elements is None:
            backend = "numpy"
        else:
            gate_ok = (
                overridden
                or n_machines is None
                or _jax_accelerator_available()
                or (
                    n_machines <= _AUTO_MAX_MACHINES
                    and elements * n_machines <= _AUTO_MAX_WORK
                )
            )
            backend = "jax" if gate_ok and elements >= threshold else "numpy"
    resolved = "jax" if backend == "jax" and _jax_available() else "numpy"
    # Auditability of the auto-dispatch gates: when a TraceRecorder is
    # active, every resolution lands in its dispatch log (no-op otherwise).
    record_dispatch(requested, resolved, regime, elements, n_machines, site)
    return resolved


# Batches at least this large amortize JAX dispatch/compile overhead on the
# fixed-point sweep; below it the NumPy path wins.
_JAX_AUTO_THRESHOLD = 32_768  # B * T elements


def simulate_batch(
    etg: ExecutionGraph,
    cluster: Cluster,
    task_machine: np.ndarray,
    r0,
    backend: str = "auto",
) -> BatchSimResult:
    """Evaluate B placements (same instance counts) in one vectorized sweep.

    Args:
      etg: supplies the UTG and instance counts (its own assignment ignored).
      task_machine: (B, T) machine index per task per candidate.
      r0: offered topology input rate at each spout — a scalar applied to
        every candidate, or a (B,) vector with one rate per candidate row
        (lets e.g. benchmarks score proposed-vs-default placements at their
        own stable rates in a single sweep).
      backend: ``"numpy"`` (reference), ``"jax"`` (jitted
        ``lax.while_loop`` fixed point, float64 — agrees with NumPy to
        1e-9), or ``"auto"`` (JAX for large batches when importable, NumPy
        otherwise). The JAX path falls back to NumPy if JAX is missing.
    """
    if backend not in ("auto", "numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "auto":
        tm = np.asarray(task_machine)
        backend = (
            "jax"
            if tm.size >= _JAX_AUTO_THRESHOLD and _jax_available()
            else "numpy"
        )
    if backend == "jax":
        if _jax_available():
            from repro.core.sim_jax import simulate_batch_jax

            return simulate_batch_jax(etg, cluster, task_machine, r0)
        backend = "numpy"  # graceful fallback: NumPy is the reference path

    utg = etg.utg
    comp = etg.task_component()                       # (T,)
    n_inst = etg.n_instances
    task_machine = np.asarray(task_machine, dtype=np.int64)
    if task_machine.ndim != 2 or task_machine.shape[1] != comp.shape[0]:
        raise ValueError("task_machine must be (B, T)")
    B, T = task_machine.shape
    m = cluster.n_machines
    r0 = np.asarray(r0, dtype=np.float64)
    if r0.ndim not in (0, 1) or (r0.ndim == 1 and r0.shape != (B,)):
        raise ValueError("r0 must be a scalar or a (B,) vector")
    if B == 0:
        # Empty batch: the fixed point's convergence reduction is undefined
        # over zero rows; return correctly-shaped empties instead.
        empty = np.zeros((0, T), dtype=np.float64)
        return BatchSimResult(
            ir=empty,
            pr=empty.copy(),
            tcu=empty.copy(),
            machine_util=np.zeros((0, m), dtype=np.float64),
            throughput=np.zeros(0, dtype=np.float64),
        )

    ttypes = utg.component_types[comp]                # (T,)
    mtypes = cluster.machine_types[task_machine]      # (B, T)
    e = cluster.profile.e[ttypes[None, :], mtypes]    # (B, T)
    met = cluster.profile.met[ttypes[None, :], mtypes]

    # Fixed MET load per machine (rate independent).
    rows = np.repeat(np.arange(B), T)
    cols = task_machine.reshape(-1)
    met_load = np.zeros((B, m), dtype=np.float64)
    np.add.at(met_load, (rows, cols), met.reshape(-1))

    topo = utg.topo_order()
    sources = set(utg.sources)
    parents = [utg.parents(i) for i in range(utg.n_components)]
    alpha = utg.alpha

    # Machine demand scale factors, refined to a fixed point.
    s = np.ones((B, m), dtype=np.float64)
    cir = np.zeros((B, utg.n_components), dtype=np.float64)
    pr_comp = np.zeros_like(cir)  # processed (post-throttle) rate per component

    # Mean throttle factor applied to a component's instances, given the
    # candidate's machine scale factors: instances split rate evenly, so the
    # component's processed rate is CIR/N * sum_k s[machine of instance k].
    inst_of_comp = [np.flatnonzero(comp == i) for i in range(utg.n_components)]

    ir_task = np.zeros((B, T), dtype=np.float64)
    for _ in range(_MAX_ITERS):
        # Propagate rates in topo order under current throttle factors.
        for i in topo:
            if i in sources:
                cir[:, i] = r0
            else:
                cir[:, i] = 0.0
                for p in parents[i]:
                    cir[:, i] += alpha[p] * pr_comp[:, p]
            idx = inst_of_comp[i]
            per_inst = cir[:, i : i + 1] / float(n_inst[i])     # (B, 1)
            ir_task[:, idx] = per_inst
            s_inst = np.take_along_axis(s, task_machine[:, idx], axis=1)
            pr_comp[:, i] = per_inst[:, 0] * s_inst.sum(axis=1)

        # Recompute machine scale factors from offered variable load.
        var = e * ir_task                                         # (B, T)
        var_load = np.zeros((B, m), dtype=np.float64)
        np.add.at(var_load, (rows, cols), var.reshape(-1))
        head = np.maximum(cluster.capacity[None, :] - met_load, 0.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            s_new = np.where(var_load > head, head / np.maximum(var_load, 1e-300), 1.0)
        if np.max(np.abs(s_new - s)) < _TOL:
            s = s_new
            break
        s = s_new

    pr_task = ir_task * np.take_along_axis(s, task_machine, axis=1)
    tcu = e * pr_task + met
    util = np.zeros((B, m), dtype=np.float64)
    np.add.at(util, (rows, cols), tcu.reshape(-1))
    return BatchSimResult(
        ir=ir_task,
        pr=pr_task,
        tcu=tcu,
        machine_util=util,
        throughput=pr_task.sum(axis=1),
    )


def measured_tcu(
    etg: ExecutionGraph,
    cluster: Cluster,
    r0: float,
    seed: int = 0,
    noise_scale: float = 0.035,
) -> np.ndarray:
    """'Measured' per-task CPU utilization with the paper's noise profile.

    §6.2: measurement variance is low when the CPU is lightly or heavily
    loaded and highest at moderate load. We model the measurement error as
    zero-mean Gaussian with std ``noise_scale * 100 * 4u(1-u)`` where u is
    the machine's utilization fraction — a parabola peaking at u=0.5 —
    truncated so the max |error| stays below the paper's observed 8 points.
    """
    sim = simulate(etg, cluster, r0)
    machine = etg.task_machine()
    u = np.clip(sim.machine_util[machine] / cluster.capacity[machine], 0.0, 1.0)
    std = noise_scale * 100.0 * 4.0 * u * (1.0 - u)
    rng = np.random.default_rng(seed)
    noise = np.clip(rng.normal(0.0, 1.0, size=std.shape) * std, -7.9, 7.9)
    return np.clip(sim.tcu + noise, 0.0, None)
