"""CPU-usage prediction (eq. 5) and rate propagation (eq. 6) — paper §5.2.

Vectorized over components / tasks. All functions are pure NumPy so the
scheduler's inner loop (which calls these thousands of times) stays
allocation-light; a batched variant used by the optimal scheduler lives in
``simulator.py``.

Conventions
-----------
* Rates are tuples/second. ``R0`` is the topology input rate injected at
  every spout.
* Shuffle grouping splits a component's incoming stream evenly over its
  instances (the paper's eq. 6 with uniform division), so all instances of a
  component share one input rate ``CIR_i / N_i``.
* Fields grouping (``UserGraph.groupings``) pins each key to one instance;
  a ``SkewModel`` carries the realized per-instance load fractions so the
  closed form can score imbalanced placements — per-instance IR becomes
  ``CIR_i * frac_{i,k}(N_i)`` instead of ``CIR_i / N_i``, still linear in
  the topology input rate, so R* keeps its closed form.
* With multiple downstream components, Storm *replicates* the output stream
  per subscribing component; within a component it is split evenly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = [
    "component_rates",
    "instance_rates",
    "Prediction",
    "predict",
    "closed_form_rates",
    "max_stable_rate",
    "max_stable_rate_batch",
    "network_unit_load",
    "per_row_task_maps",
    "resource_operands",
    "SkewModel",
]

# Element cap for one row chunk of the network accumulation: the cut-traffic
# term materializes (B_chunk, n_components, n_machines) scatter tensors (four
# of them) plus the distance matvecs, so wide topologies on large clusters
# would otherwise blow past the (B, T) sweep memory ``refine._SCORE_CHUNK``
# budgets for. Rows are independent, so chunking never changes results
# (regression-tested at m=90 in tests/test_resource_vector.py).
_NET_CHUNK_ELEMS = 4_000_000


def component_rates(utg: UserGraph, r0: float) -> np.ndarray:
    """Component-level input rates CIR (eq. 6 aggregated per component).

    Spouts receive ``r0`` each. For a non-spout component b:
    ``CIR_b = sum_{(a,b) in E} alpha_a * CIR_a``.
    """
    n = utg.n_components
    cir = np.zeros(n, dtype=np.float64)
    for s in utg.sources:
        cir[s] = r0
    for v in utg.topo_order():
        out = utg.alpha[v] * cir[v]
        for c in utg.children(v):
            cir[c] += out
    return cir


def instance_rates(
    etg: ExecutionGraph, r0: float, skew: "SkewModel | None" = None
) -> np.ndarray:
    """Per-task input rate IR_i (eq. 6): CIR of its component / N instances.

    With a ``skew`` model, keyed components use their realized per-instance
    fractions instead of the even split (shuffle components unchanged).
    """
    if skew is not None:
        if skew.utg is not etg.utg:
            raise ValueError("skew model was built for a different topology")
        return skew.per_task_unit_ir(etg.n_instances) * float(r0)
    cir = component_rates(etg.utg, r0)
    comp = etg.task_component()
    return cir[comp] / etg.n_instances[comp]


class SkewModel:
    """Realized fields-grouping load shape for closed-form scoring.

    Built from one key realization per fields edge (drawn at trace compile
    time — see ``runtime_stream.traces.KeyRealization``), the model answers
    one question: what fraction of component c's input does instance k of
    N handle? For a keyed component that is a mix of its in-edge streams —
    shuffle edges (and spout injection) split evenly, each fields edge
    routes by its key→hash→instance map:

        frac_{c,k}(N) = even_c / N + sum_e w_e * shares_e(N)[k]

    where ``w_e`` is edge e's share of the component's unit-rate CIR (a
    rate-independent constant, eq. 6 linearity) and ``even_c`` the
    remainder. Components without fields in-edges keep the exact eq. 6
    even-split floats (``instance_fractions`` returns None for them), so a
    skew-scored schedule only departs from the even-split score where keys
    actually route.

    The model also carries the operators' *keyed state*: each fields edge
    declares ``state_per_tuple`` (state tuples retained per unit of the
    edge's tuple rate — ``FieldsGrouping.state_per_tuple``), and instance k
    of a keyed component owns state proportional to the key share it
    handles:

        state_{c,k}(N) = sum_e state_per_tuple_e * alpha_p * CIR_p(1) * shares_e(N)[k]

    — the SkewModel fractions × a per-component state size. Shuffle
    components (and fields edges with ``state_per_tuple == 0``) carry no
    keyed state, so a shuffle-only topology's migrations stay free of
    state transfer (``per_task_state`` is all zeros) and drop-only replans
    remain free.
    """

    __slots__ = (
        "utg",
        "cir_unit",
        "_keyed",
        "_state_mix",
        "_frac_cache",
        "_unit_ir_cache",
        "_state_cache",
    )

    def __init__(
        self,
        utg: UserGraph,
        edge_shares: dict[tuple[int, int], Callable[[int], np.ndarray]],
    ):
        """Args:
          utg: the topology (supplies groupings and alpha/CIR structure).
          edge_shares: per fields edge, a callable mapping a downstream
            instance count n to the (n,) tuple-share vector (e.g. a
            ``KeyRealization.shares`` bound method). Must cover exactly
            the UTG's fields-grouped edges.
        """
        want = {g.edge for g in utg.groupings}
        if set(edge_shares) != want:
            raise ValueError(
                f"edge_shares must cover exactly the fields edges {sorted(want)}"
            )
        self.utg = utg
        self.cir_unit = component_rates(utg, 1.0)
        # Per keyed component: (even_weight, [(edge_weight, shares_fn), ...])
        # and the state mix [(state_size_e, shares_fn), ...] where
        # state_size_e = state_per_tuple_e * the edge's unit-rate tuple flow.
        self._keyed: dict[int, tuple[float, list]] = {}
        self._state_mix: dict[int, list] = {}
        for c in utg.keyed_components:
            cir_c = float(self.cir_unit[c])
            mix: list[tuple[float, Callable[[int], np.ndarray]]] = []
            smix: list[tuple[float, Callable[[int], np.ndarray]]] = []
            keyed_w = 0.0
            for g in utg.groupings:
                p, dst = g.edge
                if dst != c:
                    continue
                flow = float(utg.alpha[p] * self.cir_unit[p])
                w = flow / cir_c if cir_c > 0.0 else 0.0
                mix.append((w, edge_shares[g.edge]))
                keyed_w += w
                if g.state_per_tuple > 0.0:
                    smix.append((g.state_per_tuple * flow, edge_shares[g.edge]))
            self._keyed[c] = (max(1.0 - keyed_w, 0.0), mix)
            if smix:
                self._state_mix[c] = smix
        self._frac_cache: dict[tuple[int, int], np.ndarray] = {}
        self._unit_ir_cache: dict[tuple[int, ...], np.ndarray] = {}
        self._state_cache: dict[tuple[int, ...], np.ndarray] = {}

    @property
    def keyed_components(self) -> list[int]:
        return sorted(self._keyed)

    def instance_fractions(self, component: int, n: int) -> np.ndarray | None:
        """(n,) input fraction per instance of ``component`` at count ``n``,
        or None for shuffle components (use the exact eq. 6 even split)."""
        if component not in self._keyed:
            return None
        key = (component, int(n))
        frac = self._frac_cache.get(key)
        if frac is None:
            even_w, mix = self._keyed[component]
            frac = np.full(int(n), even_w / int(n), dtype=np.float64)
            for w_e, shares_fn in mix:
                frac = frac + w_e * shares_fn(int(n))
            self._frac_cache[key] = frac
        return frac

    def per_task_unit_ir(self, n_instances: np.ndarray) -> np.ndarray:
        """(T,) per-task input rate at unit topology rate for an (n,)
        instance-count vector (paper eq. 3 task order)."""
        key = tuple(int(k) for k in np.asarray(n_instances))
        out = self._unit_ir_cache.get(key)
        if out is None:
            parts = []
            for c, nk in enumerate(key):
                frac = self.instance_fractions(c, nk)
                if frac is None:
                    # Same division the even-split path performs, so shuffle
                    # components' floats agree exactly.
                    parts.append(np.full(nk, self.cir_unit[c] / nk))
                else:
                    parts.append(self.cir_unit[c] * frac)
            out = np.concatenate(parts) if parts else np.zeros(0)
            self._unit_ir_cache[key] = out
        return out

    def per_row_unit_ir(self, n_instances: np.ndarray) -> np.ndarray:
        """(B, T) per-task unit input rates for a (B, n) count matrix
        (every row must share one task total)."""
        n_instances = np.asarray(n_instances, dtype=np.int64)
        uniq, inverse = np.unique(n_instances, axis=0, return_inverse=True)
        rows = np.stack([self.per_task_unit_ir(u) for u in uniq])
        # reshape: np.unique's inverse shape for axis=0 varies across
        # NumPy 2.x minors (flat vs shaped); flat indexing works on all.
        return rows[inverse.reshape(-1)]

    # ------------------------------------------------------- keyed state

    @property
    def has_state(self) -> bool:
        """True when any fields edge declares ``state_per_tuple > 0`` —
        i.e. migrations can ship state and should be priced for it."""
        return bool(self._state_mix)

    def component_state(self) -> np.ndarray:
        """(n,) total keyed state per component (state tuples): the sum of
        every in-edge's ``state_per_tuple`` × unit-rate tuple flow.
        Invariant under the instance count — resharding moves state
        between instances, it never creates or destroys it."""
        out = np.zeros(self.utg.n_components, dtype=np.float64)
        for c, smix in self._state_mix.items():
            out[c] = sum(s for s, _ in smix)
        return out

    def instance_state(self, component: int, n: int) -> np.ndarray:
        """(n,) keyed state held by each instance of ``component`` at count
        ``n`` — the component's state split by realized key share (an
        instance owning the hot key holds proportionally more state).
        Zeros for stateless/shuffle components."""
        smix = self._state_mix.get(component)
        out = np.zeros(int(n), dtype=np.float64)
        if smix is None:
            return out
        for s_e, shares_fn in smix:
            out = out + s_e * shares_fn(int(n))
        return out

    def per_task_state(self, n_instances: np.ndarray) -> np.ndarray:
        """(T,) keyed state per task (paper eq. 3 task order) for an (n,)
        instance-count vector; zeros wherever no stateful fields edge
        lands."""
        key = tuple(int(k) for k in np.asarray(n_instances))
        out = self._state_cache.get(key)
        if out is None:
            parts = [self.instance_state(c, nk) for c, nk in enumerate(key)]
            out = np.concatenate(parts) if parts else np.zeros(0)
            self._state_cache[key] = out
        return out


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Predicted state of an (ETG, cluster, rate) triple.

    Attributes:
      ir: (T,) per-task input rates.
      tcu: (T,) predicted per-task CPU utilization (eq. 5).
      machine_util: (m,) predicted utilization per machine.
      mac: (m,) remaining capacity (paper's MAC).
      throughput: predicted overall throughput = sum of task processing
        rates, assuming no machine is over-utilized (the paper's objective,
        eq. 2, under the MAC >= 0 constraint).
    """

    ir: np.ndarray
    tcu: np.ndarray
    machine_util: np.ndarray
    mac: np.ndarray
    throughput: float

    @property
    def over_utilized(self) -> np.ndarray:
        """(m,) bool — machines whose predicted utilization exceeds capacity."""
        return self.mac < 0.0

    @property
    def feasible(self) -> bool:
        return bool(np.all(self.mac >= 0.0))


def predict(etg: ExecutionGraph, cluster: Cluster, r0: float) -> Prediction:
    """eq. 5 over every task of the ETG at topology input rate ``r0``."""
    comp = etg.task_component()            # (T,)
    machine = etg.task_machine()           # (T,)
    task_types = etg.utg.component_types[comp]
    ir = instance_rates(etg, r0)           # (T,)

    mtypes = cluster.machine_types[machine]
    e = cluster.profile.e[task_types, mtypes]
    met = cluster.profile.met[task_types, mtypes]
    tcu = e * ir + met                     # eq. 5

    util = np.zeros(cluster.n_machines, dtype=np.float64)
    np.add.at(util, machine, tcu)
    mac = cluster.capacity - util
    return Prediction(
        ir=ir,
        tcu=tcu,
        machine_util=util,
        mac=mac,
        throughput=float(ir.sum()),
    )


def max_stable_rate(
    etg: ExecutionGraph, cluster: Cluster, skew: SkewModel | None = None
) -> tuple[float, float]:
    """Largest topology input rate with every MAC_w >= 0, and its throughput.

    Because eq. 5/6 are linear in the topology input rate R, the per-machine
    utilization is ``met_w + R * var_w`` with rate-independent coefficients,
    so the binding constraint solves in closed form:

        R* = min_w (capacity_w - met_w) / var_w     (over machines, var_w > 0)

    Returns (R*, throughput at R*) where throughput is the paper's objective
    (eq. 2): the sum of all task processing rates. A placement whose fixed
    MET overhead alone exceeds some machine's capacity is infeasible at any
    rate -> (0.0, 0.0). A ``skew`` model replaces keyed components' even
    split with their realized per-instance fractions (still linear in R, so
    the closed form is exact — the skew-aware utilization bound).
    """
    rate, thpt = max_stable_rate_batch(
        etg, cluster, etg.task_machine()[None, :], skew=skew
    )
    return float(rate[0]), float(thpt[0])


def per_row_task_maps(
    cir_unit: np.ndarray, n_instances: np.ndarray, n_tasks: int
) -> tuple[np.ndarray, np.ndarray]:
    """Per-row (component, unit-IR) task maps for a (B, n) count matrix.

    Supports candidate batches whose rows carry *different* instance-count
    vectors (e.g. lockstep growth chains growing different components), as
    long as every row has the same task total ``n_tasks`` — rectangular
    batches keep the vectorized scoring shape-stable.

    Per row b, task j belongs to the component whose cumulative count block
    contains j (paper eq. 3 order), and its unit input rate is
    ``cir_unit[c] / n_instances[b, c]`` — the same per-component division
    then gather the shared-count path performs, so per-row scores are
    bit-identical to scoring each row against its own template.

    Returns:
      (comp, unit_ir), each (B, n_tasks).
    """
    n_instances = np.asarray(n_instances, dtype=np.int64)
    if n_instances.ndim != 2:
        raise ValueError("per-row n_instances must be (B, n)")
    if np.any(n_instances < 1):
        raise ValueError("every component needs >= 1 instance (paper constraint)")
    if np.any(n_instances.sum(axis=1) != n_tasks):
        raise ValueError(
            "per-row n_instances must all sum to task_machine's task count"
        )
    # Batches built from candidate sweeps repeat count vectors in runs (a
    # lockstep chain contributes one vector for all m of its consecutive
    # rows), so map one representative per run and fan the results back
    # out — O(B·n) grouping, no sort. Values are unchanged: each row's
    # maps still come from its own vector.
    B = n_instances.shape[0]
    if B > 1:
        starts = np.empty(B, dtype=bool)
        starts[0] = True
        np.any(n_instances[1:] != n_instances[:-1], axis=1, out=starts[1:])
        reps = n_instances[starts]                     # (U, n)
        inverse = np.cumsum(starts) - 1                # (B,)
    else:
        reps, inverse = n_instances, np.zeros(B, dtype=np.int64)
    ends = np.cumsum(reps, axis=1)                     # (U, n)
    comp_u = (np.arange(n_tasks)[None, :] >= ends[:, :, None]).sum(axis=1)
    per_unit = cir_unit[None, :] / reps                # (U, n)
    unit_ir_u = np.take_along_axis(per_unit, comp_u, axis=1)
    return comp_u[inverse], unit_ir_u[inverse]


def network_unit_load(
    task_machine: np.ndarray,
    comp: np.ndarray,
    unit_ir: np.ndarray,
    alpha: np.ndarray,
    cir_unit: np.ndarray,
    edges: tuple,
    distance: np.ndarray,
    net_penalty: float = 1.0,
    chunk_elems: int = _NET_CHUNK_ELEMS,
) -> np.ndarray:
    """(B, m) per-machine cut-traffic CPU load at unit topology rate.

    The Eidenbenz & Locher cut-traffic term, folded into the closed form's
    variable coefficient: for every UTG edge (a, b), the unit-rate flow
    from instance i of a to instance j of b is ``out_i * rfrac_j`` where

    * ``out_i = alpha_a * unit_ir_i`` — sender i's unit-rate output stream
      (eq. 6: a component's output replicates per subscribing component);
    * ``rfrac_j = unit_ir_j / cir_unit_b`` — receiver j's share of b's
      input (the even split 1/N_b for shuffle components; the realized key
      share for skew rows — the same per-task ``unit_ir`` every scoring
      regime already carries fixes both).

    Each endpoint machine pays ``net_penalty * flow * distance[w_i, w_j]``
    CPU points per unit rate (serialization/deserialization cost of the
    cut stream; ``distance`` has a zero diagonal so colocated flow is
    free). The rank-1 (out × rfrac) structure means the per-edge double
    sum collapses to scatters by machine plus one distance matvec — O(B·T)
    scatter + O(B·n·m²) matmul, never the full edge×machine product; row
    chunks are capped at ``chunk_elems`` (B_chunk·n·m) elements.

    ``comp`` / ``unit_ir`` are (T,) shared or (B, T) per-row task maps —
    exactly the operands ``closed_form_rates`` receives, so every scoring
    regime (shared / per-row / skew) prices the same network term.
    """
    task_machine = np.asarray(task_machine, dtype=np.int64)
    B, T = task_machine.shape
    n = cir_unit.shape[0]
    m = distance.shape[0]
    comp_bt = comp if comp.ndim == 2 else np.broadcast_to(comp[None, :], (B, T))
    unit_bt = unit_ir if unit_ir.ndim == 2 else np.broadcast_to(
        unit_ir[None, :], (B, T)
    )
    alpha = np.asarray(alpha, dtype=np.float64)
    # Per-task sender output and receiver share (see docstring). A
    # zero-input component carries no flow; its receive fraction is moot.
    out_t = alpha[comp_bt] * unit_bt                         # (B, T)
    cir_of_t = cir_unit[comp_bt]
    with np.errstate(divide="ignore", invalid="ignore"):
        rfrac_t = np.where(cir_of_t > 0.0, unit_bt / np.maximum(cir_of_t, 1e-300), 0.0)

    net = np.empty((B, m), dtype=np.float64)
    chunk = max(1, int(chunk_elems) // max(1, n * m))
    for start in range(0, B, chunk):
        stop = min(start + chunk, B)
        bc = stop - start
        rows = np.repeat(np.arange(bc), T)
        cols_c = comp_bt[start:stop].reshape(-1)
        cols_w = task_machine[start:stop].reshape(-1)
        send = np.zeros((bc, n, m), dtype=np.float64)
        recv = np.zeros((bc, n, m), dtype=np.float64)
        np.add.at(send, (rows, cols_c, cols_w), out_t[start:stop].reshape(-1))
        np.add.at(recv, (rows, cols_c, cols_w), rfrac_t[start:stop].reshape(-1))
        # D-matvec per (row, component): charge on machine w is
        # Σ_v distance[w, v] × (other endpoint's mass on v).
        send_d = send @ distance.T                            # (bc, n, m)
        recv_d = recv @ distance.T
        acc = np.zeros((bc, m), dtype=np.float64)
        for a, b in edges:
            acc += send[:, a, :] * recv_d[:, b, :]            # sender side
            acc += recv[:, b, :] * send_d[:, a, :]            # receiver side
        net[start:stop] = acc
    return net * float(net_penalty)


def resource_operands(
    cluster: Cluster,
    task_machine: np.ndarray,
    comp: np.ndarray,
    unit_ir: np.ndarray,
    alpha: np.ndarray,
    cir_unit: np.ndarray,
    edges: tuple,
    component_types: np.ndarray,
) -> tuple[np.ndarray | None, np.ndarray | None, np.ndarray | None]:
    """(net_var, mem, mem_capacity) extras for ``closed_form_rates``.

    All three are ``None`` on a scalar-CPU cluster, so default-parameter
    scoring takes exactly the legacy code path (the bit-identity
    guarantee). ``mem`` matches ``comp``'s shape ((T,) or (B, T)).
    """
    net_var = mem = mem_capacity = None
    if cluster.has_network:
        net_var = network_unit_load(
            task_machine, comp, unit_ir, alpha, cir_unit, edges,
            cluster.distance, cluster.net_penalty,
        )
    if cluster.has_memory:
        mem = cluster.profile.mem[component_types[comp]]
        mem_capacity = cluster.mem_capacity
    return net_var, mem, mem_capacity


def max_stable_rate_batch(
    etg: ExecutionGraph,
    cluster: Cluster,
    task_machine: np.ndarray,
    backend: str = "numpy",
    n_instances: np.ndarray | None = None,
    skew: SkewModel | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized ``max_stable_rate`` over B placements.

    Args:
      task_machine: (B, T) machine index per task per candidate placement.
      backend: ``"numpy"`` (default; the reference floats — the refine and
        optimal engines' equivalence guarantees rely on it), ``"jax"``
        (jitted float64 scatter-free closed form, ~1e-15 relative
        agreement; falls back to NumPy when JAX is unavailable), or
        ``"auto"`` (JAX above the regime's calibrated element-count
        crossover, machine-count gated on CPU — see
        ``simulator.resolve_closed_form_backend`` / benchmarks/bench_dispatch.py).
      n_instances: optional (B, n) per-row instance-count matrix overriding
        ``etg.n_instances`` row by row (every row must sum to T). Lets one
        sweep score candidates that grow/shrink *different* components.
      skew: optional fields-grouping load model; keyed components score at
        their realized per-instance fractions instead of the even split.
        Skew rows dispatch like everything else (the jitted kernel is
        skew-agnostic — skew only changes the unit-rate values) under the
        ``"skew"`` crossover regime.

    Returns:
      (rates, throughputs), each (B,).
    """
    from repro.core.simulator import resolve_closed_form_backend

    task_machine = np.asarray(task_machine, dtype=np.int64)
    n_machines = cluster.capacity.shape[0]
    if skew is not None:
        if skew.utg is not etg.utg:
            raise ValueError("skew model was built for a different topology")
        if task_machine.ndim != 2:
            raise ValueError("task_machine must be (B, T)")
        if (
            resolve_closed_form_backend(
                backend,
                task_machine.size,
                regime="skew",
                n_machines=n_machines,
                site="max_stable_rate_batch",
            )
            == "jax"
        ):
            from repro.core.sim_jax import max_stable_rate_batch_jax

            return max_stable_rate_batch_jax(
                etg, cluster, task_machine, n_instances=n_instances, skew=skew
            )
        if n_instances is not None:
            n_inst_bn = np.asarray(n_instances, dtype=np.int64)
            comp, _ = per_row_task_maps(
                skew.cir_unit, n_inst_bn, task_machine.shape[1]
            )
            unit_ir = skew.per_row_unit_ir(n_inst_bn)
            task_types = etg.utg.component_types[comp]
        else:
            comp = etg.task_component()
            task_types = etg.utg.component_types[comp][None, :]
            unit_ir = skew.per_task_unit_ir(etg.n_instances)
        net_var = mem = mem_cap = None
        if cluster.has_resources:
            net_var, mem, mem_cap = resource_operands(
                cluster, task_machine, comp, unit_ir, etg.utg.alpha,
                skew.cir_unit, etg.utg.edges, etg.utg.component_types,
            )
        mtypes = cluster.machine_types[task_machine]
        e = cluster.profile.e[task_types, mtypes]
        met = cluster.profile.met[task_types, mtypes]
        return closed_form_rates(
            task_machine, e, met, unit_ir, cluster.capacity,
            net_var=net_var, mem=mem, mem_capacity=mem_cap,
        )
    if (
        resolve_closed_form_backend(
            backend,
            task_machine.size,
            regime="per_row" if n_instances is not None else "shared",
            n_machines=n_machines,
            site="max_stable_rate_batch",
        )
        == "jax"
    ):
        from repro.core.sim_jax import max_stable_rate_batch_jax

        return max_stable_rate_batch_jax(
            etg, cluster, task_machine, n_instances=n_instances
        )
    if n_instances is not None:
        if task_machine.ndim != 2:
            raise ValueError("task_machine must be (B, T)")
        cir_unit = component_rates(etg.utg, 1.0)
        comp, unit_ir = per_row_task_maps(
            cir_unit, n_instances, task_machine.shape[1]
        )                                              # each (B, T)
        task_types = etg.utg.component_types[comp]     # (B, T)
    else:
        comp = etg.task_component()
        task_types = etg.utg.component_types[comp][None, :]
        unit_ir = instance_rates(etg, 1.0)             # (T,) IR per unit R
    net_var = mem = mem_cap = None
    if cluster.has_resources:
        if n_instances is None:
            cir_unit = component_rates(etg.utg, 1.0)
        net_var, mem, mem_cap = resource_operands(
            cluster, task_machine, comp, unit_ir, etg.utg.alpha,
            cir_unit, etg.utg.edges, etg.utg.component_types,
        )

    mtypes = cluster.machine_types[task_machine]       # (B, T)
    e = cluster.profile.e[task_types, mtypes]
    met = cluster.profile.met[task_types, mtypes]
    return closed_form_rates(
        task_machine, e, met, unit_ir, cluster.capacity,
        net_var=net_var, mem=mem, mem_capacity=mem_cap,
    )


def closed_form_rates(
    task_machine: np.ndarray,
    e: np.ndarray,
    met: np.ndarray,
    unit_ir: np.ndarray,
    capacity: np.ndarray,
    net_var: np.ndarray | None = None,
    mem: np.ndarray | None = None,
    mem_capacity: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Shared closed-form scoring core (the single NumPy copy of the math).

    Given per-task (B, T) profile gathers, accumulate per-machine fixed and
    variable loads in task order and solve ``R* = min_w (cap_w - met_w) /
    var_w``. Both ``max_stable_rate_batch`` and
    ``ScheduleState.score_task_machine_batch`` call this — the engines'
    bit-identical-scoring contract rests on there being exactly one copy
    (``sim_jax._msr_kernel`` mirrors it in JAX, ~1e-15 agreement).

    ``unit_ir`` is (T,) when every row shares one instance-count vector, or
    (B, T) when rows carry their own (``per_row_task_maps``). NumPy's
    pairwise row sum makes the per-row throughput reduction bit-identical
    to the shared one.

    ``capacity`` is (m,) when every row scores against one capacity vector,
    or (B, m) when rows carry their own — the multi-tenant batch scorer
    prices each tenant's candidates against that tenant's residual
    capacity this way.

    Resource-vector extras (all default ``None`` = scalar-CPU scoring,
    byte-for-byte today's math):

    * ``net_var`` — (B, m) per-machine cut-traffic CPU load at unit rate
      (``network_unit_load``); added to the variable coefficient, so
      ``R* = min_w (cap_w - met_w) / (var_w + net_w)`` — the closed form
      with the network unit-IR folded in.
    * ``mem`` / ``mem_capacity`` — (T,)/(B, T) per-task memory demand and
      (m,)/(B, m) per-machine memory capacity. Memory is rate-independent,
      so it is a *hard* feasibility mask: any machine over memory makes
      the row's rate 0 regardless of CPU head room.
    """
    B, T = task_machine.shape
    m = capacity.shape[-1]
    rows = np.repeat(np.arange(B), T)
    cols = task_machine.reshape(-1)
    unit_ir_bt = unit_ir if unit_ir.ndim == 2 else unit_ir[None, :]
    var_w = np.zeros((B, m), dtype=np.float64)
    met_w = np.zeros((B, m), dtype=np.float64)
    np.add.at(var_w, (rows, cols), (e * unit_ir_bt).reshape(-1))
    np.add.at(met_w, (rows, cols), met.reshape(-1))
    if net_var is not None:
        var_w = var_w + net_var

    cap_b = capacity if capacity.ndim == 2 else capacity[None, :]
    head = cap_b - met_w                               # (B, m)
    infeasible = np.any(head < 0.0, axis=1)
    if mem is not None:
        mem_bt = mem if mem.ndim == 2 else mem[None, :]
        mem_w = np.zeros((B, m), dtype=np.float64)
        np.add.at(
            mem_w, (rows, cols), np.broadcast_to(mem_bt, (B, T)).reshape(-1)
        )
        mem_cap_b = (
            mem_capacity if mem_capacity.ndim == 2 else mem_capacity[None, :]
        )
        infeasible |= np.any(mem_w > mem_cap_b, axis=1)
    # over="ignore": a zero-var machine with capacity-scale head can hit
    # head/1e-300 -> inf; np.where discards it, so silence the warning.
    with np.errstate(divide="ignore", over="ignore"):
        limits = np.where(var_w > 0.0, head / np.maximum(var_w, 1e-300), np.inf)
    rates = np.min(limits, axis=1)
    rates = np.where(infeasible, 0.0, np.clip(rates, 0.0, None))
    if unit_ir.ndim == 2:
        return rates, rates * unit_ir.sum(axis=1)
    return rates, rates * unit_ir.sum()
