"""CPU-usage prediction (eq. 5) and rate propagation (eq. 6) — paper §5.2.

Vectorized over components / tasks. All functions are pure NumPy so the
scheduler's inner loop (which calls these thousands of times) stays
allocation-light; a batched variant used by the optimal scheduler lives in
``simulator.py``.

Conventions
-----------
* Rates are tuples/second. ``R0`` is the topology input rate injected at
  every spout.
* Shuffle grouping splits a component's incoming stream evenly over its
  instances (the paper's eq. 6 with uniform division), so all instances of a
  component share one input rate ``CIR_i / N_i``.
* With multiple downstream components, Storm *replicates* the output stream
  per subscribing component; within a component it is split evenly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import ExecutionGraph, UserGraph
from repro.core.profiles import Cluster

__all__ = [
    "component_rates",
    "instance_rates",
    "Prediction",
    "predict",
    "closed_form_rates",
    "max_stable_rate",
    "max_stable_rate_batch",
    "per_row_task_maps",
]


def component_rates(utg: UserGraph, r0: float) -> np.ndarray:
    """Component-level input rates CIR (eq. 6 aggregated per component).

    Spouts receive ``r0`` each. For a non-spout component b:
    ``CIR_b = sum_{(a,b) in E} alpha_a * CIR_a``.
    """
    n = utg.n_components
    cir = np.zeros(n, dtype=np.float64)
    for s in utg.sources:
        cir[s] = r0
    for v in utg.topo_order():
        out = utg.alpha[v] * cir[v]
        for c in utg.children(v):
            cir[c] += out
    return cir


def instance_rates(etg: ExecutionGraph, r0: float) -> np.ndarray:
    """Per-task input rate IR_i (eq. 6): CIR of its component / N instances."""
    cir = component_rates(etg.utg, r0)
    comp = etg.task_component()
    return cir[comp] / etg.n_instances[comp]


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Predicted state of an (ETG, cluster, rate) triple.

    Attributes:
      ir: (T,) per-task input rates.
      tcu: (T,) predicted per-task CPU utilization (eq. 5).
      machine_util: (m,) predicted utilization per machine.
      mac: (m,) remaining capacity (paper's MAC).
      throughput: predicted overall throughput = sum of task processing
        rates, assuming no machine is over-utilized (the paper's objective,
        eq. 2, under the MAC >= 0 constraint).
    """

    ir: np.ndarray
    tcu: np.ndarray
    machine_util: np.ndarray
    mac: np.ndarray
    throughput: float

    @property
    def over_utilized(self) -> np.ndarray:
        """(m,) bool — machines whose predicted utilization exceeds capacity."""
        return self.mac < 0.0

    @property
    def feasible(self) -> bool:
        return bool(np.all(self.mac >= 0.0))


def predict(etg: ExecutionGraph, cluster: Cluster, r0: float) -> Prediction:
    """eq. 5 over every task of the ETG at topology input rate ``r0``."""
    comp = etg.task_component()            # (T,)
    machine = etg.task_machine()           # (T,)
    task_types = etg.utg.component_types[comp]
    ir = instance_rates(etg, r0)           # (T,)

    mtypes = cluster.machine_types[machine]
    e = cluster.profile.e[task_types, mtypes]
    met = cluster.profile.met[task_types, mtypes]
    tcu = e * ir + met                     # eq. 5

    util = np.zeros(cluster.n_machines, dtype=np.float64)
    np.add.at(util, machine, tcu)
    mac = cluster.capacity - util
    return Prediction(
        ir=ir,
        tcu=tcu,
        machine_util=util,
        mac=mac,
        throughput=float(ir.sum()),
    )


def max_stable_rate(etg: ExecutionGraph, cluster: Cluster) -> tuple[float, float]:
    """Largest topology input rate with every MAC_w >= 0, and its throughput.

    Because eq. 5/6 are linear in the topology input rate R, the per-machine
    utilization is ``met_w + R * var_w`` with rate-independent coefficients,
    so the binding constraint solves in closed form:

        R* = min_w (capacity_w - met_w) / var_w     (over machines, var_w > 0)

    Returns (R*, throughput at R*) where throughput is the paper's objective
    (eq. 2): the sum of all task processing rates. A placement whose fixed
    MET overhead alone exceeds some machine's capacity is infeasible at any
    rate -> (0.0, 0.0).
    """
    rate, thpt = max_stable_rate_batch(etg, cluster, etg.task_machine()[None, :])
    return float(rate[0]), float(thpt[0])


def per_row_task_maps(
    cir_unit: np.ndarray, n_instances: np.ndarray, n_tasks: int
) -> tuple[np.ndarray, np.ndarray]:
    """Per-row (component, unit-IR) task maps for a (B, n) count matrix.

    Supports candidate batches whose rows carry *different* instance-count
    vectors (e.g. lockstep growth chains growing different components), as
    long as every row has the same task total ``n_tasks`` — rectangular
    batches keep the vectorized scoring shape-stable.

    Per row b, task j belongs to the component whose cumulative count block
    contains j (paper eq. 3 order), and its unit input rate is
    ``cir_unit[c] / n_instances[b, c]`` — the same per-component division
    then gather the shared-count path performs, so per-row scores are
    bit-identical to scoring each row against its own template.

    Returns:
      (comp, unit_ir), each (B, n_tasks).
    """
    n_instances = np.asarray(n_instances, dtype=np.int64)
    if n_instances.ndim != 2:
        raise ValueError("per-row n_instances must be (B, n)")
    if np.any(n_instances < 1):
        raise ValueError("every component needs >= 1 instance (paper constraint)")
    if np.any(n_instances.sum(axis=1) != n_tasks):
        raise ValueError(
            "per-row n_instances must all sum to task_machine's task count"
        )
    # Batches built from candidate sweeps repeat count vectors in runs (a
    # lockstep chain contributes one vector for all m of its consecutive
    # rows), so map one representative per run and fan the results back
    # out — O(B·n) grouping, no sort. Values are unchanged: each row's
    # maps still come from its own vector.
    B = n_instances.shape[0]
    if B > 1:
        starts = np.empty(B, dtype=bool)
        starts[0] = True
        np.any(n_instances[1:] != n_instances[:-1], axis=1, out=starts[1:])
        reps = n_instances[starts]                     # (U, n)
        inverse = np.cumsum(starts) - 1                # (B,)
    else:
        reps, inverse = n_instances, np.zeros(B, dtype=np.int64)
    ends = np.cumsum(reps, axis=1)                     # (U, n)
    comp_u = (np.arange(n_tasks)[None, :] >= ends[:, :, None]).sum(axis=1)
    per_unit = cir_unit[None, :] / reps                # (U, n)
    unit_ir_u = np.take_along_axis(per_unit, comp_u, axis=1)
    return comp_u[inverse], unit_ir_u[inverse]


def max_stable_rate_batch(
    etg: ExecutionGraph,
    cluster: Cluster,
    task_machine: np.ndarray,
    backend: str = "numpy",
    n_instances: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized ``max_stable_rate`` over B placements.

    Args:
      task_machine: (B, T) machine index per task per candidate placement.
      backend: ``"numpy"`` (default; the reference floats — the refine and
        optimal engines' equivalence guarantees rely on it), ``"jax"``
        (jitted float64 closed form, ~1e-15 relative agreement; falls back
        to NumPy when JAX is unavailable — worthwhile for very large B), or
        ``"auto"`` (JAX above the calibrated element-count crossover, see
        ``simulator.resolve_closed_form_backend`` / benchmarks/bench_dispatch.py).
      n_instances: optional (B, n) per-row instance-count matrix overriding
        ``etg.n_instances`` row by row (every row must sum to T). Lets one
        sweep score candidates that grow/shrink *different* components.

    Returns:
      (rates, throughputs), each (B,).
    """
    from repro.core.simulator import resolve_closed_form_backend

    task_machine = np.asarray(task_machine, dtype=np.int64)
    if resolve_closed_form_backend(backend, task_machine.size) == "jax":
        from repro.core.sim_jax import max_stable_rate_batch_jax

        return max_stable_rate_batch_jax(
            etg, cluster, task_machine, n_instances=n_instances
        )
    if n_instances is not None:
        if task_machine.ndim != 2:
            raise ValueError("task_machine must be (B, T)")
        cir_unit = component_rates(etg.utg, 1.0)
        comp, unit_ir = per_row_task_maps(
            cir_unit, n_instances, task_machine.shape[1]
        )                                              # each (B, T)
        task_types = etg.utg.component_types[comp]     # (B, T)
    else:
        comp = etg.task_component()
        task_types = etg.utg.component_types[comp][None, :]
        unit_ir = instance_rates(etg, 1.0)             # (T,) IR per unit R

    mtypes = cluster.machine_types[task_machine]       # (B, T)
    e = cluster.profile.e[task_types, mtypes]
    met = cluster.profile.met[task_types, mtypes]
    return closed_form_rates(task_machine, e, met, unit_ir, cluster.capacity)


def closed_form_rates(
    task_machine: np.ndarray,
    e: np.ndarray,
    met: np.ndarray,
    unit_ir: np.ndarray,
    capacity: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Shared closed-form scoring core (the single NumPy copy of the math).

    Given per-task (B, T) profile gathers, accumulate per-machine fixed and
    variable loads in task order and solve ``R* = min_w (cap_w - met_w) /
    var_w``. Both ``max_stable_rate_batch`` and
    ``ScheduleState.score_task_machine_batch`` call this — the engines'
    bit-identical-scoring contract rests on there being exactly one copy
    (``sim_jax._msr_kernel`` mirrors it in JAX, ~1e-15 agreement).

    ``unit_ir`` is (T,) when every row shares one instance-count vector, or
    (B, T) when rows carry their own (``per_row_task_maps``). NumPy's
    pairwise row sum makes the per-row throughput reduction bit-identical
    to the shared one.
    """
    B, T = task_machine.shape
    m = capacity.shape[0]
    rows = np.repeat(np.arange(B), T)
    cols = task_machine.reshape(-1)
    unit_ir_bt = unit_ir if unit_ir.ndim == 2 else unit_ir[None, :]
    var_w = np.zeros((B, m), dtype=np.float64)
    met_w = np.zeros((B, m), dtype=np.float64)
    np.add.at(var_w, (rows, cols), (e * unit_ir_bt).reshape(-1))
    np.add.at(met_w, (rows, cols), met.reshape(-1))

    head = capacity[None, :] - met_w                   # (B, m)
    infeasible = np.any(head < 0.0, axis=1)
    # over="ignore": a zero-var machine with capacity-scale head can hit
    # head/1e-300 -> inf; np.where discards it, so silence the warning.
    with np.errstate(divide="ignore", over="ignore"):
        limits = np.where(var_w > 0.0, head / np.maximum(var_w, 1e-300), np.inf)
    rates = np.min(limits, axis=1)
    rates = np.where(infeasible, 0.0, np.clip(rates, 0.0, None))
    if unit_ir.ndim == 2:
        return rates, rates * unit_ir.sum(axis=1)
    return rates, rates * unit_ir.sum()
