"""Training runtime: checkpoint/restart, watchdog, straggler mitigation,
preemption handling, elastic re-planning.

Fault-tolerance model (designed for 1000+ nodes, exercised here on CPU):

* **Checkpoint/restart** — async atomic checkpoints every
  ``ckpt_every`` steps carry (params, opt state, data-pipeline state);
  ``Trainer.run`` auto-resumes from the newest complete checkpoint, so a
  killed process restarts losslessly (tests kill it mid-run).
* **Preemption** — SIGTERM flips a flag; the loop finishes the in-flight
  step, writes a synchronous checkpoint, and exits 0 (clean eviction).
* **Watchdog / stragglers** — a step-time EMA; any step slower than
  ``straggler_factor`` x EMA increments a strike counter per incident. On
  ``max_strikes`` the runtime calls the elastic hook — on a real fleet this
  re-runs the paper's scheduler with the degraded machine set (the paper:
  "by any change in the cluster state, this algorithm can be used to
  recalculate"), here it logs + re-plans via repro.sched.elastic.
* **NaN containment** — non-finite loss skips the update (grads dropped)
  and counts; persistent NaNs abort rather than corrupt the checkpoint
  lineage.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from pathlib import Path
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint import store

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    max_strikes: int = 5
    max_nan_steps: int = 10


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        train_step: Callable,            # (state, batch) -> (state, metrics)
        init_state: Callable[[], Any],   # () -> state
        data: Iterator[dict] | Any,      # supports iteration; optional .state()/.seek()
        elastic_hook: Callable[[dict], None] | None = None,
        log: Callable[[str], None] = print,
    ):
        self.cfg = cfg
        self.train_step = train_step
        self.init_state = init_state
        self.data = data
        self.elastic_hook = elastic_hook
        self.log = log
        self._preempted = False
        self._strikes = 0
        self._nan_steps = 0

    # -- signals --------------------------------------------------------
    def _install_signals(self) -> None:
        def handler(signum, frame):
            self._preempted = True
            self.log(f"[trainer] signal {signum}: preemption requested")

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not the main thread (tests)

    # -- checkpoint glue -------------------------------------------------
    def _restore(self, state: Any) -> tuple[Any, int]:
        latest = store.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return state, 0
        abstract = jax.tree.map(np.asarray, state)
        restored, step = store.restore(self.cfg.ckpt_dir, abstract, latest)
        restored = jax.tree.map(jax.numpy.asarray, restored)
        # data pipeline state rides in the manifest extra
        import json

        manifest = json.loads(
            (Path(self.cfg.ckpt_dir) / f"step_{step:08d}" / "manifest.json").read_text()
        )
        if hasattr(self.data, "seek") and manifest["extra"].get("data_state"):
            self.data.seek(manifest["extra"]["data_state"])
        self.log(f"[trainer] resumed from step {step}")
        return restored, step

    def _data_state(self) -> dict | None:
        return self.data.state() if hasattr(self.data, "state") else None

    # -- main loop --------------------------------------------------------
    def run(self) -> dict:
        self._install_signals()
        state = self.init_state()
        state, start = self._restore(state)
        ckpt = store.AsyncCheckpointer(self.cfg.ckpt_dir, keep=self.cfg.keep)
        it = iter(self.data)

        ema = None
        losses = []
        step = start
        try:
            while step < self.cfg.total_steps and not self._preempted:
                batch = next(it)
                t0 = time.time()
                new_state, metrics = self.train_step(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0

                if not np.isfinite(loss):
                    self._nan_steps += 1
                    self.log(f"[trainer] step {step}: non-finite loss, skipping update "
                             f"({self._nan_steps}/{self.cfg.max_nan_steps})")
                    if self._nan_steps >= self.cfg.max_nan_steps:
                        raise FloatingPointError("persistent non-finite loss")
                    step += 1
                    continue
                state = new_state
                losses.append(loss)
                step += 1

                # Watchdog / straggler detection.
                if ema is None:
                    ema = dt
                ema = 0.9 * ema + 0.1 * dt
                if dt > self.cfg.straggler_factor * ema and step - start > 5:
                    self._strikes += 1
                    self.log(f"[trainer] step {step}: straggler step "
                             f"({dt:.3f}s vs EMA {ema:.3f}s), strike {self._strikes}")
                    if self._strikes >= self.cfg.max_strikes and self.elastic_hook:
                        self.elastic_hook({"step": step, "ema": ema, "last": dt})
                        self._strikes = 0

                if step % self.cfg.log_every == 0:
                    self.log(f"[trainer] step {step}: loss {loss:.4f} ({dt*1e3:.0f} ms)")
                if step % self.cfg.ckpt_every == 0:
                    ckpt.save(step, state, extra={"data_state": self._data_state()})

            if self._preempted:
                self.log(f"[trainer] preempted at step {step}; final checkpoint")
                store.save(self.cfg.ckpt_dir, step, jax.tree.map(np.asarray, state),
                           extra={"data_state": self._data_state()})
        finally:
            ckpt.close()
        return {"final_step": step, "losses": losses, "state": state}
