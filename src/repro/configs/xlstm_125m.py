"""xLSTM-125M [arXiv:2405.04517].

12 blocks, d_model 768, 4 heads, vocab 50304, no separate FFN (d_ff 0):
alternating mLSTM (matrix memory) / sLSTM (scalar memory) blocks.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=tuple("mlstm" if i % 2 == 0 else "slstm" for i in range(12)),
    tie_embeddings=True,
)
