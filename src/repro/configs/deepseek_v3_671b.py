"""DeepSeek-V3 671B [arXiv:2412.19437; hf].

61 layers, d_model 7168, 128 heads, MLA (q_lora 1536, kv_lora 512,
rope 64, nope 128, v 128). First 3 layers dense FFN (18432); the rest are
MoE: 1 shared + 256 routed experts (d_ff 2048), top-8. MTP depth 1.
vocab 129280.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,
    vocab_size=129_280,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    n_dense_layers=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    mtp_depth=1,
    rope_theta=1e4,
    opt_state_dtype="bfloat16",
    fsdp_over_pod=True,
)
