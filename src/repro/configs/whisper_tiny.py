"""Whisper-tiny [arXiv:2212.04356].

Encoder-decoder, 4+4 layers, d_model 384, 6 heads, d_ff 1536, vocab 51865.
The conv/mel frontend is a STUB: input_specs() supplies precomputed frame
embeddings (B, 1500, d_model).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    is_encoder_decoder=True,
    encoder_layers=4,
    encoder_seq=1500,
    tie_embeddings=True,
)
