"""StarCoder2-7B [arXiv:2402.19173; hf].

32 layers, d_model 4608, 36 heads (GQA kv=4), d_ff 18432, vocab 49152,
RoPE. (The released model uses sliding-window attention 4096; the assigned
config is exercised as full attention — see DESIGN.md shape-skip notes.)
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49_152,
    qkv_bias=True,
    rope_theta=1e5,
)
