"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; every config module
exposes ``CONFIG``. The paper's own benchmark topologies live in
``repro.configs.paper_topologies``.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

ARCHS = (
    "recurrentgemma_2b",
    "deepseek_v3_671b",
    "granite_moe_1b_a400m",
    "xlstm_125m",
    "whisper_tiny",
    "internlm2_1_8b",
    "yi_9b",
    "starcoder2_7b",
    "qwen1_5_0_5b",
    "qwen2_vl_72b",
)

_ALIASES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "xlstm-125m": "xlstm_125m",
    "whisper-tiny": "whisper_tiny",
    "internlm2-1.8b": "internlm2_1_8b",
    "yi-9b": "yi_9b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["ARCHS", "get_config", "get_shape", "SHAPES"]
