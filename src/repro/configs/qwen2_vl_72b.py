"""Qwen2-VL-72B [arXiv:2409.12191; hf] — transformer backbone only.

80 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064,
M-RoPE (temporal/height/width sections). The vision patch frontend is a
STUB: input_specs() supplies precomputed patch embeddings + M-RoPE position
streams.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),  # pairs: sums to head_dim/2 = 64
    rope_theta=1e6,
    embedding_inputs=True,
    opt_state_dtype="bfloat16",
    fsdp_over_pod=True,
)
