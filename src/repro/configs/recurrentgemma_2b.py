"""RecurrentGemma-2B [arXiv:2402.19427; hf].

26 blocks, d_model 2560, 10 heads (MQA kv=1, head_dim 256), d_ff 7680,
vocab 256000. Griffin layout: 1 local-attention block per 2 RG-LRU
recurrent blocks (window 2048); lru width = d_model.
"""

from repro.models.config import ModelConfig

_PATTERN = []
for i in range(26):
    _PATTERN.append("local_attn" if i % 3 == 2 else "rglru")

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=tuple(_PATTERN),
    local_window=2048,
    lru_width=2560,
    conv_width=4,
    rope_theta=1e4,
    tie_embeddings=True,
)
