"""Token data pipeline: synthetic corpus + memmap shard reader with
background prefetch and deterministic, restart-safe iteration order.

* ``SyntheticLM`` — endless next-token batches from a seeded generator with
  mild Zipfian token statistics (keeps loss curves non-degenerate for the
  examples without shipping a corpus).
* ``MemmapDataset`` — flat uint32 token shards (``shard_*.bin``) read as
  rolling windows; an epoch-scoped RNG permutes window order so a restart
  at (epoch, index) reproduces the exact stream — checkpointable data
  state = 2 ints, the property that matters for fault tolerance.
* ``Prefetcher`` — N-deep background thread so host batch assembly overlaps
  device compute.
"""

from __future__ import annotations

import queue
import threading
from pathlib import Path
from typing import Any, Iterator

import numpy as np

__all__ = ["SyntheticLM", "MemmapDataset", "Prefetcher", "write_corpus"]


class SyntheticLM:
    """Deterministic synthetic LM batches: {tokens, labels} int32 arrays."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed

    def batch_at(self, index: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, index))
        # Zipf-ish marginal + local repetition structure learnable by an LM.
        base = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        tokens = (base % (self.vocab_size - 2)) + 1
        # inject copy structure: every 16th position repeats 8 back
        tokens[:, 16::16] = tokens[:, 8:-8:16][:, : tokens[:, 16::16].shape[1]]
        tokens = tokens.astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


def write_corpus(path: str | Path, n_tokens: int, vocab_size: int, seed: int = 0,
                 shard_tokens: int = 1 << 20) -> list[Path]:
    """Write a synthetic corpus as uint32 memmap shards (for the examples)."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(seed)
    out = []
    written = 0
    shard = 0
    while written < n_tokens:
        n = min(shard_tokens, n_tokens - written)
        arr = (rng.zipf(1.3, size=n) % (vocab_size - 2) + 1).astype(np.uint32)
        p = path / f"shard_{shard:05d}.bin"
        arr.tofile(p)
        out.append(p)
        written += n
        shard += 1
    return out


class MemmapDataset:
    """Rolling windows over uint32 token shards, deterministic shuffle.

    State = (epoch, index); ``state()``/``seek()`` make it checkpointable.
    """

    def __init__(self, path: str | Path, seq_len: int, batch: int, seed: int = 0):
        self.paths = sorted(Path(path).glob("shard_*.bin"))
        if not self.paths:
            raise FileNotFoundError(f"no shard_*.bin under {path}")
        self.maps = [np.memmap(p, dtype=np.uint32, mode="r") for p in self.paths]
        self.total = sum(m.shape[0] for m in self.maps)
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.n_windows = self.total // (seq_len + 1)
        self.epoch = 0
        self.index = 0
        self._flat_starts = np.cumsum([0] + [m.shape[0] for m in self.maps])

    def state(self) -> dict:
        return {"epoch": self.epoch, "index": self.index}

    def seek(self, state: dict) -> None:
        self.epoch = int(state["epoch"])
        self.index = int(state["index"])

    def _window(self, w: int) -> np.ndarray:
        start = w * (self.seq_len + 1)
        shard = int(np.searchsorted(self._flat_starts, start, "right") - 1)
        off = start - self._flat_starts[shard]
        need = self.seq_len + 1
        chunks = []
        while need > 0:
            m = self.maps[shard]
            take = min(need, m.shape[0] - off)
            chunks.append(np.asarray(m[off : off + take]))
            need -= take
            shard = (shard + 1) % len(self.maps)
            off = 0
        return np.concatenate(chunks)

    def next_batch(self) -> dict[str, np.ndarray]:
        perm_rng = np.random.default_rng((self.seed, self.epoch))
        perm = perm_rng.permutation(self.n_windows)
        toks = []
        for _ in range(self.batch):
            if self.index >= self.n_windows:
                self.epoch += 1
                self.index = 0
                perm_rng = np.random.default_rng((self.seed, self.epoch))
                perm = perm_rng.permutation(self.n_windows)
            toks.append(self._window(int(perm[self.index])))
            self.index += 1
        arr = np.stack(toks).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self):
        while True:
            yield self.next_batch()


class Prefetcher:
    """Background prefetch of an iterator, depth-bounded."""

    _SENTINEL = object()

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Exception | None = None

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except Exception as e:
                self._err = e
            finally:
                self._q.put(self._SENTINEL)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err:
                raise self._err
            raise StopIteration
        return item
