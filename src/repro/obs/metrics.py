"""Counter / gauge / histogram registry for new observability series.

The executor's paper-facing metric arrays (throughput, queue, drops —
everything hashed by ``RuntimeResult.fingerprint``) stay exactly where
they are; this registry exists for *additional* series introduced by the
observability layer: per-component throughput totals, guard-evaluation
counts, arbiter grants/denials, queue high-water marks.  All state is
plain Python numbers updated in deterministic program order, so
``snapshot()`` output is reproducible across reruns.
"""

from __future__ import annotations

import bisect
from typing import Any

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_METRICS",
]


class Counter:
    """Monotonically increasing sum."""

    kind = "counter"

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: float = 0.0
        self.count: int = 0

    def add(self, amount: float = 1.0) -> None:
        self.value += amount
        self.count += 1

    def to_record(self) -> dict[str, Any]:
        return {"name": self.name, "kind": self.kind, "value": self.value, "count": self.count}


class Gauge:
    """Last-set value with a high-water mark."""

    kind = "gauge"

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: float = 0.0
        self.hwm: float = float("-inf")
        self.count: int = 0

    def set(self, value: float) -> None:
        self.value = value
        if value > self.hwm:
            self.hwm = value
        self.count += 1

    def to_record(self) -> dict[str, Any]:
        hwm = self.hwm if self.count else 0.0
        return {"name": self.name, "kind": self.kind, "value": self.value, "hwm": hwm, "count": self.count}


class Histogram:
    """Fixed-bucket histogram with overflow bucket and running sum."""

    kind = "histogram"

    def __init__(self, name: str, edges: tuple[float, ...]) -> None:
        self.name = name
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.total: float = 0.0
        self.count: int = 0

    def record(self, value: float) -> None:
        self.counts[bisect.bisect_right(self.edges, value)] += 1
        self.total += value
        self.count += 1

    def to_record(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "kind": self.kind,
            "edges": list(self.edges),
            "counts": list(self.counts),
            "total": self.total,
            "count": self.count,
        }


_DEFAULT_EDGES = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0)


class MetricsRegistry:
    """Insertion-ordered registry; get-or-create accessors per kind."""

    enabled = True

    def __init__(self) -> None:
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def counter(self, name: str) -> Counter:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Counter(name)
        elif not isinstance(m, Counter):
            raise TypeError(f"metric {name!r} already registered as {m.kind}")
        return m

    def gauge(self, name: str) -> Gauge:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Gauge(name)
        elif not isinstance(m, Gauge):
            raise TypeError(f"metric {name!r} already registered as {m.kind}")
        return m

    def histogram(self, name: str, edges: tuple[float, ...] = _DEFAULT_EDGES) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(name, edges)
        elif not isinstance(m, Histogram):
            raise TypeError(f"metric {name!r} already registered as {m.kind}")
        return m

    def __len__(self) -> int:
        return len(self._metrics)

    def __iter__(self):
        return iter(self._metrics.values())

    def snapshot(self) -> list[dict[str, Any]]:
        """All metrics as json-safe records, in registration order."""
        return [m.to_record() for m in self._metrics.values()]


class _NullMetric:
    def add(self, amount: float = 1.0) -> None:
        return None

    def set(self, value: float) -> None:
        return None

    def record(self, value: float) -> None:
        return None


_NULL_METRIC = _NullMetric()


class _NullMetricsRegistry:
    """No-op registry used by ``NullRecorder``."""

    enabled = False

    def counter(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, name: str, edges: tuple[float, ...] = _DEFAULT_EDGES) -> _NullMetric:
        return _NULL_METRIC

    def __len__(self) -> int:
        return 0

    def __iter__(self):
        return iter(())

    def snapshot(self) -> list[dict[str, Any]]:
        return []


NULL_METRICS = _NullMetricsRegistry()
