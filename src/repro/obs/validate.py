"""Schema smoke-check for observability exports.

Usage::

    python -m repro.obs.validate trace.jsonl runtime.trace.json ...

Validates JSONL record streams (``to_jsonl``) and Chrome trace-event
files (``to_chrome_trace``).  Exit status 0 when every file passes, 1 on
the first malformed record — CI runs this over the bench artifacts so a
schema regression fails the build instead of producing unloadable
traces.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

__all__ = ["validate_file", "validate_jsonl", "validate_chrome", "main"]

_RECORD_TYPES = frozenset({"meta", "span", "event", "dispatch", "decision", "metric"})
_TIMED_TYPES = frozenset({"span", "event", "dispatch", "decision"})
_METRIC_KINDS = frozenset({"counter", "gauge", "histogram"})
_PHASES = frozenset({"X", "i", "I", "M", "B", "E", "C"})


def validate_jsonl(text: str) -> tuple[int, list[str]]:
    """Check a JSONL export; returns (record count, error list)."""
    errors: list[str] = []
    n = 0
    last_ts = 0
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError as exc:
            errors.append(f"line {lineno}: invalid JSON ({exc})")
            continue
        n += 1
        if not isinstance(rec, dict):
            errors.append(f"line {lineno}: record is not an object")
            continue
        rtype = rec.get("type")
        if rtype not in _RECORD_TYPES:
            errors.append(f"line {lineno}: unknown record type {rtype!r}")
            continue
        if rtype == "meta":
            if not isinstance(rec.get("name"), str):
                errors.append(f"line {lineno}: meta record missing 'name'")
            continue
        if rtype == "metric":
            if not isinstance(rec.get("name"), str):
                errors.append(f"line {lineno}: metric missing 'name'")
            if rec.get("kind") not in _METRIC_KINDS:
                errors.append(f"line {lineno}: metric kind {rec.get('kind')!r} unknown")
            continue
        # span / event / dispatch / decision
        if not isinstance(rec.get("name"), str):
            errors.append(f"line {lineno}: {rtype} missing 'name'")
        if not isinstance(rec.get("cat"), str):
            errors.append(f"line {lineno}: {rtype} missing 'cat'")
        if not isinstance(rec.get("window"), int):
            errors.append(f"line {lineno}: {rtype} missing integer 'window'")
        ts = rec.get("ts")
        if not isinstance(ts, int) or ts <= 0:
            errors.append(f"line {lineno}: {rtype} missing positive integer 'ts'")
        elif ts <= last_ts:
            errors.append(
                f"line {lineno}: virtual clock not monotone (ts={ts} after {last_ts})"
            )
        else:
            last_ts = ts
        if rtype == "span":
            dur = rec.get("dur")
            if not isinstance(dur, int) or dur < 1:
                errors.append(f"line {lineno}: span missing positive integer 'dur'")
    if n == 0:
        errors.append("empty file: no records")
    return n, errors


def validate_chrome(obj: object) -> tuple[int, list[str]]:
    """Check a Chrome trace-event dict; returns (event count, error list)."""
    errors: list[str] = []
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"), list):
        return 0, ["not a trace-event file: missing 'traceEvents' list"]
    events = obj["traceEvents"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing 'name'")
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            errors.append(f"{where}: missing integer pid/tid")
        if ph == "M":
            continue  # metadata events carry no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: missing non-negative 'ts'")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: complete event missing non-negative 'dur'")
    if not events:
        errors.append("empty trace: no events")
    return len(events), errors


def validate_file(path: "str | Path") -> tuple[int, list[str]]:
    """Validate one export file; format chosen by content sniffing."""
    p = Path(path)
    try:
        text = p.read_text()
    except OSError as exc:
        return 0, [f"cannot read {p}: {exc}"]
    stripped = text.lstrip()
    if stripped.startswith("{") and '"traceEvents"' in stripped[:200]:
        try:
            obj = json.loads(text)
        except ValueError as exc:
            return 0, [f"invalid JSON: {exc}"]
        return validate_chrome(obj)
    return validate_jsonl(text)


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate <trace.jsonl|trace.json> ...")
        return 2
    status = 0
    for path in argv:
        n, errors = validate_file(path)
        if errors:
            status = 1
            print(f"FAIL {path}: {len(errors)} error(s) in {n} record(s)")
            for err in errors[:20]:
                print(f"  {err}")
            if len(errors) > 20:
                print(f"  ... {len(errors) - 20} more")
        else:
            print(f"OK   {path}: {n} record(s)")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
