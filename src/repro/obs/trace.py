"""Deterministic trace recording on a virtual clock.

The recorder is the backbone of the observability layer: every span,
point event, dispatch decision and replan decision is appended to a
single ordered record list.  Ordering is given by a *virtual clock* —
a monotonically increasing integer tick bumped once per record plus the
current window index — so two runs of the same deterministic program
produce byte-identical exports.  Wall-clock timings are opt-in
(``wall_clock=True``) and are carried in dedicated ``wall_*`` fields so
exporters can strip them for reproducibility checks.

Design constraints:

* recording must never perturb the computation it observes — the
  recorder only appends to Python lists and bumps counters, and the
  ``NullRecorder`` default makes every hook a no-op attribute access;
* the closed-form dispatch hook (:func:`record_dispatch`) is called
  from ``repro.core.simulator`` on *every* backend resolution, so the
  inactive path is a single module-global ``None`` check;
* this module imports only the standard library (and sibling
  ``repro.obs`` modules), so it can be imported from anywhere in
  ``repro`` without cycles.
"""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass
from typing import Any, Iterator

from repro.obs.metrics import MetricsRegistry, NULL_METRICS

__all__ = [
    "DispatchDecision",
    "NullRecorder",
    "NULL_RECORDER",
    "TraceRecorder",
    "active_recorder",
    "record_dispatch",
]


@dataclass(frozen=True)
class DispatchDecision:
    """One closed-form backend resolution (``resolve_closed_form_backend``)."""

    requested: str
    backend: str
    regime: str
    elements: int | None
    n_machines: int | None
    site: str | None
    window: int

    def to_record(self) -> dict[str, Any]:
        return {
            "requested": self.requested,
            "backend": self.backend,
            "regime": self.regime,
            "elements": self.elements,
            "n_machines": self.n_machines,
            "site": self.site,
        }


class _Span:
    """Lightweight span context manager (cheaper than a generator CM).

    The record is emitted at ``__enter__`` (so record order equals
    program order even for nested spans) and its ``dur`` — in virtual
    ticks — is filled in at ``__exit__``.  The object returned by
    ``__enter__`` is the record dict, which the caller may mutate to
    attach result arguments discovered during the span.
    """

    __slots__ = ("_recorder", "_name", "_cat", "_args", "_rec", "_w0")

    def __init__(self, recorder: "TraceRecorder", name: str, cat: str,
                 args: dict[str, Any]) -> None:
        self._recorder = recorder
        self._name = name
        self._cat = cat
        self._args = args
        self._rec: dict[str, Any] | None = None
        self._w0 = 0.0

    def __enter__(self) -> dict[str, Any]:
        rec = self._recorder._record("span", self._name, self._cat, self._args)
        self._rec = rec
        if self._recorder.wall_clock:
            self._w0 = time.perf_counter()
        return rec

    def __exit__(self, *exc: Any) -> None:
        recorder = self._recorder
        rec = self._rec
        recorder._tick += 1
        rec["dur"] = recorder._tick - rec["ts"]
        if recorder.wall_clock:
            rec["wall_dur_s"] = time.perf_counter() - self._w0
        return None


class TraceRecorder:
    """Collects spans, events and decisions on a deterministic virtual clock.

    Parameters
    ----------
    name:
        Label for the run; becomes the process name in Chrome traces and
        the ``meta`` header of JSONL exports.
    wall_clock:
        When ``True``, spans and events additionally carry
        ``wall_s`` / ``wall_dur_s`` fields from ``time.perf_counter``.
        These fields are *never* part of the virtual clock and exporters
        can strip them (``strip_wall=True``) for byte-identical reruns.
    """

    enabled = True

    def __init__(self, name: str = "run", wall_clock: bool = False) -> None:
        self.name = name
        self.wall_clock = wall_clock
        self.records: list[dict[str, Any]] = []
        self.metrics = MetricsRegistry()
        self._tick = 0
        self._window = -1
        self._wall0 = time.perf_counter()
        self._dispatch_counters: dict[tuple[str, str], Any] = {}
        self._dispatch_rows: list[tuple] = []
        self._dispatch_cache: list[DispatchDecision] = []

    # ---------------------------------------------------------------- clock

    @property
    def tick(self) -> int:
        return self._tick

    @property
    def window(self) -> int:
        return self._window

    def set_window(self, window: int) -> None:
        """Advance the virtual clock to a new window index."""
        self._window = int(window)

    # -------------------------------------------------------------- records

    def _record(
        self,
        rtype: str,
        name: str,
        cat: str,
        args: dict[str, Any] | None,
    ) -> dict[str, Any]:
        self._tick += 1
        rec: dict[str, Any] = {
            "type": rtype,
            "name": name,
            "cat": cat,
            "window": self._window,
            "ts": self._tick,
        }
        if args:
            rec["args"] = args
        if self.wall_clock:
            rec["wall_s"] = time.perf_counter() - self._wall0
        self.records.append(rec)
        return rec

    def event(self, name: str, cat: str = "event", **args: Any) -> dict[str, Any]:
        """Record an instantaneous point event."""
        return self._record("event", name, cat, args or None)

    def span(self, name: str, cat: str = "span", **args: Any) -> _Span:
        """Record a nestable span (see :class:`_Span` for semantics)."""
        return _Span(self, name, cat, args)

    def dispatch(
        self,
        requested: str,
        backend: str,
        regime: str,
        elements: int | None,
        n_machines: int | None,
        site: str | None,
    ) -> None:
        """Record one closed-form backend resolution.

        Hot path — called once per scoring sweep during refine.  The
        trace record is a direct dict literal, the per-route counter is
        cached by ``(regime, backend)``, and the :class:`DispatchDecision`
        objects are materialized lazily by the :attr:`dispatch_log`
        property, so the per-call cost is two appends and a counter bump.
        """
        tick = self._tick + 1
        self._tick = tick
        window = self._window
        self._dispatch_rows.append(
            (requested, backend, regime, elements, n_machines, site, window)
        )
        rec: dict[str, Any] = {
            "type": "dispatch",
            "name": "closed_form_dispatch",
            "cat": "dispatch",
            "window": window,
            "ts": tick,
            "args": {
                "requested": requested,
                "backend": backend,
                "regime": regime,
                "elements": None if elements is None else int(elements),
                "n_machines": None if n_machines is None else int(n_machines),
                "site": site,
            },
        }
        if self.wall_clock:
            rec["wall_s"] = time.perf_counter() - self._wall0
        self.records.append(rec)
        ctr = self._dispatch_counters.get((regime, backend))
        if ctr is None:
            ctr = self.metrics.counter(f"dispatch.{regime}.{backend}")
            self._dispatch_counters[(regime, backend)] = ctr
        ctr.add(1)

    @property
    def dispatch_log(self) -> list[DispatchDecision]:
        """All backend resolutions seen so far, as :class:`DispatchDecision`.

        Materialized lazily from the compact rows the hot path appends;
        repeated access only converts rows added since the last call.
        """
        rows = self._dispatch_rows
        cache = self._dispatch_cache
        if len(cache) != len(rows):
            for req, backend, regime, elements, n_machines, site, window in rows[
                len(cache):
            ]:
                cache.append(
                    DispatchDecision(
                        requested=str(req),
                        backend=str(backend),
                        regime=str(regime),
                        elements=None if elements is None else int(elements),
                        n_machines=None if n_machines is None else int(n_machines),
                        site=site,
                        window=window,
                    )
                )
        return cache

    def decision(self, dec: Any) -> None:
        """Record a structured replan decision (``repro.obs.ledger.ReplanDecision``)."""
        self._record("decision", f"replan:{dec.outcome}", "decision", dec.to_record())

    # ------------------------------------------------------------ activation

    def activate(self) -> contextlib.AbstractContextManager["TraceRecorder"]:
        """Install this recorder as the process-wide active recorder.

        The active recorder is the target of :func:`record_dispatch`,
        which instruments code (the closed-form backend resolver) too far
        from the call site to thread a recorder argument through.
        Activation nests: the previous active recorder is restored on
        exit.
        """
        return _activate(self)


@contextlib.contextmanager
def _activate(rec: TraceRecorder) -> Iterator[TraceRecorder]:
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = rec
    try:
        yield rec
    finally:
        _ACTIVE = prev


_ACTIVE: TraceRecorder | None = None


def active_recorder() -> TraceRecorder | None:
    """The currently activated :class:`TraceRecorder`, or ``None``."""
    return _ACTIVE


def record_dispatch(
    requested: str,
    backend: str,
    regime: str,
    elements: int | None,
    n_machines: int | None,
    site: str | None = None,
) -> None:
    """Dispatch-decision hook called by ``resolve_closed_form_backend``.

    A single global read when no recorder is active, so the instrumented
    resolver costs nothing in normal operation.
    """
    rec = _ACTIVE
    if rec is None:
        return
    rec.dispatch(requested, backend, regime, elements, n_machines, site)


class _NullContext:
    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: Any) -> None:
        return None


_NULL_CTX = _NullContext()


class NullRecorder:
    """Zero-overhead recorder: every hook is a no-op.

    Shared singleton :data:`NULL_RECORDER` is the default everywhere a
    recorder is accepted, so un-instrumented runs pay only ``enabled``
    attribute checks.
    """

    enabled = False
    wall_clock = False
    name = "null"
    records: list[dict[str, Any]] = []
    dispatch_log: list[DispatchDecision] = []
    metrics = NULL_METRICS
    tick = 0
    window = -1

    def set_window(self, window: int) -> None:
        return None

    def event(self, name: str, cat: str = "event", **args: Any) -> None:
        return None

    def span(self, name: str, cat: str = "span", **args: Any) -> _NullContext:
        return _NULL_CTX

    def dispatch(self, *args: Any, **kwargs: Any) -> None:
        return None

    def decision(self, dec: Any) -> None:
        return None

    def activate(self) -> _NullContext:
        return _NULL_CTX


NULL_RECORDER = NullRecorder()
