"""Deterministic observability: tracing, metrics, audit ledger, exporters.

The layer answers "why did the runtime do that?" without perturbing what
it observes:

* ``trace``   — ``TraceRecorder``: nestable spans + point events on a
                virtual clock (window index + integer tick); wall-clock
                opt-in and strippable; ``NullRecorder`` zero-overhead
                default; process-wide activation feeds the closed-form
                dispatch hook;
* ``metrics`` — counter / gauge / histogram registry for new series
                (per-component throughput, guard evals, arbiter
                grants/denials, queue high-water marks);
* ``ledger``  — ``ReplanDecision``: every controller verdict with the
                full two-sided guard breakdown; the legacy string log is
                a derived view;
* ``export``  — JSONL + Chrome trace-event (Perfetto) + text summary;
* ``validate``— ``python -m repro.obs.validate`` schema smoke gate.

See docs/architecture.md (Observability) and docs/api.md.
"""

from repro.obs.export import summary, to_chrome_trace, to_jsonl
from repro.obs.ledger import ReplanDecision, ReplanLedger
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import (
    NULL_RECORDER,
    DispatchDecision,
    NullRecorder,
    TraceRecorder,
    active_recorder,
    record_dispatch,
)

__all__ = [
    "TraceRecorder",
    "NullRecorder",
    "NULL_RECORDER",
    "DispatchDecision",
    "active_recorder",
    "record_dispatch",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "ReplanDecision",
    "ReplanLedger",
    "to_jsonl",
    "to_chrome_trace",
    "summary",
]
