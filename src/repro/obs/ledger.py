"""Structured replan audit ledger.

Every consult of ``OnlineController.update`` that reaches a decision
point produces one :class:`ReplanDecision` carrying the *full* two-sided
guard breakdown — the demand-capped gain, the pause debit, the
move/state cost split, the budget verdict and the candidate move list —
instead of a pre-formatted string.  The legacy ``(window, str)`` log the
tests and benchmarks grew up with is a *derived view*
(:meth:`ReplanDecision.legacy_entry` / :meth:`ReplanLedger.legacy_view`)
so the structured record is the source of truth.

Outcomes:

``no_move``
    Drift fired but ``refine`` returned the incumbent placement (the
    guard never ran; guard fields stay at their defaults).
``budget``
    Transfer cost exceeded ``elastic_budget`` — rejected before the
    benefit comparison.
``skip``
    Guard ran and the demand-capped, pause-debited benefit did not clear
    the transfer cost.
``replan``
    Accepted: the plan is handed to the executor.
``deferred``
    Accepted by the controller but denied by the multi-tenant
    ``ReplanArbiter`` (its per-period move budget was exhausted).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

__all__ = ["ReplanDecision", "ReplanLedger"]

_GUARD_OUTCOMES = frozenset({"budget", "skip", "replan", "deferred"})


def _json_safe(x: float) -> float | str:
    """Floats for JSON: non-finite values become strings ("inf", "nan")."""
    return x if math.isfinite(x) else str(x)


@dataclasses.dataclass(frozen=True)
class ReplanDecision:
    """One controller decision with its full guard breakdown."""

    window: int
    trigger: str                 # drift reason: scale_out/capacity/drain/...
    outcome: str                 # no_move | budget | skip | replan | deferred
    moves: int = 0               # instances that would restart
    state_shipped: float = 0.0   # keyed-state tuples the transfer ships
    gain_rate: float = 0.0       # demand-capped throughput delta (tuples/s)
    benefit: float = 0.0         # gain integrated over horizon − pause_loss
    pause_loss: float = 0.0      # service forgone during migration pauses
    move_cost: float = 0.0       # moves × migration_cost
    state_cost: float = 0.0      # state_shipped × state_cost
    cost: float = 0.0            # move_cost + state_cost
    budget: float = float("inf")  # elastic_budget in force
    demand: float = 0.0          # offered demand cap (tuples/s)
    current_throughput: float = 0.0
    plan_throughput: float = 0.0
    plan_rate: float = 0.0
    horizon_windows: int = 0
    candidate_moves: tuple[str, ...] = ()  # refine's applied move descriptors

    @property
    def accepted(self) -> bool:
        return self.outcome == "replan"

    @property
    def has_guard_breakdown(self) -> bool:
        """True when the two-sided guard actually ran for this decision."""
        return self.outcome in _GUARD_OUTCOMES

    @property
    def message(self) -> str:
        """The legacy log string for this decision (format-compatible)."""
        if self.outcome == "no_move":
            return f"{self.trigger}:no_move"
        if self.outcome == "budget":
            return (
                f"{self.trigger}:budget cost={self.cost:.0f} moves={self.moves} "
                f"state={self.state_shipped:.0f}"
            )
        if self.outcome == "deferred":
            return "deferred:arbiter"
        # skip / replan share the gain-formatted tail.
        return (
            f"{self.trigger}:{self.outcome} gain={self.gain_rate:.2f}/s "
            f"moves={self.moves} state={self.state_shipped:.0f}"
        )

    def legacy_entry(self) -> tuple:
        """The tuple the old ``OnlineController.log`` list carried."""
        if self.outcome == "deferred":
            # The arbiter's historical in-band marker was a 3-tuple.
            return (self.window, "deferred:arbiter", float(self.moves))
        return (self.window, self.message)

    def to_record(self) -> dict[str, Any]:
        """JSON-safe dict for exporters (non-finite floats stringified)."""
        return {
            "window": self.window,
            "trigger": self.trigger,
            "outcome": self.outcome,
            "moves": self.moves,
            "state_shipped": _json_safe(self.state_shipped),
            "gain_rate": _json_safe(self.gain_rate),
            "benefit": _json_safe(self.benefit),
            "pause_loss": _json_safe(self.pause_loss),
            "move_cost": _json_safe(self.move_cost),
            "state_cost": _json_safe(self.state_cost),
            "cost": _json_safe(self.cost),
            "budget": _json_safe(self.budget),
            "demand": _json_safe(self.demand),
            "current_throughput": _json_safe(self.current_throughput),
            "plan_throughput": _json_safe(self.plan_throughput),
            "plan_rate": _json_safe(self.plan_rate),
            "horizon_windows": self.horizon_windows,
            "candidate_moves": list(self.candidate_moves),
        }


class ReplanLedger(list):
    """Ordered list of :class:`ReplanDecision` with derived views."""

    @property
    def accepted(self) -> list[ReplanDecision]:
        return [d for d in self if d.outcome == "replan"]

    @property
    def rejected(self) -> list[ReplanDecision]:
        return [d for d in self if d.outcome != "replan"]

    def legacy_view(self) -> list[tuple]:
        """The old ``OnlineController.log`` contents, tuple for tuple."""
        return [d.legacy_entry() for d in self]

    def to_records(self) -> list[dict[str, Any]]:
        return [d.to_record() for d in self]
