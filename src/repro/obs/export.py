"""Exporters: JSONL, Chrome trace-event JSON (Perfetto), text summary.

All exporters serialize with ``sort_keys=True`` and compact separators,
so a deterministic recorder produces *byte-identical* output across
reruns once wall-clock fields are stripped (``strip_wall=True``) or the
recorder ran with ``wall_clock=False``.
"""

from __future__ import annotations

import json
from collections import Counter as _TallyCounter
from pathlib import Path
from typing import Any

from repro.obs.trace import NullRecorder, TraceRecorder

__all__ = ["to_jsonl", "to_chrome_trace", "summary"]

_WALL_KEYS = ("wall_s", "wall_dur_s")


def _coerce(obj: Any) -> Any:
    """json.dumps fallback for numpy scalars / arrays that leaked into args."""
    if hasattr(obj, "item"):
        return obj.item()
    if hasattr(obj, "tolist"):
        return obj.tolist()
    return str(obj)


def _dumps(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"), default=_coerce)


def to_jsonl(
    recorder: "TraceRecorder | NullRecorder",
    path: "str | Path | None" = None,
    strip_wall: bool = False,
) -> str:
    """Serialize a recorder to JSONL: meta header, records, metric snapshot.

    One JSON object per line.  ``strip_wall=True`` drops the opt-in
    ``wall_s`` / ``wall_dur_s`` fields so recorder-on reruns compare
    byte-for-byte.
    """
    lines = [
        _dumps(
            {
                "type": "meta",
                "name": recorder.name,
                "wall_clock": bool(recorder.wall_clock) and not strip_wall,
                "records": len(recorder.records),
            }
        )
    ]
    for rec in recorder.records:
        if strip_wall and any(k in rec for k in _WALL_KEYS):
            rec = {k: v for k, v in rec.items() if k not in _WALL_KEYS}
        lines.append(_dumps(rec))
    for m in recorder.metrics.snapshot():
        lines.append(_dumps({"type": "metric", **m}))
    text = "\n".join(lines) + "\n"
    if path is not None:
        Path(path).write_text(text)
    return text


def to_chrome_trace(
    recorder: "TraceRecorder | NullRecorder",
    path: "str | Path | None" = None,
) -> dict[str, Any]:
    """Serialize to the Chrome trace-event format (Perfetto-loadable).

    Virtual-clock ticks map to the format's microsecond ``ts`` axis, one
    thread per record category.  Spans become complete ("X") events,
    point events / dispatch decisions / replan decisions become instants
    ("i"); the metric snapshot lands as instants on a trailing
    ``metrics`` thread.
    """
    events: list[dict[str, Any]] = []
    tids: dict[str, int] = {}

    def tid_for(cat: str) -> int:
        tid = tids.get(cat)
        if tid is None:
            tid = tids[cat] = len(tids)
        return tid

    events.append(
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0, "args": {"name": recorder.name}}
    )
    body: list[dict[str, Any]] = []
    for rec in recorder.records:
        cat = rec["cat"]
        args = dict(rec.get("args") or {})
        args["window"] = rec["window"]
        if "wall_dur_s" in rec:
            args["wall_dur_s"] = rec["wall_dur_s"]
        ev: dict[str, Any] = {
            "name": rec["name"],
            "cat": cat,
            "ts": rec["ts"],
            "pid": 0,
            "tid": tid_for(cat),
            "args": args,
        }
        if rec["type"] == "span":
            ev["ph"] = "X"
            ev["dur"] = max(int(rec.get("dur", 1)), 1)
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        body.append(ev)
    last_ts = recorder.records[-1]["ts"] if recorder.records else 0
    for i, m in enumerate(recorder.metrics.snapshot()):
        body.append(
            {
                "name": m["name"],
                "cat": "metrics",
                "ph": "i",
                "s": "t",
                "ts": last_ts + 1 + i,
                "pid": 0,
                "tid": tid_for("metrics"),
                "args": m,
            }
        )
    for cat, tid in tids.items():
        events.append(
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid, "args": {"name": cat}}
        )
    events.extend(body)
    trace = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        Path(path).write_text(_dumps(trace) + "\n")
    return trace


def summary(recorder: "TraceRecorder | NullRecorder") -> str:
    """Plain-text roll-up: spans, events, dispatch routing, metrics."""
    lines = [f"trace '{recorder.name}': {len(recorder.records)} records, tick={recorder.tick}"]

    span_count: _TallyCounter = _TallyCounter()
    span_ticks: _TallyCounter = _TallyCounter()
    span_wall: dict[str, float] = {}
    event_count: _TallyCounter = _TallyCounter()
    decision_count: _TallyCounter = _TallyCounter()
    for rec in recorder.records:
        if rec["type"] == "span":
            span_count[rec["name"]] += 1
            span_ticks[rec["name"]] += rec.get("dur", 0)
            if "wall_dur_s" in rec:
                span_wall[rec["name"]] = span_wall.get(rec["name"], 0.0) + rec["wall_dur_s"]
        elif rec["type"] == "event":
            event_count[rec["name"]] += 1
        elif rec["type"] == "decision":
            decision_count[rec["name"]] += 1
    if span_count:
        lines.append("spans:")
        for name, n in span_count.most_common():
            wall = f"  wall={span_wall[name]:.4f}s" if name in span_wall else ""
            lines.append(f"  {name:<28} n={n:<5} ticks={span_ticks[name]}{wall}")
    if event_count:
        lines.append("events:")
        for name, n in event_count.most_common():
            lines.append(f"  {name:<28} n={n}")
    if decision_count:
        lines.append("replan decisions:")
        for name, n in sorted(decision_count.items()):
            lines.append(f"  {name:<28} n={n}")
    if recorder.dispatch_log:
        routes: _TallyCounter = _TallyCounter()
        for d in recorder.dispatch_log:
            routes[(d.site or "?", d.regime, d.backend)] += 1
        lines.append("closed-form dispatch:")
        for (site, regime, backend), n in sorted(routes.items()):
            lines.append(f"  {site:<24} {regime:<8} -> {backend:<6} n={n}")
    metrics = recorder.metrics.snapshot()
    if metrics:
        lines.append("metrics:")
        for m in metrics:
            if m["kind"] == "gauge":
                lines.append(f"  {m['name']:<36} gauge last={m['value']:.4g} hwm={m['hwm']:.4g}")
            elif m["kind"] == "histogram":
                lines.append(f"  {m['name']:<36} hist  n={m['count']} total={m['total']:.4g}")
            else:
                lines.append(f"  {m['name']:<36} count value={m['value']:.6g}")
    return "\n".join(lines)
