"""Fig. 7 — execution-graph (instance count) selection quality on the
Storm-Benchmark two-bolt topologies (RollingCount, UniqueVisitor).

Sweep all <x, y> instance pairs, score each pair's best achievable
throughput (optimal placement at those counts), and check the pair the
proposed algorithm picks. Paper: RollingCount hits the optimal <5,4>
exactly; UniqueVisitor picks <4,5> vs optimal <5,5>, costing 2 %.
"""

from __future__ import annotations

import itertools

import numpy as np

from benchmarks.common import emit, timeit_us
from repro.core import (
    max_stable_rate,
    max_stable_rate_batch,
    paper_cluster,
    rolling_count_topology,
    schedule,
    unique_visitor_topology,
)
from repro.core.graph import ExecutionGraph
from repro.core.optimal import _compositions  # composition enumerator
from repro.core.refine import refine


def best_at_counts(topo, cluster, counts) -> float:
    """Best achievable throughput with fixed instance counts (opt placement)."""
    n_inst = np.asarray(counts, dtype=np.int64)
    template = ExecutionGraph(
        utg=topo,
        n_instances=n_inst,
        assignment=[np.zeros(int(k), dtype=np.int64) for k in n_inst],
    )
    m = cluster.n_machines
    per_comp = [list(_compositions(int(k), m)) for k in n_inst]
    best = 0.0
    batch = []
    for combo in itertools.product(*per_comp):
        flat = np.concatenate(
            [np.repeat(np.arange(m), c) for c in combo]
        )
        batch.append(flat)
    tm = np.stack(batch)
    _, thpt = max_stable_rate_batch(template, cluster, tm)
    return float(thpt.max())


def run(topo_fn, max_per_bolt: int = 6) -> dict:
    cluster = paper_cluster((1, 1, 1))
    topo = topo_fn()
    sweep = {}
    for x in range(1, max_per_bolt + 1):
        for y in range(1, max_per_bolt + 1):
            sweep[(x, y)] = best_at_counts(topo, cluster, [1, x, y])
    best_pair = max(sweep, key=sweep.get)

    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    ref = refine(sched.etg, cluster)
    ours_pair = (int(ref.etg.n_instances[1]), int(ref.etg.n_instances[2]))
    ours_thpt = sweep.get(ours_pair, ref.throughput)
    return {
        "topology": topo.name,
        "optimal_pair": best_pair,
        "optimal_thpt": sweep[best_pair],
        "ours_pair": ours_pair,
        "ours_thpt": ours_thpt,
        "loss_pct": (1 - ours_thpt / sweep[best_pair]) * 100,
    }


def main() -> None:
    for topo_fn in (rolling_count_topology, unique_visitor_topology):
        us = timeit_us(lambda f=topo_fn: run(f), iters=1, warmup=0)
        r = run(topo_fn)
        emit(
            f"fig7_instances_{r['topology']}",
            us,
            f"ours={r['ours_pair']};optimal={r['optimal_pair']};"
            f"loss={r['loss_pct']:.1f}%(paper<=2%)",
        )


if __name__ == "__main__":
    main()
