"""Fig. 10 + Tables 4/5 — large-scale simulation: proposed vs default
scheduler on three cluster scenarios (small 2/2/2, medium 10/10/10, large
20/70/90 machines per type).

As in the paper (§6.3), the proposed algorithm first determines the
instance counts; both schedulers then place the *same* counts, isolating
placement quality. Reported per scenario x topology: throughput gain,
weighted-utilization gain (eq. 7/8), and the Table-5 gain ratio
diff_thpt / diff_util (> 1 = the scheduler converts utilization into
throughput more efficiently than round-robin).

Paper bands: small +26-49 %, medium +36-48 %, large +27-31 % throughput;
all Table-5 ratios > 1.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    diamond_topology,
    linear_topology,
    max_stable_rate,
    paper_cluster,
    round_robin_schedule,
    schedule,
    simulate_batch,
    star_topology,
    weighted_utilization,
    gain_ratio,
)

SCENARIOS = {
    "small": (2, 2, 2),
    "medium": (10, 10, 10),
    "large": (20, 70, 90),
}


def run(scenario: str, topo_fn) -> dict:
    cluster = paper_cluster(SCENARIOS[scenario])
    topo = topo_fn()
    t0 = time.perf_counter()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0)
    t_sched = time.perf_counter() - t0

    rr = round_robin_schedule(topo, cluster, sched.etg.n_instances)
    rate_o, thpt_o = max_stable_rate(sched.etg, cluster)
    rate_d, thpt_d = max_stable_rate(rr, cluster)
    # Both placements share the instance-count vector (§6.3 fair-comparison
    # protocol), so they score in one batched sweep — each at its own stable
    # rate via the per-row r0 vector; "auto" picks the JAX backend when the
    # batch is big enough to amortize dispatch.
    tm = np.stack([sched.etg.task_machine(), rr.task_machine()])
    both = simulate_batch(
        sched.etg, cluster, tm, np.array([rate_o, rate_d]), backend="auto"
    )
    sim_o = both.row(0)
    sim_d = both.row(1)
    util_o = weighted_utilization(sched.etg, cluster, sim_o)
    util_d = weighted_utilization(rr, cluster, sim_d)
    return {
        "scenario": scenario,
        "topology": topo.name,
        "tasks": int(sched.etg.total_tasks),
        "thpt_gain_pct": (thpt_o / thpt_d - 1) * 100,
        "util_gain_pct": (util_o / util_d - 1) * 100,
        "table5_ratio": gain_ratio(thpt_o, thpt_d, util_o, util_d),
        "t_sched_us": t_sched * 1e6,
        "instances": sched.etg.n_instances.tolist(),
    }


def main() -> None:
    for scenario in SCENARIOS:
        for topo_fn in (linear_topology, diamond_topology, star_topology):
            r = run(scenario, topo_fn)
            emit(
                f"fig10_{scenario}_{r['topology']}",
                r["t_sched_us"],
                f"tasks={r['tasks']};thpt_gain={r['thpt_gain_pct']:.1f}%;"
                f"util_gain={r['util_gain_pct']:.1f}%;"
                f"table5_ratio={r['table5_ratio']:.2f}",
            )


if __name__ == "__main__":
    main()
