"""Online-runtime benchmark (``BENCH_runtime.json``).

Executes three policies against every drift scenario in the streaming
runtime (``repro.runtime_stream``):

* **static** — a schedule provisioned for the scenario's *initial* rate
  (``provision_schedule``, the paper's size-to-observed-load protocol),
  then frozen for the whole trace;
* **online** — the same starting schedule driven by ``OnlineController``
  (windowed drift detection, incremental ``refine``-move replanning, the
  migration cost/benefit guard);
* **oracle** — a full ``schedule()`` re-plan at every window with free
  migrations (``OracleRescheduler`` + ``migration_pause=0``), the
  adaptation upper bound.

The acceptance gates recorded per scenario (ISSUE 4): the online
controller's sustained throughput must be >= the static schedule's and
within 10% of the oracle's, with migration counts reported. The JAX
evaluator's throughput for the static policy is cross-checked against the
Python executor as a parity smoke.

The keyed-skew rows (ISSUE 5, ``keyed_rolling_count``) pit the skew-aware
controller against an even-split-scored static provision on fields-grouped
traces; there the oracle (a full even-split ``schedule()``) is itself
skew-blind, so ``within_10pct_of_oracle`` is informational — the gate on
those rows is ``beats_static``.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core import linear_topology, paper_cluster, schedule
from repro.core.graph import keyed_rolling_count_topology, rolling_count_topology
from repro.core.refine import refine
from repro.runtime_stream import (
    OnlineController,
    OracleRescheduler,
    RuntimeConfig,
    StreamExecutor,
    evaluate_policies_batch,
    provision_schedule,
)
from repro.runtime_stream.traces import (
    TraceSpec,
    burst_trace,
    failure_trace,
    key_skew_shift,
    machine_slowdown,
    ramp_trace,
    rate_ramp,
    sine_trace,
    slowdown_trace,
)

N_WINDOWS = 240
SEED = 0
# One event-loop config for every policy and scenario: a 120-tuple queue
# bound makes sustained overload trip real back-pressure (the default 500
# lets short transients hide entirely inside the queues).
CONFIG = RuntimeConfig(max_queue=120.0)
ORACLE_CONFIG = RuntimeConfig(max_queue=120.0, migration_pause=0)


def _scenarios(topo, cluster) -> list[tuple[TraceSpec, float]]:
    """(trace spec, provisioning rate) per drift scenario.

    Rates are expressed against the cluster's maximum stable rate for the
    topology (schedule+refine), so scenarios scale with cluster shape.
    """
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    r = full.rate
    big = int(np.argmax(cluster.capacity))  # the most capable machine
    return [
        (ramp_trace(0.3 * r, 1.2 * r, n_windows=N_WINDOWS), 0.3 * r),
        (burst_trace(0.5 * r, factor=3.0, n_windows=N_WINDOWS, every=60,
                     width=20, jitter=3), 0.5 * r),
        (sine_trace(0.65 * r, amplitude=0.45, n_windows=N_WINDOWS, period=160),
         0.65 * r),
        (slowdown_trace(0.9 * r, machine=big, factor=0.5, n_windows=N_WINDOWS),
         0.9 * r),
        (failure_trace(0.85 * r, machine=big, n_windows=N_WINDOWS), 0.85 * r),
        (
            TraceSpec(
                name="ramp_slowdown",
                n_windows=N_WINDOWS,
                base_rate=0.4 * r,
                events=(
                    rate_ramp(1.1 * r, start=20, end=120),
                    machine_slowdown(big, 0.6, start=150),
                ),
            ),
            0.4 * r,
        ),
    ]


def _keyed_scenarios(topo, cluster) -> list[tuple[TraceSpec, float]]:
    """Keyed-skew drift rows (ISSUE 5): the static baseline provisions by
    the even-split closed form for the offered rate; the realized key skew
    saturates a hot instance well below that, so only the skew-aware
    online controller sustains the load.

    * ``keyed_hot`` — constant offered load between the skew-aware and the
      even-split stable rate: the static schedule back-pressures from the
      start, the controller replans against the realized shares;
    * ``keyed_shift`` — sustainable start, then ``key_skew_shift`` re-rolls
      the hot keys onto new instances mid-trace (rate and capacity never
      change — drift the even-split signals cannot see).
    """
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    r = full.rate  # even-split closed form — intentionally skew-blind
    return [
        (
            TraceSpec(name="keyed_hot", n_windows=N_WINDOWS, base_rate=0.95 * r),
            0.95 * r,
        ),
        (
            TraceSpec(
                name="keyed_shift",
                n_windows=N_WINDOWS,
                base_rate=0.8 * r,
                events=(key_skew_shift(start=N_WINDOWS // 3, zipf_s=2.0),),
            ),
            0.8 * r,
        ),
    ]


def run_scenario(topo, cluster, spec: TraceSpec, provision_rate: float) -> dict:
    trace = spec.compile(cluster, seed=SEED, utg=topo)
    start_etg = provision_schedule(topo, cluster, provision_rate)

    t0 = time.perf_counter()
    static = StreamExecutor(start_etg, cluster, trace, config=CONFIG).run()
    t_static = time.perf_counter() - t0

    ctl = OnlineController(topo, cluster, period=10)
    t0 = time.perf_counter()
    online = StreamExecutor(start_etg, cluster, trace, config=CONFIG).run(
        controller=ctl
    )
    t_online = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle = StreamExecutor(
        start_etg, cluster, trace, config=ORACLE_CONFIG
    ).run(controller=OracleRescheduler(topo, cluster))
    t_oracle = time.perf_counter() - t0

    s_static = static.sustained_throughput()
    s_online = online.sustained_throughput()
    s_oracle = oracle.sustained_throughput()
    return {
        "scenario": trace.name,
        "windows": trace.n_windows,
        "provision_rate": round(provision_rate, 3),
        "sustained_static": round(s_static, 3),
        "sustained_online": round(s_online, 3),
        "sustained_oracle": round(s_oracle, 3),
        "online_vs_static": round(s_online / max(s_static, 1e-9), 3),
        "online_vs_oracle": round(s_online / max(s_oracle, 1e-9), 3),
        "online_migrations": int(online.migrations.sum()),
        "online_replans": int((online.migrations > 0).sum()),
        "oracle_migrations": int(oracle.migrations.sum()),
        "controller_log_tail": [f"w{w}:{msg}" for w, msg in ctl.log[-3:]],
        "beats_static": bool(s_online >= s_static),
        "within_10pct_of_oracle": bool(s_online >= 0.9 * s_oracle),
        "static_s": round(t_static, 3),
        "online_s": round(t_online, 3),
        "oracle_s": round(t_oracle, 3),
    }


def parity_smoke(topo, cluster) -> dict:
    """JAX scan vs Python loop on a shared scenario (max |diff|)."""
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    traces = [
        ramp_trace(0.3 * full.rate, 1.5 * full.rate, n_windows=120).compile(
            cluster, seed=1
        ),
        slowdown_trace(0.9 * full.rate, machine=2, n_windows=120).compile(
            cluster, seed=2
        ),
    ]
    policies = full.etg.task_machine()[None, :]
    a = evaluate_policies_batch(full.etg, cluster, traces, policies,
                                backend="numpy")
    b = evaluate_policies_batch(full.etg, cluster, traces, policies,
                                backend="auto")
    diff = float(np.max(np.abs(a.throughput - b.throughput)))
    try:
        import jax  # noqa: F401

        jax_used = True
    except ImportError:
        jax_used = False
    return {
        "jax_available": jax_used,
        "max_abs_throughput_diff": diff,
        "within_1e9": bool(diff <= 1e-9),
    }


def main(json_path: str | None = None) -> None:
    cluster = paper_cluster((1, 1, 1))
    results = {}
    for topo_name, topo, scen_fn in (
        ("linear", linear_topology(), _scenarios),
        ("rolling_count", rolling_count_topology(), _scenarios),
        (
            "keyed_rolling_count",
            keyed_rolling_count_topology(n_keys=16, zipf_s=1.5),
            _keyed_scenarios,
        ),
    ):
        rows = [
            run_scenario(topo, cluster, spec, rate)
            for spec, rate in scen_fn(topo, cluster)
        ]
        results[topo_name] = rows
        for row in rows:
            emit(
                f"runtime_{topo_name}_{row['scenario']}",
                row["online_s"] * 1e6,
                f"online={row['sustained_online']};static={row['sustained_static']};"
                f"oracle={row['sustained_oracle']};migrations={row['online_migrations']};"
                f"beats_static={row['beats_static']};"
                f"within_10pct={row['within_10pct_of_oracle']}",
            )
    parity = parity_smoke(linear_topology(), cluster)
    emit(
        "runtime_eval_parity",
        0.0,
        f"jax={parity['jax_available']};max_diff={parity['max_abs_throughput_diff']:.2e};"
        f"within_1e9={parity['within_1e9']}",
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"scenarios": results, "parity": parity}, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", default=None, help="write BENCH_runtime.json here")
    args = parser.parse_args()
    main(json_path=args.json)
