"""Online-runtime benchmark (``BENCH_runtime.json``).

Executes the policy ladder against every drift scenario in the streaming
runtime (``repro.runtime_stream``):

* **static** — a schedule provisioned for the scenario's *initial* rate
  (``provision_schedule``, the paper's size-to-observed-load protocol),
  then frozen for the whole trace;
* **online** — the same starting schedule driven by ``OnlineController``
  (windowed drift detection, incremental ``refine``-move replanning, the
  state-aware migration cost/benefit guard);
* **online_blind** (keyed/stateful rows only) — the same controller with
  ``state_aware=False``: flat per-move pricing, no state in the ledger —
  the pre-ISSUE-8 cost model, kept as the ablation baseline;
* **oracle** — a full ``schedule()`` re-plan at every window with free
  migrations (``OracleRescheduler`` + ``migration_pause=0``), cached per
  *(capacity, skew epoch)* and polished skew-aware on keyed rows — the
  adaptation upper bound.

Per row the JSON records sustained throughput for each policy, the
latency-SLO column (fraction of tail windows whose Little's-law latency
estimate meets ``SLO_S`` seconds), migration counts, and the acceptance
booleans. The elastic rows (``machine_addition``) run on a *fleet*
cluster whose spare machine's capacity column switches on mid-trace; the
stateful keyed rows ship keyed operator state at a finite
``state_transfer_rate``, which is where the state-aware controller
separates from the blind one.

``--check BENCH.json`` is the CI smoke gate: it fails unless every row
has ``beats_static`` (online sustained >= static), every row's replan
audit ledger is complete (accepted decisions == applied replans, full
guard breakdown on every guard verdict), the recorded evaluator parity
holds, and the observability overhead rows stay under 5%.

``--trace-out PREFIX`` additionally runs one instrumented scenario with
a ``repro.obs.TraceRecorder`` and writes ``PREFIX.jsonl`` +
``PREFIX.trace.json`` (Chrome trace-event format — load in Perfetto);
CI validates both with ``python -m repro.obs.validate`` and uploads them
as artifacts.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import linear_topology, paper_cluster, schedule
from repro.obs import TraceRecorder, to_chrome_trace, to_jsonl
from repro.core.graph import keyed_rolling_count_topology, rolling_count_topology
from repro.core.refine import refine
from repro.runtime_stream import (
    OnlineController,
    OracleRescheduler,
    RuntimeConfig,
    StreamExecutor,
    evaluate_policies_batch,
    provision_schedule,
)
from repro.runtime_stream.traces import (
    TraceSpec,
    burst_trace,
    elastic_trace,
    failure_trace,
    key_skew_shift,
    machine_addition,
    machine_slowdown,
    ramp_trace,
    rate_ramp,
    sine_trace,
    slowdown_trace,
)

N_WINDOWS = 240
SEED = 0
SLO_S = 5.0  # latency SLO: tail windows must estimate <= 5 s queueing delay
STATE_PER_TUPLE = 25.0   # keyed state retained per unit tuple rate (stateful rows)
STATE_RATE = 25.0        # state tuples shippable per second while migrating
# One event-loop config for every policy and scenario: a 120-tuple queue
# bound makes sustained overload trip real back-pressure (the default 500
# lets short transients hide entirely inside the queues).
CONFIG = RuntimeConfig(max_queue=120.0)
ORACLE_CONFIG = RuntimeConfig(max_queue=120.0, migration_pause=0)
# Stateful keyed rows: migrations ship keyed state at a finite rate, so a
# hot instance's restart pauses for multiple windows. The oracle keeps its
# idealized free migrations (instant state transfer).
STATE_CONFIG = RuntimeConfig(max_queue=120.0, state_transfer_rate=STATE_RATE)
DRAIN_CONFIG = RuntimeConfig(
    max_queue=120.0, state_transfer_rate=STATE_RATE, capacity_notice=25
)


def _scenarios(topo, cluster) -> list[tuple[TraceSpec, float, object, object]]:
    """(trace spec, provisioning rate, exec cluster, config) per scenario.

    Rates are expressed against the cluster's maximum stable rate for the
    topology (schedule+refine), so scenarios scale with cluster shape.
    The elastic row runs on a fleet with one spare i5 whose capacity
    column switches on mid-trace (``machine_addition``).
    """
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    r = full.rate
    big = int(np.argmax(cluster.capacity))  # the most capable machine
    rows: list[tuple[TraceSpec, float, object, object]] = [
        (ramp_trace(0.3 * r, 1.2 * r, n_windows=N_WINDOWS), 0.3 * r, cluster, CONFIG),
        (burst_trace(0.5 * r, factor=3.0, n_windows=N_WINDOWS, every=60,
                     width=20, jitter=3), 0.5 * r, cluster, CONFIG),
        (sine_trace(0.65 * r, amplitude=0.45, n_windows=N_WINDOWS, period=160),
         0.65 * r, cluster, CONFIG),
        (slowdown_trace(0.9 * r, machine=big, factor=0.5, n_windows=N_WINDOWS),
         0.9 * r, cluster, CONFIG),
        (failure_trace(0.85 * r, machine=big, n_windows=N_WINDOWS), 0.85 * r,
         cluster, CONFIG),
        (
            TraceSpec(
                name="ramp_slowdown",
                n_windows=N_WINDOWS,
                base_rate=0.4 * r,
                events=(
                    rate_ramp(1.1 * r, start=20, end=120),
                    machine_slowdown(big, 0.6, start=150),
                ),
            ),
            0.4 * r,
            cluster,
            CONFIG,
        ),
    ]
    # Cloud scale-out: a spare i5 (fleet machine 3) joins after the rate
    # ramp passes the initial fleet's bound — only a controller that grows
    # onto the new capacity column rides the ramp.
    fleet = paper_cluster((1, 1, 2))
    r4 = refine(schedule(topo, fleet, r0=1.0, rate_epsilon=0.05).etg, fleet).rate
    rows.append(
        (
            elastic_trace(0.5 * r, 1.05 * r4, machine=3, n_windows=N_WINDOWS,
                          join=120),
            0.5 * r,
            fleet,
            CONFIG,
        )
    )
    return rows


def _keyed_scenarios(topo, cluster) -> list[tuple[TraceSpec, float, object, object]]:
    """Keyed-skew drift rows: the static baseline provisions by the
    even-split closed form for the offered rate; the realized key skew
    saturates a hot instance well below that, so only the skew-aware
    online controller sustains the load. All rows run with operator state
    (``state_per_tuple`` > 0) shipping at a finite transfer rate — the
    regime separating the state-aware controller from the blind one.

    * ``keyed_hot`` — constant offered load between the skew-aware and the
      even-split stable rate: the static schedule back-pressures from the
      start, the controller replans against the realized shares;
    * ``keyed_shift`` — sustainable start, then ``key_skew_shift`` re-rolls
      the hot keys onto new instances mid-trace (rate and capacity never
      change — drift the even-split signals cannot see);
    * ``keyed_elastic`` — scale-out under keyed state: a spare machine
      joins mid-ramp, then leaves with ``capacity_notice`` windows of
      warning (drain-before-removal under a stateful migration cost).
    """
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    r = full.rate  # even-split closed form — intentionally skew-blind
    rows: list[tuple[TraceSpec, float, object, object]] = [
        (
            TraceSpec(name="keyed_hot", n_windows=N_WINDOWS, base_rate=1.0 * r),
            1.0 * r,
            cluster,
            STATE_CONFIG,
        ),
        (
            TraceSpec(
                name="keyed_shift",
                n_windows=N_WINDOWS,
                base_rate=0.8 * r,
                events=(key_skew_shift(start=N_WINDOWS // 3, zipf_s=2.0),),
            ),
            0.8 * r,
            cluster,
            STATE_CONFIG,
        ),
    ]
    fleet = paper_cluster((1, 1, 2))
    rows.append(
        (
            TraceSpec(
                name="keyed_elastic",
                n_windows=N_WINDOWS,
                base_rate=0.7 * r,
                events=(
                    rate_ramp(1.2 * r, start=20, end=100),
                    machine_addition(3, start=80, end=160),
                ),
            ),
            0.7 * r,
            fleet,
            DRAIN_CONFIG,
        )
    )
    return rows


def _start_etg(topo, trace, provision_rate: float, cluster):
    """Provision against the machines alive at window 0 (an elastic fleet's
    spare column is off until its machine_addition fires)."""
    alive0 = trace.capacity[0] > 0.0
    prov_cluster = (
        cluster if alive0.all() else paper_cluster(
            tuple(
                int(np.sum(cluster.machine_types[alive0] == t))
                for t in range(cluster.profile.n_machine_types)
            )
        )
    )
    return provision_schedule(topo, prov_cluster, provision_rate)


def _ledger_complete(ctl: OnlineController, online) -> bool:
    """Acceptance: every accepted AND rejected replan is in the audit
    ledger with a full guard breakdown, and accepted decisions match the
    replans the executor actually applied."""
    guard = [d for d in ctl.ledger if d.has_guard_breakdown]
    return bool(
        len(ctl.ledger.accepted) == int((online.migrations > 0).sum())
        and all(
            d.moves > 0
            and abs(d.cost - (d.move_cost + d.state_cost)) < 1e-9
            and d.candidate_moves
            for d in guard
        )
    )


def run_scenario(topo, spec: TraceSpec, provision_rate: float, cluster,
                 config: RuntimeConfig) -> dict:
    trace = spec.compile(cluster, seed=SEED, utg=topo)
    start_etg = _start_etg(topo, trace, provision_rate, cluster)
    oracle_config = ORACLE_CONFIG

    t0 = time.perf_counter()
    static = StreamExecutor(start_etg, cluster, trace, config=config).run()
    t_static = time.perf_counter() - t0

    ctl = OnlineController(topo, cluster, period=10)
    t0 = time.perf_counter()
    online = StreamExecutor(start_etg, cluster, trace, config=config).run(
        controller=ctl
    )
    t_online = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle = StreamExecutor(
        start_etg, cluster, trace, config=oracle_config
    ).run(controller=OracleRescheduler(topo, cluster))
    t_oracle = time.perf_counter() - t0

    s_static = static.sustained_throughput()
    s_online = online.sustained_throughput()
    s_oracle = oracle.sustained_throughput()
    row = {
        "scenario": trace.name,
        "windows": trace.n_windows,
        "provision_rate": round(provision_rate, 3),
        "sustained_static": round(s_static, 3),
        "sustained_online": round(s_online, 3),
        "sustained_oracle": round(s_oracle, 3),
        "online_vs_static": round(s_online / max(s_static, 1e-9), 3),
        "online_vs_oracle": round(s_online / max(s_oracle, 1e-9), 3),
        "latency_slo_s": SLO_S,
        "latency_slo_static": round(static.latency_slo_frac(SLO_S), 3),
        "latency_slo_online": round(online.latency_slo_frac(SLO_S), 3),
        "latency_slo_oracle": round(oracle.latency_slo_frac(SLO_S), 3),
        "online_migrations": int(online.migrations.sum()),
        "online_replans": int((online.migrations > 0).sum()),
        "oracle_migrations": int(oracle.migrations.sum()),
        "controller_log_tail": [f"w{w}:{msg}" for w, msg in ctl.log[-3:]],
        "ledger_decisions": len(ctl.ledger),
        "ledger_accepted": len(ctl.ledger.accepted),
        "ledger_rejected": len(ctl.ledger.rejected),
        "ledger_complete": _ledger_complete(ctl, online),
        "beats_static": bool(s_online >= s_static),
        "within_10pct_of_oracle": bool(s_online >= 0.9 * s_oracle),
        "static_s": round(t_static, 3),
        "online_s": round(t_online, 3),
        "oracle_s": round(t_oracle, 3),
    }
    if topo.groupings:
        # Ablation on keyed/stateful rows: the state-blind controller
        # prices the same replans flat (no state in the ledger, no pause
        # loss from state shipping) — the pre-ISSUE-8 guard.
        blind = OnlineController(topo, cluster, period=10, state_aware=False)
        res_blind = StreamExecutor(
            start_etg, cluster, trace, config=config
        ).run(controller=blind)
        s_blind = res_blind.sustained_throughput()
        row["sustained_online_blind"] = round(s_blind, 3)
        row["latency_slo_online_blind"] = round(
            res_blind.latency_slo_frac(SLO_S), 3
        )
        row["blind_migrations"] = int(res_blind.migrations.sum())
        row["aware_beats_blind"] = bool(s_online >= s_blind)
        if bool(np.all(trace.capacity == trace.capacity[:1])):
            # The re-keyed-oracle acceptance (ISSUE 8): on a fixed fleet
            # the per-(capacity, skew-epoch) oracle must not lose to the
            # online controller. Elastic keyed rows are exempt — there
            # the oracle replans from scratch at every capacity flip and
            # refine's non-convex landscape can land it in a worse basin
            # than the controller's state-aware inertia holds; the raw
            # sustained numbers stay recorded for inspection.
            row["oracle_not_below_online"] = bool(s_oracle >= 0.99 * s_online)
    return row


def overhead_rows(cluster) -> list[dict]:
    """Recorder-on vs recorder-off CPU time on one shuffle and one keyed
    scenario; ``--check`` gates at < 5%.

    A single run is ~50-150 ms — the same order as scheduler jitter and
    CPU-frequency drift on shared runners, so mean/median ratios flap by
    several percent between invocations.  Off and on runs are therefore
    interleaved one-by-one (drift slower than a run cancels out of each
    sample's ratio of sums), timed with ``time.process_time_ns`` (immune
    to preemption), and the reported overhead is the *minimum* sample
    ratio — the least noise-contaminated measurement, as in min-of-N
    timing.  The gate exists to catch gross instrumentation regressions
    (per-window allocation in the hot loop, accidental always-on wall
    probes); differences below the runner noise floor are not resolvable
    and not what it polices."""
    rows: list[dict] = []
    keyed = keyed_rolling_count_topology(
        n_keys=16, zipf_s=1.5, state_per_tuple=STATE_PER_TUPLE
    )
    for topo, scen_fn in ((linear_topology(), _scenarios),
                          (keyed, _keyed_scenarios)):
        spec, rate, clu, cfg = scen_fn(topo, cluster)[0]
        trace = spec.compile(clu, seed=SEED, utg=topo)
        start_etg = _start_etg(topo, trace, rate, clu)

        def run_once(recorder=None) -> float:
            ctl = OnlineController(topo, clu, period=10, recorder=recorder)
            t0 = time.process_time_ns()
            StreamExecutor(
                start_etg, clu, trace, config=cfg, recorder=recorder
            ).run(controller=ctl)
            return (time.process_time_ns() - t0) / 1e9

        def make_rec():
            return TraceRecorder(
                name=f"overhead-{trace.name}", wall_clock=True
            )

        run_once()  # warm-up: imports, caches, first-touch allocations
        rec = make_rec()
        run_once(rec)
        ratios: list[float] = []
        off_times: list[float] = []
        on_times: list[float] = []
        for _ in range(7):
            t_off = t_on = 0.0
            for _ in range(4):  # interleave singles within the sample
                t_off += run_once()
                rec = make_rec()
                t_on += run_once(rec)
            off_times.append(t_off / 4)
            on_times.append(t_on / 4)
            ratios.append(t_on / max(t_off, 1e-12))
        off = statistics.median(off_times)
        on = statistics.median(on_times)
        frac = min(ratios) - 1.0
        rows.append(
            {
                "scenario": trace.name,
                "recorder_off_s": round(off, 4),
                "recorder_on_s": round(on, 4),
                "overhead_pct": round(100.0 * frac, 2),
                "within_5pct": bool(frac < 0.05),
                "records": len(rec.records),
            }
        )
    return rows


def export_demo_trace(prefix: str, cluster=None) -> tuple[str, str]:
    """One instrumented controller run exported for the CI artifacts.

    Writes ``<prefix>.jsonl`` and ``<prefix>.trace.json`` (Chrome
    trace-event format — open https://ui.perfetto.dev and drag the file
    in); returns the two paths.
    """
    cluster = cluster if cluster is not None else paper_cluster((1, 1, 1))
    topo = linear_topology()
    spec, rate, clu, cfg = _scenarios(topo, cluster)[0]
    trace = spec.compile(clu, seed=SEED, utg=topo)
    start_etg = _start_etg(topo, trace, rate, clu)
    rec = TraceRecorder(name=f"bench_runtime_{trace.name}", wall_clock=True)
    ctl = OnlineController(topo, clu, period=10, recorder=rec)
    StreamExecutor(start_etg, clu, trace, config=cfg, recorder=rec).run(
        controller=ctl
    )
    jsonl_path = f"{prefix}.jsonl"
    chrome_path = f"{prefix}.trace.json"
    to_jsonl(rec, path=jsonl_path)
    to_chrome_trace(rec, path=chrome_path)
    print(f"trace export: {jsonl_path} ({len(rec.records)} records), "
          f"{chrome_path} (Perfetto-loadable)")
    return jsonl_path, chrome_path


def parity_smoke(topo, cluster) -> dict:
    """JAX scan vs Python loop on a shared scenario (max |diff|)."""
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    traces = [
        ramp_trace(0.3 * full.rate, 1.5 * full.rate, n_windows=120).compile(
            cluster, seed=1
        ),
        slowdown_trace(0.9 * full.rate, machine=2, n_windows=120).compile(
            cluster, seed=2
        ),
    ]
    policies = full.etg.task_machine()[None, :]
    a = evaluate_policies_batch(full.etg, cluster, traces, policies,
                                backend="numpy")
    b = evaluate_policies_batch(full.etg, cluster, traces, policies,
                                backend="auto")
    diff = float(np.max(np.abs(a.throughput - b.throughput)))
    lat_diff = float(np.max(np.abs(a.latency() - b.latency())))
    try:
        import jax  # noqa: F401

        jax_used = True
    except ImportError:
        jax_used = False
    return {
        "jax_available": jax_used,
        "max_abs_throughput_diff": diff,
        "max_abs_latency_diff": lat_diff,
        "within_1e9": bool(diff <= 1e-9),
    }


def check(json_path: str) -> int:
    """CI smoke gate: every recorded row must have online >= static, a
    complete replan audit ledger, the keyed ablation rows must not lose
    to the blind controller, the evaluator parity must hold, and the
    recorder overhead rows must stay under 5%."""
    with open(json_path) as f:
        data = json.load(f)
    bad: list[str] = []
    for topo_name, rows in data["scenarios"].items():
        for row in rows:
            tag = f"{topo_name}/{row['scenario']}"
            if not row.get("beats_static", False):
                bad.append(f"{tag}: online < static")
            if not row.get("ledger_complete", False):
                bad.append(f"{tag}: replan audit ledger incomplete")
            if "aware_beats_blind" in row and not row["aware_beats_blind"]:
                bad.append(f"{tag}: state-aware < state-blind")
            if "oracle_not_below_online" in row and not row["oracle_not_below_online"]:
                bad.append(f"{tag}: oracle lost to the online controller")
    parity = data.get("parity", {})
    if parity.get("jax_available") and not parity.get("within_1e9", False):
        bad.append("parity: JAX evaluator drifted past 1e-9")
    overhead = data.get("overhead", [])
    if not overhead:
        bad.append("overhead: recorder overhead rows missing")
    for row in overhead:
        if not row.get("within_5pct", False):
            bad.append(
                f"overhead/{row['scenario']}: recorder overhead "
                f"{row.get('overhead_pct')}% >= 5%"
            )
    if bad:
        for line in bad:
            print(f"runtime check FAILED: {line}")
        return 1
    n = sum(len(rows) for rows in data["scenarios"].values())
    print(f"runtime check ok: {n} rows, online >= static on all, "
          "ledgers complete, keyed ablation, parity and recorder "
          "overhead hold")
    return 0


def main(json_path: str | None = None, trace_out: str | None = None) -> None:
    cluster = paper_cluster((1, 1, 1))
    results = {}
    for topo_name, topo, scen_fn in (
        ("linear", linear_topology(), _scenarios),
        ("rolling_count", rolling_count_topology(), _scenarios),
        (
            "keyed_rolling_count",
            keyed_rolling_count_topology(
                n_keys=16, zipf_s=1.5, state_per_tuple=STATE_PER_TUPLE
            ),
            _keyed_scenarios,
        ),
    ):
        rows = [
            run_scenario(topo, spec, rate, clu, cfg)
            for spec, rate, clu, cfg in scen_fn(topo, cluster)
        ]
        results[topo_name] = rows
        for row in rows:
            extra = (
                f";blind={row['sustained_online_blind']}"
                if "sustained_online_blind" in row
                else ""
            )
            emit(
                f"runtime_{topo_name}_{row['scenario']}",
                row["online_s"] * 1e6,
                f"online={row['sustained_online']};static={row['sustained_static']};"
                f"oracle={row['sustained_oracle']};migrations={row['online_migrations']};"
                f"slo={row['latency_slo_online']};beats_static={row['beats_static']};"
                f"within_10pct={row['within_10pct_of_oracle']}{extra}",
            )
    parity = parity_smoke(linear_topology(), cluster)
    emit(
        "runtime_eval_parity",
        0.0,
        f"jax={parity['jax_available']};max_diff={parity['max_abs_throughput_diff']:.2e};"
        f"within_1e9={parity['within_1e9']}",
    )
    overhead = overhead_rows(cluster)
    for row in overhead:
        emit(
            f"runtime_obs_overhead_{row['scenario']}",
            row["recorder_on_s"] * 1e6,
            f"off={row['recorder_off_s']}s;on={row['recorder_on_s']}s;"
            f"overhead={row['overhead_pct']}%;within_5pct={row['within_5pct']};"
            f"records={row['records']}",
        )
    if trace_out:
        export_demo_trace(trace_out, cluster)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(
                {"scenarios": results, "parity": parity, "overhead": overhead},
                f,
                indent=2,
            )
            f.write("\n")


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", default=None, help="write BENCH_runtime.json here")
    parser.add_argument("--check", default=None, metavar="JSON",
                        help="validate a recorded BENCH_runtime.json and exit")
    parser.add_argument(
        "--trace-out", default=None, metavar="PREFIX",
        help="export one instrumented run as PREFIX.jsonl + PREFIX.trace.json",
    )
    args = parser.parse_args()
    if args.check:
        sys.exit(check(args.check))
    main(json_path=args.json, trace_out=args.trace_out)
