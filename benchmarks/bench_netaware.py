"""Network-aware vs distance-blind placement (``BENCH_netaware.json``).

The ISSUE 10 acceptance benchmark for the resource-vector objective: on
rack-structured clusters, does pricing cut traffic into the closed form
(R* = min_w (cap_w - met_w) / (var_w + net_w)) actually buy throughput
over the distance-blind scalar-CPU schedule?

Per scenario both pipelines start from the same ``schedule`` + ``refine``
run on ``cluster.without_network()`` (the distance-blind engine — exactly
today's scalar objective). The *blind* row re-scores that placement on
the true network-aware objective; the *aware* row hands the same
placement to ``refine`` on the full cluster, so the hill climb prices
cut traffic while it moves instances (the tiny shuffle-heavy scenario
runs the exhaustive network-aware ``optimal_schedule`` instead — its
colocation win sits across a hill-climb barrier). Both make the gate
structural: refine never degrades its seed and the optimal's budget
covers the blind placement, so ``aware >= blind`` must hold on every
row, and the shuffle-heavy scenario (high alpha fan-out across racks
with a steep penalty) must show a strict gain — colocating the shuffle
edge beats spreading for CPU headroom.

``--check BENCH.json`` is the CI smoke gate: it fails unless every row
has ``aware_ge_blind`` and at least one row shows a strict gain.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    UserGraph,
    max_stable_rate,
    optimal_schedule,
    paper_cluster,
    rack_distance_matrix,
    refine,
    rolling_count_topology,
    schedule,
    wide_fanout_topology,
)

MEM = np.array([1.0, 2.0, 3.0, 4.0])


def _shuffle_heavy(alpha: float = 4.0) -> UserGraph:
    """One hot shuffle edge: a spout fanning ``alpha`` tuples per input
    into a mid-type bolt — the cut-traffic-dominated shape."""
    return UserGraph(
        name="shuffle_heavy",
        component_types=np.array([0, 2]),
        edges=((0, 1),),
        alpha=np.array([alpha, 1.0]),
    )


def _racked(counts, racks, net_penalty, cross_rack=2.0, with_memory=False):
    profile = paper_cluster((1, 1, 1)).profile
    if with_memory:
        profile = profile.with_mem(MEM)
    cluster = paper_cluster(counts, profile)
    if with_memory:
        cluster = cluster.with_resources(
            mem_capacity=np.full(cluster.n_machines, 4.0 * float(MEM.sum()))
        )
    return cluster.with_resources(
        distance=rack_distance_matrix(np.asarray(racks), cross_rack=cross_rack),
        net_penalty=net_penalty,
    )


SCENARIOS = [
    # The colocation-wins golden from tests/test_resource_vector.py: two
    # same-type machines on different racks, penalty steep enough that
    # splitting the shuffle edge costs more than the CPU headroom buys.
    # Colocation sits across a hill-climb barrier (every single move from
    # the blind spread degrades first), so this tiny scenario runs the
    # exhaustive network-aware optimal instead of refine.
    (
        "shuffle_heavy_2rack",
        _shuffle_heavy(),
        _racked((0, 2, 0), [0, 1], 10.0),
        "optimal",
    ),
    # Paper topology on a 6-machine 2-rack cluster with a mild penalty:
    # the regime where CPU stays primary and network breaks ties.
    (
        "rolling_count_2rack",
        rolling_count_topology(),
        _racked((2, 2, 2), [0, 0, 0, 1, 1, 1], 1.0),
        "refine",
    ),
    # High-fan-out DAG across 3 racks with memory attached — the full
    # resource vector (CPU + memory + network) in one sweep.
    (
        "wide_fanout_3rack_mem",
        wide_fanout_topology(),
        _racked(
            (2, 2, 2), [0, 0, 1, 1, 2, 2], 2.0, cross_rack=3.0,
            with_memory=True,
        ),
        "refine",
    ),
]


def scenario_row(name: str, utg, cluster, engine: str) -> dict:
    blind_cluster = cluster.without_network()
    t0 = time.perf_counter()
    seed = schedule(utg, blind_cluster, r0=1.0, rate_epsilon=0.5)
    blind = refine(seed.etg, blind_cluster, backend="numpy")
    t_blind = time.perf_counter() - t0
    # Same placement, true objective: what the distance-blind engine
    # actually sustains once cut traffic is priced.
    _, blind_true = max_stable_rate(blind.etg, cluster)

    t0 = time.perf_counter()
    if engine == "optimal":
        # Budget = the blind engine's own task count, so the blind
        # placement is inside the searched space and optimal >= blind
        # holds structurally, same as the refine seeding.
        aware = optimal_schedule(
            utg, cluster, max_total_tasks=int(blind.etg.total_tasks)
        )
    else:
        aware = refine(blind.etg, cluster, backend="numpy")
    t_aware = time.perf_counter() - t0
    aware_true = float(aware.throughput)
    _, check_rate = max_stable_rate(aware.etg, cluster)

    tm_blind = blind.etg.task_machine()
    tm_aware = aware.etg.task_machine()
    gain = (aware_true - float(blind_true)) / max(float(blind_true), 1e-12)
    return {
        "scenario": name,
        "engine": engine,
        "n_machines": cluster.n_machines,
        "net_penalty": float(cluster.net_penalty),
        "has_memory": bool(cluster.has_memory),
        "blind_rate_true_objective": float(blind_true),
        "aware_rate": aware_true,
        "gain_pct": round(100.0 * gain, 3),
        "aware_ge_blind": bool(aware_true >= float(blind_true) * (1 - 1e-12)),
        "rescore_consistent": bool(
            abs(check_rate - aware_true) <= 1e-9 * max(1.0, aware_true)
        ),
        "blind_tasks": int(tm_blind.size),
        "aware_tasks": int(tm_aware.size),
        "moved_tasks": (
            int(np.sum(tm_blind != tm_aware))
            if tm_blind.size == tm_aware.size else None
        ),
        "blind_machines_used": int(np.unique(tm_blind).size),
        "aware_machines_used": int(np.unique(tm_aware).size),
        "blind_wall_s": round(t_blind, 4),
        "aware_wall_s": round(t_aware, 4),
    }


def main(json_path: str | None = None) -> None:
    rows = [
        scenario_row(name, utg, cluster, engine)
        for name, utg, cluster, engine in SCENARIOS
    ]
    for row in rows:
        emit(
            f"netaware_{row['scenario']}",
            row["aware_wall_s"] * 1e6,
            f"blind={row['blind_rate_true_objective']:.4f};"
            f"aware={row['aware_rate']:.4f};gain_pct={row['gain_pct']};"
            f"aware_ge_blind={row['aware_ge_blind']}",
        )
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"scenarios": rows}, f, indent=2)
            f.write("\n")


def check(path: str) -> int:
    """CI gate: aware >= blind everywhere, strict gain on >= 1 scenario."""
    with open(path) as f:
        rows = json.load(f)["scenarios"]
    failures = []
    for row in rows:
        if not row["aware_ge_blind"]:
            failures.append(f"{row['scenario']}: aware < blind")
        if not row["rescore_consistent"]:
            failures.append(f"{row['scenario']}: refine/rescore mismatch")
    if not any(row["gain_pct"] > 0.1 for row in rows):
        failures.append("no scenario shows a strict network-aware gain")
    if failures:
        for f_ in failures:
            print(f"netaware check FAILED: {f_}", file=sys.stderr)
        return 1
    print(f"netaware check OK: {len(rows)} scenarios, "
          f"max gain {max(r['gain_pct'] for r in rows)}%")
    return 0


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", default=None,
                        help="write BENCH_netaware.json here")
    parser.add_argument("--check", default=None, metavar="JSON",
                        help="validate a recorded run's acceptance gates")
    args = parser.parse_args()
    if args.check:
        sys.exit(check(args.check))
    main(json_path=args.json)
