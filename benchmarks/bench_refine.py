"""Refine/optimal engine perf baseline (``BENCH_refine.json``).

The refine hill climb and the exhaustive optimal search both have two
engines (see docs/architecture.md): the ``reference`` per-candidate
copy-and-score paths, and the ``state`` engines that express moves as
``ScheduleState`` deltas and score whole candidate sets through vectorized
``max_stable_rate_batch`` sweeps. This benchmark times both on the slow
test suite's scenario (the paper's 3-worker cluster, rate_epsilon=0.05
schedules — ``test_refined_schedule_within_4pct_of_optimal``), verifies the
engines return identical results, and records the speedups the repo
regresses against (target: >= 10x on the refine scenario). The wide
scenario additionally times the lockstep growth-chain explorer against the
sequential one (target: >= 2x at 10+ components), and the exhaustive
search runs with the closed-form beam bound (candidates include its
pruning).
"""

from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import emit
from repro.core import (
    diamond_topology,
    linear_topology,
    optimal_schedule,
    paper_cluster,
    schedule,
    star_topology,
    wide_fanout_topology,
)
from repro.core.refine import refine

TOPOLOGIES = (linear_topology, diamond_topology, star_topology)
SLOW_SUITE_CLUSTER = (1, 1, 1)
WIDE_CLUSTER = (2, 2, 2)


def _interleaved_median_times(fns, repeats: int = 5) -> list[float]:
    """Median wall time per fn, with the fns' runs interleaved round-robin
    so slow drift on a shared runner hits every fn equally."""
    times: list[list[float]] = [[] for _ in fns]
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            times[i].append(time.perf_counter() - t0)
    return [sorted(ts)[len(ts) // 2] for ts in times]


def bench_refine_wide(skip_reference: bool = False) -> dict:
    """Wide-topology refine: lockstep vs sequential chain exploration.

    The acceptance target for the lockstep explorer is >= 2x over the
    sequential state engine on this scenario (both bit-identical to the
    reference climb, which is also timed unless skipped). 14 mid bolts ->
    C(16, 2) = 120 pair chains per round; state engines are timed as
    interleaved medians of 5 runs (sub-second timings drift on shared
    runners)."""
    cluster = paper_cluster(WIDE_CLUSTER)
    topo = wide_fanout_topology(14)
    etg = schedule(topo, cluster, r0=1.0, rate_epsilon=0.1).etg
    lock = refine(etg, cluster, lockstep=True)   # warm + results
    seq = refine(etg, cluster, lockstep=False)
    t_lock, t_seq = _interleaved_median_times(
        (
            lambda: refine(etg, cluster, lockstep=True),
            lambda: refine(etg, cluster, lockstep=False),
        )
    )
    out = {
        "scenario": f"{topo.name}_{'_'.join(map(str, WIDE_CLUSTER))}",
        "tasks": int(etg.total_tasks),
        "components": int(topo.n_components),
        "moves": len(lock.moves),
        "lockstep_s": round(t_lock, 4),
        "sequential_s": round(t_seq, 4),
        "lockstep_speedup": round(t_seq / max(t_lock, 1e-9), 1),
        "identical": bool(
            lock.moves == seq.moves
            and lock.throughput == seq.throughput
            and lock.etg.task_machine().tolist()
            == seq.etg.task_machine().tolist()
        ),
    }
    if not skip_reference:
        t0 = time.perf_counter()
        ref = refine(etg, cluster, engine="reference")
        t_ref = time.perf_counter() - t0
        out["reference_s"] = round(t_ref, 4)
        out["speedup_vs_reference"] = round(t_ref / max(t_lock, 1e-9), 1)
        out["identical"] = bool(
            out["identical"]
            and ref.moves == lock.moves
            and ref.throughput == lock.throughput
        )
    return out


def bench_refine_engines(skip_reference: bool = False) -> dict:
    """Slow-suite refine scenario: reference vs state engine per topology."""
    cluster = paper_cluster(SLOW_SUITE_CLUSTER)
    per_topo = []
    total_state = total_ref = 0.0
    identical = True
    for topo_fn in TOPOLOGIES:
        topo = topo_fn()
        etg = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg
        refine(etg, cluster, engine="state")  # warm any lazy imports
        t0 = time.perf_counter()
        state = refine(etg, cluster, engine="state")
        t_state = time.perf_counter() - t0
        row = {
            "topology": topo.name,
            "tasks": int(etg.total_tasks),
            "moves": len(state.moves),
            "state_s": round(t_state, 4),
        }
        total_state += t_state
        if not skip_reference:
            t0 = time.perf_counter()
            ref = refine(etg, cluster, engine="reference")
            t_ref = time.perf_counter() - t0
            total_ref += t_ref
            row["reference_s"] = round(t_ref, 4)
            row["speedup"] = round(t_ref / max(t_state, 1e-9), 1)
            same = (
                ref.moves == state.moves
                and ref.rate == state.rate
                and ref.throughput == state.throughput
                and ref.etg.task_machine().tolist()
                == state.etg.task_machine().tolist()
            )
            row["identical"] = bool(same)
            identical = identical and same
        per_topo.append(row)
    out = {
        "scenario": f"slow_suite_{'_'.join(map(str, SLOW_SUITE_CLUSTER))}",
        "topologies": per_topo,
        "state_total_s": round(total_state, 4),
    }
    if not skip_reference:
        out["reference_total_s"] = round(total_ref, 4)
        out["speedup"] = round(total_ref / max(total_state, 1e-9), 1)
        out["identical"] = identical
    return out


def bench_optimal_engines(skip_reference: bool = False) -> dict:
    """Exhaustive search: reference vs vectorized state engine."""
    cluster = paper_cluster(SLOW_SUITE_CLUSTER)
    topo = linear_topology()
    max_total_tasks = 8
    optimal_schedule(topo, cluster, max_total_tasks=max_total_tasks)  # warm
    t0 = time.perf_counter()
    state = optimal_schedule(
        topo, cluster, max_total_tasks=max_total_tasks, engine="state"
    )
    t_state = time.perf_counter() - t0
    out = {
        "scenario": f"linear_mtt{max_total_tasks}",
        "candidates": int(state.candidates_evaluated),
        "state_s": round(t_state, 4),
    }
    if not skip_reference:
        t0 = time.perf_counter()
        ref = optimal_schedule(
            topo, cluster, max_total_tasks=max_total_tasks, engine="reference"
        )
        t_ref = time.perf_counter() - t0
        out["reference_s"] = round(t_ref, 4)
        out["speedup"] = round(t_ref / max(t_state, 1e-9), 1)
        out["identical"] = bool(
            ref.throughput == state.throughput
            and ref.candidates_evaluated == state.candidates_evaluated
            and ref.etg.task_machine().tolist() == state.etg.task_machine().tolist()
        )
    return out


def main(json_path: str | None = None, skip_reference: bool = False) -> None:
    ref_bench = bench_refine_engines(skip_reference=skip_reference)
    emit(
        "refine_engines_slow_suite",
        ref_bench["state_total_s"] * 1e6,
        ";".join(
            f"{k}={v}" for k, v in ref_bench.items()
            if k not in ("topologies", "state_total_s")
        ),
    )
    wide_bench = bench_refine_wide(skip_reference=skip_reference)
    emit(
        "refine_wide_lockstep",
        wide_bench["lockstep_s"] * 1e6,
        ";".join(
            f"{k}={v}" for k, v in wide_bench.items() if k != "lockstep_s"
        ),
    )
    opt_bench = bench_optimal_engines(skip_reference=skip_reference)
    emit(
        "optimal_engines",
        opt_bench["state_s"] * 1e6,
        ";".join(f"{k}={v}" for k, v in opt_bench.items() if k != "state_s"),
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(
                {
                    "refine": ref_bench,
                    "refine_wide": wide_bench,
                    "optimal": opt_bench,
                },
                f,
                indent=2,
            )
            f.write("\n")


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", default=None, help="write BENCH_refine.json here")
    parser.add_argument(
        "--skip-reference",
        action="store_true",
        help="skip the slow reference-engine timings (noisy CI runners)",
    )
    args = parser.parse_args()
    main(json_path=args.json, skip_reference=args.skip_reference)
