"""Fig. 6 — CPU-usage prediction accuracy.

For the highCompute bolt of each micro-benchmark topology on each machine
type, sweep the input rate from low to saturation (the paper starts at 8
tuples/s and multiplies by random factors), compare predicted TCU (eq. 5)
against the simulator's measured TCU (with the paper's moderate-load noise
profile), and report accuracy = 100 - mean |error|.

Paper claims: >= 92 % accuracy, max error < 8 CPU points.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit_us
from repro.core import (
    diamond_topology,
    first_assignment,
    linear_topology,
    max_stable_rate,
    measured_tcu,
    paper_cluster,
    predict,
    prediction_accuracy,
    star_topology,
)


def run() -> dict:
    cluster = paper_cluster((1, 1, 1))
    all_pred, all_meas = [], []
    worst = 0.0
    for topo_fn in (linear_topology, diamond_topology, star_topology):
        topo = topo_fn()
        etg = first_assignment(topo, cluster, 1.0)
        max_rate, _ = max_stable_rate(etg, cluster)
        rng = np.random.default_rng(0)
        rate = max(max_rate / 32.0, 0.05)
        while rate <= max_rate:
            pred = predict(etg, cluster, rate)
            meas = measured_tcu(etg, cluster, rate, seed=int(rate * 1000) % 2**31)
            all_pred.extend(pred.tcu.tolist())
            all_meas.extend(meas.tolist())
            worst = max(worst, float(np.abs(pred.tcu - meas).max()))
            rate *= float(rng.uniform(1.2, 1.8))

    acc = prediction_accuracy(np.array(all_pred), np.array(all_meas))
    return {"accuracy": acc, "max_error": worst, "n_points": len(all_pred)}


def main() -> None:
    us = timeit_us(run, iters=1, warmup=0)
    r = run()
    emit(
        "fig6_prediction_accuracy",
        us,
        f"accuracy={r['accuracy']:.1f}%;max_err={r['max_error']:.2f}pts;"
        f"n={r['n_points']};paper>=92%",
    )


if __name__ == "__main__":
    main()
