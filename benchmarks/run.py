"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig6   CPU-usage prediction accuracy            (bench_prediction)
  fig7   instance-count selection (RollingCount / UniqueVisitor)
  fig8   throughput: default vs proposed vs optimal (also fig3)
  fig9   per-machine utilization comparison
  fig10  large-scale simulation scenarios + Table 4/5
  sec3   scheduler wall-time vs exhaustive optimal
  refine refine/optimal engine baseline (writes BENCH_refine.json)
  dispatch closed-form scorer backend crossover (writes BENCH_dispatch.json)
  runtime online streaming runtime: static vs online controller vs oracle
         on drift scenarios (writes BENCH_runtime.json)
  multitenant 100-tenant fairness scale, tenant-batched scoring, shared
         runtime (writes BENCH_multitenant.json)
  netaware network-aware vs distance-blind placement on rack-structured
         clusters (writes BENCH_netaware.json)
  planner beyond-paper heterogeneous LM fleet planning
  roofline dry-run roofline aggregation (requires dry-run artifacts)
"""

from __future__ import annotations

from benchmarks import (
    bench_dispatch,
    bench_instances,
    bench_largescale,
    bench_multitenant,
    bench_netaware,
    bench_planner,
    bench_prediction,
    bench_refine,
    bench_roofline,
    bench_runtime,
    bench_sched_speed,
    bench_throughput,
    bench_utilization,
)


def main() -> None:
    print("name,us_per_call,derived")
    bench_prediction.main()
    bench_throughput.main()
    bench_instances.main()
    bench_utilization.main()
    bench_largescale.main()
    bench_sched_speed.main(json_path="BENCH_sched.json")
    bench_refine.main(json_path="BENCH_refine.json")
    bench_dispatch.main(json_path="BENCH_dispatch.json")
    # trace_out exports one instrumented run (JSONL + Chrome trace-event)
    # alongside the JSON — the repro.obs demo artifacts CI validates.
    bench_runtime.main(
        json_path="BENCH_runtime.json", trace_out="BENCH_runtime_trace"
    )
    bench_multitenant.main(json_path="BENCH_multitenant.json")
    bench_netaware.main(json_path="BENCH_netaware.json")
    bench_planner.main()
    bench_roofline.main()


if __name__ == "__main__":
    main()
