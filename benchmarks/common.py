"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows; ``derived``
carries the benchmark's headline quantity (throughput, accuracy, ...).
"""

from __future__ import annotations

import time
from typing import Callable

__all__ = ["timeit_us", "emit"]


def timeit_us(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
