"""Roofline table — reads the dry-run artifacts (experiments/dryrun/*.json)
and emits one row per (arch x shape) cell on the single-pod mesh: the three
terms, the dominant bottleneck, and the useful-FLOPs ratio.

Run ``python -m repro.launch.dryrun --all`` first (the dry-run is hours of
XLA compile; this benchmark only aggregates).
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path("experiments/dryrun")


def main() -> None:
    if not DRYRUN_DIR.exists():
        emit("roofline_table", 0.0, "missing:run repro.launch.dryrun --all first")
        return
    for f in sorted(DRYRUN_DIR.glob("*_single.json")):
        d = json.loads(f.read_text())
        name = f"roofline_{d['arch']}_{d['shape']}"
        if "skipped" in d:
            emit(name, 0.0, "skipped:sub-quadratic-only-shape")
            continue
        if "error" in d:
            emit(name, 0.0, f"error:{d['error'][:60]}")
            continue
        t = d["terms_s"]
        temp_gb = d["memory"].get("temp_size_in_bytes", 0) / 1e9
        emit(
            name,
            d.get("compile_s", 0.0) * 1e6,
            f"compute={t['compute']:.4f}s;memory={t['memory']:.4f}s;"
            f"collective={t['collective']:.4f}s;dominant={d['dominant']};"
            f"useful_flops_ratio={d['useful_flops_ratio']:.2f};"
            f"temp_gb={temp_gb:.1f}",
        )


if __name__ == "__main__":
    main()
