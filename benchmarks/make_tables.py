"""Render the §Roofline markdown tables from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.make_tables >> EXPERIMENTS.md
"""

from __future__ import annotations

import glob
import json


def table(pattern: str, title: str, dedup: bool = True) -> None:
    rows = []
    seen = set()
    for f in sorted(glob.glob(pattern)):
        d = json.load(open(f))
        tag = f.split("/")[-2]
        name = d["arch"].replace("-", "_").replace(".", "_")
        if not dedup:
            name = f"{name} ({tag})"
        key = (name, d["shape"])
        if key in seen:
            continue
        seen.add(key)
        if "skipped" in d:
            rows.append((key[0], key[1], "skip", "", "", "", "", "", ""))
            continue
        if "error" in d:
            rows.append((key[0], key[1], "ERROR", "", "", "", "", "", ""))
            continue
        t = d["terms_s"]
        rows.append((
            key[0], key[1], d["dominant"],
            f"{t['compute']:.3f}", f"{t['memory']:.3f}", f"{t['collective']:.3f}",
            f"{d['memory'].get('temp_size_in_bytes', 0)/1e9:.1f}",
            f"{d['useful_flops_ratio']:.2f}",
            f"{d.get('compile_s', 0):.0f}s",
        ))
    print(f"\n### {title}\n")
    print("| arch | shape | dominant | compute s | memory s | collective s | "
          "temp GB/dev | 6ND/HLO | compile |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows):
        print("| " + " | ".join(str(x) for x in r) + " |")


def main() -> None:
    table("experiments/dryrun/*_single.json", "Single-pod 16x16 (roofline baselines)")
    table("experiments/dryrun/*_multi.json", "Multi-pod 2x16x16 (shardability proof)")
    table("experiments/hillclimb*/*.json",
          "Hillclimb iterations (3 chosen cells; dir = iteration)", dedup=False)


if __name__ == "__main__":
    main()
