"""Figs. 3 & 8 — throughput of default / proposed / proposed+refine /
optimal schedulers on the Micro-Benchmark topologies over the paper's
3-worker heterogeneous cluster.

Paper claims: proposed gives 7-44 % over the default scheduler and lands
within 4 % (worst case) of the optimal scheduler. We report the faithful
Alg. 1+2 result and the beyond-paper refined result separately.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    diamond_topology,
    linear_topology,
    max_stable_rate,
    optimal_schedule,
    paper_cluster,
    round_robin_schedule,
    schedule,
    star_topology,
)
from repro.core.refine import refine


def run_topology(topo_fn) -> dict:
    cluster = paper_cluster((1, 1, 1))
    topo = topo_fn()

    t0 = time.perf_counter()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    t_sched = time.perf_counter() - t0
    _, ours = max_stable_rate(sched.etg, cluster)

    t0 = time.perf_counter()
    ref = refine(sched.etg, cluster)
    t_refine = time.perf_counter() - t0

    rr = round_robin_schedule(topo, cluster, sched.etg.n_instances)
    _, default = max_stable_rate(rr, cluster)

    t0 = time.perf_counter()
    opt = optimal_schedule(
        topo, cluster, max_total_tasks=max(ref.etg.total_tasks + 1, 8)
    )
    t_opt = time.perf_counter() - t0

    return {
        "topology": topo.name,
        "default": default,
        "proposed": ours,
        "refined": ref.throughput,
        "optimal": opt.throughput,
        "gain_vs_default_pct": (ours / default - 1) * 100,
        "refined_gain_vs_default_pct": (ref.throughput / default - 1) * 100,
        "below_optimal_pct": (1 - ours / opt.throughput) * 100,
        "refined_below_optimal_pct": (1 - ref.throughput / opt.throughput) * 100,
        "t_sched_us": t_sched * 1e6,
        "t_refine_us": t_refine * 1e6,
        "t_optimal_us": t_opt * 1e6,
        "optimal_candidates": opt.candidates_evaluated,
    }


def main() -> None:
    for topo_fn in (linear_topology, diamond_topology, star_topology):
        r = run_topology(topo_fn)
        emit(
            f"fig8_throughput_{r['topology']}",
            r["t_sched_us"],
            f"default={r['default']:.1f};proposed={r['proposed']:.1f};"
            f"refined={r['refined']:.1f};optimal={r['optimal']:.1f};"
            f"gain={r['gain_vs_default_pct']:.1f}%(paper 7-44%);"
            f"below_opt={r['below_optimal_pct']:.1f}%;"
            f"refined_below_opt={r['refined_below_optimal_pct']:.1f}%(paper<=4%)",
        )


if __name__ == "__main__":
    main()
