"""Fig. 9 — per-machine CPU utilization under the three schedulers.

Runs each schedule at its own max stable rate through the simulator and
reports total and per-machine utilization. The paper's finding: the
optimal scheduler drives the highest total utilization; the proposed
scheduler uses the fast machine better than default even when its *total*
utilization is lower (Star), and its throughput is higher throughout.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit_us
from repro.core import (
    diamond_topology,
    linear_topology,
    max_stable_rate,
    optimal_schedule,
    paper_cluster,
    round_robin_schedule,
    schedule,
    simulate,
    star_topology,
)
from repro.core.refine import refine


def run(topo_fn) -> dict:
    cluster = paper_cluster((1, 1, 1))
    topo = topo_fn()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    ref = refine(sched.etg, cluster)
    rr = round_robin_schedule(topo, cluster, sched.etg.n_instances)
    opt = optimal_schedule(topo, cluster,
                           max_total_tasks=max(ref.etg.total_tasks + 1, 8))

    out = {"topology": topo.name}
    for name, etg in (("default", rr), ("proposed", sched.etg),
                      ("optimal", opt.etg)):
        rate, thpt = max_stable_rate(etg, cluster)
        sim = simulate(etg, cluster, rate)
        out[name] = {
            "throughput": thpt,
            "util": sim.machine_util.round(1).tolist(),
            "total_util": float(sim.machine_util.sum()),
        }
    return out


def main() -> None:
    for topo_fn in (linear_topology, diamond_topology, star_topology):
        us = timeit_us(lambda f=topo_fn: run(f), iters=1, warmup=0)
        r = run(topo_fn)
        emit(
            f"fig9_utilization_{r['topology']}",
            us,
            ";".join(
                f"{k}:thpt={v['throughput']:.1f},util={v['total_util']:.0f}"
                for k, v in r.items() if k != "topology"
            ),
        )


if __name__ == "__main__":
    main()
