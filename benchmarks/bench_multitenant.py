"""Multi-tenant scheduling benchmark (``BENCH_multitenant.json``).

Three question groups, each with recorded acceptance gates (ISSUE 7):

* **scale** — ~100 tenants on one shared heterogeneous cluster must
  schedule in seconds, with the shared-load invariant intact and no
  tenant below its guaranteed floor (``fair_slice_floors`` — the
  warm-start baseline, re-verified here against an independent
  recomputation). Two cluster variants: roomy machines (most fair
  slices host their tenant — the no-regression gate is non-vacuous)
  and paper-capacity machines (thin slices exercise the MET-deferral
  path).
* **batching** — scoring candidate rows of many tenants through one
  tenant-batched per-row-capacity call vs the explicit per-tenant
  residual loop: reported speedup plus max |diff| (parity is the test
  suite's job; the bench records it anyway).
* **runtime** — a small fleet executes its traces on the shared capacity
  grid; per-tenant satisfaction and arbiter admissions are recorded.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    ScheduleState,
    diamond_topology,
    jain_index,
    linear_topology,
    paper_cluster,
    rolling_count_topology,
    star_topology,
)
from repro.multitenant import (
    MultiTenantRuntime,
    MultiTenantState,
    Tenant,
    TenantSet,
    TenantBatchScorer,
    compile_tenant_traces,
    fair_slice_floors,
    schedule_tenants,
)
from repro.runtime_stream import TraceSpec

SEED = 0
TOPOS = [linear_topology, diamond_topology, star_topology, rolling_count_topology]

# Large-fleet knobs: a light warm refine and one structural attempt per
# tenant keep 100 tenants in single-digit seconds; the guarantees
# (invariant, fair-slice no-regression) do not depend on these budgets.
FLEET_KW = dict(warm_refine_rounds=2, structure_attempts=1, refine_moves=1)


def _fleet(n_tenants: int, rng: np.random.Generator) -> list[Tenant]:
    tenants = []
    for i in range(n_tenants):
        tenants.append(
            Tenant(
                name=f"t{i:03d}",
                utg=TOPOS[i % len(TOPOS)](),
                target_rate=float(rng.uniform(20, 200)),
                priority=float(rng.choice([1.0, 1.0, 2.0, 4.0])),
            )
        )
    return tenants


def _no_regression(tenants, cluster, ms) -> tuple[bool, int]:
    """Re-verify the warm-start guarantee against independently recomputed
    floors (``fair_slice_floors`` with the same refine budget the run
    used): every tenant's solo rate on its fair slice of the MET-reduced
    working capacity, 0 for deferred tenants — theirs holds trivially, so
    only floors > 0 count as non-vacuous. Returns (all_ok, n_nonvacuous)."""
    floors = fair_slice_floors(
        tenants, cluster, warm_refine_rounds=FLEET_KW["warm_refine_rounds"]
    )
    rates = np.array([ms.allocation(t.name).rate for t in tenants])
    ok = bool(np.all(rates >= floors * (1.0 - 1e-6)))
    return ok, int(np.sum(floors > 0.0))


def scale_row(n_tenants: int, counts, cap_scale: float, label: str) -> dict:
    rng = np.random.default_rng(SEED)
    tenants = _fleet(n_tenants, rng)
    cluster = paper_cluster(counts)
    cluster = cluster.with_capacity(cluster.capacity * cap_scale)

    t0 = time.perf_counter()
    ms = schedule_tenants(tenants, cluster, validate=False, **FLEET_KW)
    wall = time.perf_counter() - t0

    states = [
        ScheduleState.from_etg(a.etg, cluster, skew=t.skew)
        for a, t in zip(ms.allocations, tenants)
    ]
    mt = MultiTenantState(TenantSet(tenants), cluster, states, rates=ms.rates)
    feasible = mt.feasible(slack=1e-9)
    no_reg, nonvacuous = _no_regression(tenants, cluster, ms)
    levels = ms.levels
    return {
        "label": label,
        "n_tenants": n_tenants,
        "n_machines": cluster.n_machines,
        "capacity_per_machine": float(cluster.capacity[0]),
        "wall_s": round(wall, 3),
        "rounds": ms.rounds,
        "candidates_evaluated": ms.candidates_evaluated,
        "total_rate": round(float(ms.rates.sum()), 3),
        "min_level": float(levels.min()),
        "median_level": float(np.median(levels)),
        "jain_index_levels": round(jain_index(levels), 4),
        "feasible": bool(feasible),
        "no_regression_vs_fair_slice": bool(no_reg),
        "nonvacuous_baselines": nonvacuous,
        "under_60s": bool(wall < 60.0),
    }


def batching_row(n_tenants: int = 20) -> dict:
    """Tenant-batched met-fold scoring vs the per-tenant residual loop."""
    rng = np.random.default_rng(SEED)
    tenants = _fleet(n_tenants, rng)
    cluster = paper_cluster((4, 4, 4))
    ms = schedule_tenants(tenants, cluster, **FLEET_KW)
    states = [
        ScheduleState.from_etg(a.etg, cluster) for a in ms.allocations
    ]
    mt = MultiTenantState(
        TenantSet(tenants), cluster, states, rates=ms.rates * 0.9
    )
    m = cluster.n_machines
    sweeps = []
    for t, st in enumerate(mt.states):
        base = st.task_machine()
        rows = []
        for col in range(base.shape[0]):
            for dest in range(m):
                if dest == base[col]:
                    continue
                row = base.copy()
                row[col] = dest
                rows.append(row)
        sweeps.append((t, np.stack(rows)))
    n_rows = sum(r.shape[0] for _, r in sweeps)

    scorer = TenantBatchScorer(mt, backend="auto")
    t0 = time.perf_counter()
    batched = scorer.score(sweeps)
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    looped = [scorer.reference_scores(t, rows) for t, rows in sweeps]
    t_loop = time.perf_counter() - t0

    diff = max(
        float(np.max(np.abs(b[0] - l[0]))) if b[0].size else 0.0
        for b, l in zip(batched, looped)
    )
    return {
        "n_tenants": n_tenants,
        "candidate_rows": n_rows,
        "batched_s": round(t_batched, 4),
        "per_tenant_loop_s": round(t_loop, 4),
        "speedup": round(t_loop / max(t_batched, 1e-9), 2),
        "max_abs_rate_diff": diff,
        "parity_1e9": bool(diff <= 1e-9),
    }


def runtime_row() -> dict:
    tenants = TenantSet(
        [
            Tenant(name="alice", utg=linear_topology(), target_rate=8.0),
            Tenant(name="bob", utg=diamond_topology(), target_rate=8.0, priority=2.0),
            Tenant(name="carol", utg=star_topology(), target_rate=6.0),
        ]
    )
    cluster = paper_cluster((2, 2, 2))
    ms = schedule_tenants(list(tenants), cluster)
    specs = [
        TraceSpec(name=t.name, n_windows=96, base_rate=0.8 * ms.rates[i])
        for i, t in enumerate(tenants)
    ]
    mtrace = compile_tenant_traces(tenants, specs, cluster, seed=SEED)
    rt = MultiTenantRuntime(ms, tenants, cluster, mtrace)
    t0 = time.perf_counter()
    res = rt.run(online=True, moves_per_period=4)
    wall = time.perf_counter() - t0
    admitted = [int(ok) for *_rest, ok in res.arbiter_log]
    return {
        "n_tenants": len(tenants),
        "n_windows": mtrace.n_windows,
        "wall_s": round(wall, 3),
        "allocated_rates": [round(float(r), 3) for r in ms.rates],
        "satisfaction": [round(float(s), 3) for s in res.satisfaction],
        "arbiter_requests": len(res.arbiter_log),
        "arbiter_admitted": int(sum(admitted)),
        "all_served": bool(np.all(res.satisfaction > 0.0)),
    }


def main(json_path: str | None = None) -> None:
    rows = {
        "scale": [
            scale_row(100, (20, 30, 40), cap_scale=4.0, label="roomy_90x400"),
            scale_row(100, (20, 30, 40), cap_scale=1.0, label="paper_90x100"),
        ],
        "batching": batching_row(),
        "runtime": runtime_row(),
    }
    for row in rows["scale"]:
        emit(
            f"multitenant_scale_{row['label']}",
            row["wall_s"] * 1e6,
            f"tenants={row['n_tenants']};rounds={row['rounds']};"
            f"feasible={row['feasible']};no_regression={row['no_regression_vs_fair_slice']};"
            f"jain={row['jain_index_levels']};under_60s={row['under_60s']}",
        )
    b = rows["batching"]
    emit(
        "multitenant_batching",
        b["batched_s"] * 1e6,
        f"rows={b['candidate_rows']};speedup={b['speedup']};parity={b['parity_1e9']}",
    )
    r = rows["runtime"]
    emit(
        "multitenant_runtime",
        r["wall_s"] * 1e6,
        f"tenants={r['n_tenants']};satisfaction={r['satisfaction']};"
        f"all_served={r['all_served']}",
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", default=None, help="write BENCH_multitenant.json here")
    args = parser.parse_args()
    main(json_path=args.json)
