"""Calibrate the closed-form scorer's NumPy/JAX dispatch crossovers.

``max_stable_rate_batch`` / ``ScheduleState.score_task_machine_batch`` can
run the eq. 5 closed form either through NumPy's sequential ``np.add.at``
accumulation (the bit-exact reference) or through the scatter-free jitted
JAX kernel (one-hot contraction, ~1e-15 relative agreement). The JAX path
pays a fixed dispatch cost per call and does B*T*m work versus NumPy's
B*T, so ``backend="auto"`` needs per-regime crossovers: element floors
(below which NumPy wins) plus a machine-count gate (above which the dense
contraction loses on CPU).

This benchmark times both backends over (scenario × regime × batch size):
scenarios span paper-realistic clusters (3 / 6 / 15 machines) plus the
wide-cluster ``stress`` shape (180 machines, the paper's 20/70/90 large
scenario), and each scenario is swept through all three kernel regimes —
``shared`` ((T,) task maps), ``per_row`` ((B, T) maps, lockstep growth
sweeps) and ``skew`` (realized fields-grouping rates on a keyed topology).
Everything lands in ``BENCH_dispatch.json``.

Recorded calibration (2-core CPU-only container): the scatter-free kernel
beats NumPy 1.5-6x on every realistic scenario once the sweep clears the
per-regime element floors (``simulator._CLOSED_FORM_AUTO_THRESHOLDS``), so
``"auto"`` picks JAX there; on the 180-machine stress shape the m-fold
contraction overhead flips the verdict at every size, which is exactly
what ``simulator._AUTO_MAX_MACHINES`` encodes. Re-run on new hardware and
override via ``REPRO_CLOSED_FORM_JAX_THRESHOLD`` (all regimes) or
``REPRO_CLOSED_FORM_JAX_THRESHOLD_{SHARED,PER_ROW,SKEW}`` if the picture
differs.

``--check`` replays ``resolve_closed_form_backend`` over a recorded grid
and fails if "auto" ever selects a backend slower than the recorded NumPy
time — the CI smoke gate for dispatch regressions.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    keyed_rolling_count_topology,
    paper_cluster,
    schedule,
    wide_fanout_topology,
)
from repro.core.schedule_state import ScheduleState
from repro.core.simulator import (
    _AUTO_MAX_MACHINES,
    _AUTO_MAX_WORK,
    _CLOSED_FORM_AUTO_THRESHOLDS,
    resolve_closed_form_backend,
)

# Batch sizes swept per (scenario, regime) — rows per scored sweep.
BATCH_SIZES = (1, 8, 64, 256, 1024, 4096, 16384)
# (cluster counts, label, max batch). The three realistic scenarios track
# paper-scale clusters where the scatter-free path should win; ``stress``
# keeps the 20/70/90 wide cluster as an honest diagnostic of where the
# dense contraction loses (capped batch: the losing kernel is slow).
SCENARIOS = (
    ((1, 1, 1), "small", 16384),
    ((2, 2, 2), "medium", 16384),
    ((4, 5, 6), "large", 16384),
    ((20, 70, 90), "stress", 4096),
)
REGIMES = ("shared", "per_row", "skew")


def _skew_state(cluster) -> ScheduleState:
    """A ScheduleState carrying a realized fields-grouping skew model
    (keyed topology, key realization drawn at trace compile time)."""
    from repro.runtime_stream import StreamExecutor, TraceSpec

    utg = keyed_rolling_count_topology(n_keys=16, zipf_s=1.5)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0).etg
    probe = StreamExecutor(
        etg, cluster, TraceSpec(name="probe", n_windows=2, base_rate=1.0), seed=5
    )
    return ScheduleState.from_etg(etg, cluster, skew=probe.skew_model_at(0))


def _time_backend(state: ScheduleState, tm: np.ndarray, backend: str,
                  n_instances: np.ndarray | None = None,
                  iters: int = 5) -> float:
    """Median wall time (s) of one scored sweep (post-warmup, so the JAX
    number is steady-state dispatch, not compilation)."""
    for _ in range(2):
        state.score_task_machine_batch(tm, n_instances=n_instances,
                                       backend=backend)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state.score_task_machine_batch(tm, n_instances=n_instances,
                                       backend=backend)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_dispatch() -> dict:
    rng = np.random.default_rng(0)
    jax_available = resolve_closed_form_backend("jax") == "jax"
    grid = []
    crossovers = []
    auto_picks_jax = False
    for counts, label, max_batch in SCENARIOS:
        cluster = paper_cluster(counts)
        m = cluster.n_machines
        sched = schedule(wide_fanout_topology(6), cluster,
                         r0=1.0, rate_epsilon=1.0)
        plain = ScheduleState.from_etg(sched.etg, cluster)
        skewed = _skew_state(cluster)
        for regime in REGIMES:
            state = skewed if regime == "skew" else plain
            T = int(state.n_instances.sum())
            n = state.utg.n_components
            rows = []
            for B in BATCH_SIZES:
                if B > max_batch:
                    continue
                tm = rng.integers(0, m, size=(B, T))
                n_inst = (
                    np.tile(state.n_instances, (B, 1))
                    if regime == "per_row"
                    else None
                )
                t_np = _time_backend(state, tm, "numpy", n_inst)
                elements = B * T
                auto = resolve_closed_form_backend(
                    "auto", elements, regime=regime, n_machines=m
                )
                auto_picks_jax = auto_picks_jax or auto == "jax"
                row = {
                    "scenario": label,
                    "regime": regime,
                    "machines": m,
                    "tasks": T,
                    "components": n,
                    "batch": B,
                    "elements": elements,
                    "numpy_us": round(t_np * 1e6, 1),
                    "auto_backend": auto,
                }
                if jax_available:
                    t_jax = _time_backend(state, tm, "jax", n_inst)
                    row["jax_us"] = round(t_jax * 1e6, 1)
                    row["jax_speedup"] = round(t_np / max(t_jax, 1e-12), 2)
                rows.append(row)
            grid.extend(rows)
            if jax_available:
                # Crossover = smallest sweep from which JAX wins by a real
                # margin (10%+) at that size and every larger one — a single
                # noisy win on a microsecond-scale batch is not a crossover.
                for i, row in enumerate(rows):
                    if all(r["jax_speedup"] >= 1.1 for r in rows[i:]):
                        crossovers.append(
                            {
                                "scenario": label,
                                "regime": regime,
                                "machines": m,
                                "tasks": T,
                                "crossover_elements": row["elements"],
                            }
                        )
                        break
    return {
        "jax_available": jax_available,
        "grid": grid,
        "crossovers": crossovers,
        "auto_thresholds": dict(_CLOSED_FORM_AUTO_THRESHOLDS),
        "auto_max_machines": _AUTO_MAX_MACHINES,
        "auto_max_work": _AUTO_MAX_WORK,
        "auto_picks_jax": auto_picks_jax,
    }


def check(json_path: str) -> int:
    """Smoke gate: replay auto dispatch over a recorded grid; any pick that
    the recording shows losing to NumPy is a failure. Run without
    REPRO_CLOSED_FORM_JAX_THRESHOLD* overrides."""
    with open(json_path) as f:
        recorded = json.load(f)
    failures = []
    picked_jax = 0
    for row in recorded["grid"]:
        if "jax_us" not in row:
            continue
        auto = resolve_closed_form_backend(
            "auto", row["elements"], regime=row["regime"],
            n_machines=row["machines"],
        )
        if auto == "jax":
            picked_jax += 1
            if row["jax_us"] > row["numpy_us"]:
                failures.append(
                    f"auto picked jax but recorded jax_us={row['jax_us']} > "
                    f"numpy_us={row['numpy_us']} at {row['scenario']}/"
                    f"{row['regime']} B={row['batch']} ({row['elements']} el)"
                )
    if recorded.get("jax_available") and picked_jax == 0:
        failures.append("auto never picked jax anywhere on the recorded grid")
    for msg in failures:
        print(f"DISPATCH-CHECK FAIL: {msg}")
    if not failures:
        print(
            f"dispatch check ok: {picked_jax} grid points route to jax, "
            "none slower than numpy"
        )
    return 1 if failures else 0


def main(json_path: str | None = None) -> None:
    out = bench_dispatch()
    for c in out["crossovers"]:
        emit(
            f"dispatch_crossover_{c['scenario']}_{c['regime']}",
            float(c["crossover_elements"]),
            f"tasks={c['tasks']};machines={c['machines']}",
        )
    if not out["crossovers"]:
        emit(
            "dispatch_crossover",
            0.0,
            f"jax_available={out['jax_available']};"
            f"auto_picks_jax={out['auto_picks_jax']};"
            "numpy_wins_all_measured_sizes",
        )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", default=None,
                        help="write BENCH_dispatch.json here")
    parser.add_argument("--check", default=None, metavar="JSON",
                        help="validate auto dispatch against a recorded grid")
    args = parser.parse_args()
    if args.check:
        sys.exit(check(args.check))
    main(json_path=args.json)
