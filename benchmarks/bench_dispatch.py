"""Calibrate the closed-form scorer's NumPy/JAX dispatch crossover.

``max_stable_rate_batch`` / ``ScheduleState.score_task_machine_batch`` can
run the eq. 5 closed form either through NumPy's sequential ``np.add.at``
accumulation (the bit-exact reference) or through the jitted JAX
scatter-add kernel (~1e-15 relative agreement). The JAX path pays a fixed
dispatch cost per call but scales better, so ``backend="auto"`` needs a
crossover point: below it NumPy wins, above it JAX does.

This benchmark times both backends over a (task count × batch size) grid
that brackets the real workloads — small-cluster refine sweeps (tens of
rows × ~10 tasks) up to the paper's large-cluster RELOCATE+SWAP chunks
(16 384 rows × ~650 tasks ≈ 10 M elements) — locates the crossover in
``B * T`` elements per (task-count) row of the grid, and records everything
in ``BENCH_dispatch.json``.

Recorded calibration (2-core CPU-only container): the jitted kernel is
0.2-0.4× NumPy at *every* grid point — XLA's CPU scatter-add is serial —
so ``"auto"`` resolves to NumPy whenever JAX's default backend is the CPU,
and the ``simulator._CLOSED_FORM_AUTO_THRESHOLD`` element floor only
engages on accelerator backends. Re-run this benchmark on new hardware and
set ``REPRO_CLOSED_FORM_JAX_THRESHOLD`` (elements) if the picture differs.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core import paper_cluster, schedule, wide_fanout_topology
from repro.core.schedule_state import ScheduleState
from repro.core.simulator import (
    _closed_form_auto_threshold,
    resolve_closed_form_backend,
)

# Batch sizes swept per task count (rows per sweep).
BATCH_SIZES = (1, 8, 64, 256, 1024, 4096, 16384)
# (cluster counts, target tasks label) — spans refine's sweep shapes.
SCENARIOS = (
    ((1, 1, 1), "small"),
    ((2, 2, 2), "medium"),
    ((20, 70, 90), "large"),
)


def _time_backend(state: ScheduleState, tm: np.ndarray, backend: str,
                  iters: int = 5) -> float:
    """Median wall time (s) of one scored sweep (post-warmup, so the JAX
    number is steady-state dispatch, not compilation)."""
    for _ in range(2):
        state.score_task_machine_batch(tm, backend=backend)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state.score_task_machine_batch(tm, backend=backend)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_dispatch() -> dict:
    rng = np.random.default_rng(0)
    jax_available = resolve_closed_form_backend("jax") == "jax"
    grid = []
    crossovers = []
    for counts, label in SCENARIOS:
        cluster = paper_cluster(counts)
        topo = wide_fanout_topology(6)
        sched = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0)
        state = ScheduleState.from_etg(sched.etg, cluster)
        T = int(state.n_instances.sum())
        rows = []
        for B in BATCH_SIZES:
            tm = rng.integers(0, cluster.n_machines, size=(B, T))
            t_np = _time_backend(state, tm, "numpy")
            row = {
                "scenario": label,
                "tasks": T,
                "batch": B,
                "elements": B * T,
                "numpy_us": round(t_np * 1e6, 1),
            }
            if jax_available:
                t_jax = _time_backend(state, tm, "jax")
                row["jax_us"] = round(t_jax * 1e6, 1)
                row["jax_speedup"] = round(t_np / max(t_jax, 1e-12), 2)
            rows.append(row)
        grid.extend(rows)
        if jax_available:
            # Crossover = smallest sweep from which JAX wins by a real
            # margin (10%+) at that size and every larger one — a single
            # noisy win on a microsecond-scale batch is not a crossover.
            for i, row in enumerate(rows):
                if all(r["jax_speedup"] >= 1.1 for r in rows[i:]):
                    crossovers.append(
                        {
                            "scenario": label,
                            "tasks": T,
                            "crossover_elements": row["elements"],
                        }
                    )
                    break
    threshold = _closed_form_auto_threshold()
    return {
        "jax_available": jax_available,
        "grid": grid,
        "crossovers": crossovers,
        "auto_threshold_elements": (
            None if np.isinf(threshold) else int(threshold)
        ),
        "auto_picks_jax": bool(np.isfinite(threshold)),
    }


def main(json_path: str | None = None) -> None:
    out = bench_dispatch()
    for c in out["crossovers"]:
        emit(
            f"dispatch_crossover_{c['scenario']}",
            float(c["crossover_elements"]),
            f"tasks={c['tasks']};threshold={out['auto_threshold_elements']}",
        )
    if not out["crossovers"]:
        emit(
            "dispatch_crossover",
            0.0,
            f"jax_available={out['jax_available']};"
            f"auto_picks_jax={out['auto_picks_jax']};"
            "numpy_wins_all_measured_sizes",
        )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", default=None,
                        help="write BENCH_dispatch.json here")
    args = parser.parse_args()
    main(json_path=args.json)
