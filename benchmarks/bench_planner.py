"""Beyond-paper — heterogeneous fleet planning for LM serving.

The paper's algorithm applied to its TPU incarnation: plan pipeline-stage
replicas for each assigned architecture over a mixed v5e/v4/lite fleet and
compare the admission rate against naive round-robin placement.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs import ARCHS, get_config
from repro.sched.fleet import DevicePool, Fleet, TPU_LITE, TPU_V4, TPU_V5E
from repro.sched.planner import plan

FLEET = Fleet(pools=(
    DevicePool(chip=TPU_V5E, count=8, chips_per_group=16, name="v5e"),
    DevicePool(chip=TPU_V4, count=4, chips_per_group=8, name="v4"),
    DevicePool(chip=TPU_LITE, count=12, chips_per_group=4, name="lite"),
))


def main() -> None:
    for arch in ARCHS:
        cfg = get_config(arch)
        t0 = time.perf_counter()
        p = plan(cfg, FLEET, n_stages=4)
        dt = time.perf_counter() - t0
        gain = (p.tokens_per_s / max(p.baseline_tokens_per_s, 1e-9) - 1) * 100
        emit(
            f"planner_{arch}",
            dt * 1e6,
            f"admission={p.tokens_per_s:,.0f}tok/s;"
            f"rr_baseline={p.baseline_tokens_per_s:,.0f};gain={gain:.0f}%;"
            f"iters={p.iterations}",
        )


if __name__ == "__main__":
    main()
