"""§3 — scheduler wall-time vs the exhaustive optimal search.

The paper reports the optimal scheduler checking 27 405 possibilities in
~18 hours on a 4-socket Xeon server. Our batched closed-form evaluator
(beyond-paper: multiset placement collapse + vectorized max-stable-rate
scoring) covers a *larger* design space in seconds on one CPU; the
proposed heuristic is another 2-3 orders faster.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import linear_topology, optimal_schedule, paper_cluster, schedule
from repro.core.refine import refine


def main() -> None:
    cluster = paper_cluster((1, 1, 1))
    topo = linear_topology()

    t0 = time.perf_counter()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    refine(sched.etg, cluster)
    t_heur = time.perf_counter() - t0

    t0 = time.perf_counter()
    opt = optimal_schedule(topo, cluster, max_total_tasks=10)
    t_opt = time.perf_counter() - t0

    emit(
        "sec3_scheduler_walltime",
        t_heur * 1e6,
        f"heuristic={t_heur*1e3:.1f}ms;optimal={t_opt:.2f}s;"
        f"candidates={opt.candidates_evaluated};"
        f"paper_optimal=18h@27405cands;"
        f"speedup_vs_paper={(18*3600)/max(t_opt,1e-9):,.0f}x",
    )


if __name__ == "__main__":
    main()
