"""§3 — scheduler wall-time vs the exhaustive optimal search, plus the
scheduler-engine perf baseline (``BENCH_sched.json``).

The paper reports the optimal scheduler checking 27 405 possibilities in
~18 hours on a 4-socket Xeon server. Our batched closed-form evaluator
(beyond-paper: multiset placement collapse + type-symmetry pruning +
vectorized max-stable-rate scoring) covers a *larger* design space in
seconds on one CPU; the proposed heuristic is another 2-3 orders faster.

``BENCH_sched.json`` records the perf trajectory future PRs regress
against: large-scenario (20/70/90 machines, 478 tasks) ``schedule()`` wall
time for the reference vs incremental engines (with an identity check on
the resulting schedule), and ``simulate_batch`` placements/sec for the
NumPy and JAX backends.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    linear_topology,
    optimal_schedule,
    paper_cluster,
    schedule,
    simulate_batch,
)
from repro.core.refine import refine
from repro.core.simulator import _jax_available

LARGE = (20, 70, 90)
SIM_BATCH = 2048


def bench_engines(skip_reference: bool = False) -> dict:
    """Large-scenario schedule() wall time: reference vs incremental."""
    cluster = paper_cluster(LARGE)
    topo = linear_topology()

    t0 = time.perf_counter()
    inc = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="incremental")
    t_inc = time.perf_counter() - t0

    out = {
        "scenario": "large_linear_20_70_90",
        "tasks": int(inc.etg.total_tasks),
        "iterations": inc.iterations,
        "rate": inc.rate,
        "incremental_s": round(t_inc, 4),
    }
    if not skip_reference:
        t0 = time.perf_counter()
        ref = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="reference")
        t_ref = time.perf_counter() - t0
        out["reference_s"] = round(t_ref, 4)
        out["speedup"] = round(t_ref / max(t_inc, 1e-9), 1)
        out["identical_schedule"] = bool(
            ref.rate == inc.rate
            and np.array_equal(ref.etg.n_instances, inc.etg.n_instances)
            and np.array_equal(ref.etg.task_machine(), inc.etg.task_machine())
        )
    return out


def bench_sim_backends() -> dict:
    """simulate_batch placements/sec, NumPy vs JAX, medium scenario."""
    cluster = paper_cluster((10, 10, 10))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=1.0).etg
    rng = np.random.default_rng(0)
    tm = rng.integers(0, cluster.n_machines, size=(SIM_BATCH, etg.total_tasks))
    r0 = 60.0

    t0 = time.perf_counter()
    simulate_batch(etg, cluster, tm, r0, backend="numpy")
    t_np = time.perf_counter() - t0
    out = {
        "batch": SIM_BATCH,
        "tasks": int(etg.total_tasks),
        "numpy_placements_per_s": round(SIM_BATCH / t_np, 1),
    }
    if _jax_available():
        simulate_batch(etg, cluster, tm, r0, backend="jax")  # compile
        t0 = time.perf_counter()
        simulate_batch(etg, cluster, tm, r0, backend="jax")
        t_jax = time.perf_counter() - t0
        out["jax_placements_per_s"] = round(SIM_BATCH / t_jax, 1)
        out["jax_speedup"] = round(t_np / t_jax, 1)
    return out


def main(json_path: str | None = None, skip_reference: bool = False) -> None:
    cluster = paper_cluster((1, 1, 1))
    topo = linear_topology()

    t0 = time.perf_counter()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    refine(sched.etg, cluster)
    t_heur = time.perf_counter() - t0

    t0 = time.perf_counter()
    opt = optimal_schedule(topo, cluster, max_total_tasks=10)
    t_opt = time.perf_counter() - t0

    emit(
        "sec3_scheduler_walltime",
        t_heur * 1e6,
        f"heuristic={t_heur*1e3:.1f}ms;optimal={t_opt:.2f}s;"
        f"candidates={opt.candidates_evaluated};"
        f"paper_optimal=18h@27405cands;"
        f"speedup_vs_paper={(18*3600)/max(t_opt,1e-9):,.0f}x",
    )

    engines = bench_engines(skip_reference=skip_reference)
    emit(
        "sched_engine_large",
        engines["incremental_s"] * 1e6,
        ";".join(f"{k}={v}" for k, v in engines.items() if k != "incremental_s"),
    )
    sim = bench_sim_backends()
    emit(
        "sim_batch_backends",
        0.0,
        ";".join(f"{k}={v}" for k, v in sim.items()),
    )

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"schedule": engines, "simulate_batch": sim}, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", default=None, help="write BENCH_sched.json here")
    parser.add_argument(
        "--skip-reference",
        action="store_true",
        help="skip the ~12-25 s reference-engine timing (noisy CI runners)",
    )
    args = parser.parse_args()
    main(json_path=args.json, skip_reference=args.skip_reference)
