"""Deterministic multi-tenant scheduling tests (no hypothesis required).

The hypothesis suite in ``test_multitenant_properties.py`` explores the
same invariants over random fleets; this file pins them on fixed
scenarios so the fast local tier (and coverage) exercises the package
even when hypothesis is not installed.
"""

import numpy as np
import pytest

from repro.core import (
    ScheduleState,
    diamond_topology,
    fairness_levels,
    jain_index,
    linear_topology,
    paper_cluster,
    refine,
    rolling_count_topology,
    schedule,
    star_topology,
)
from repro.multitenant import (
    MultiTenantRuntime,
    MultiTenantState,
    Tenant,
    TenantSet,
    compile_tenant_traces,
    fair_shares,
    schedule_tenants,
)
from repro.runtime_stream import TraceSpec


def _three_tenants():
    return [
        Tenant(name="alice", utg=linear_topology(), target_rate=10.0, priority=2.0),
        Tenant(name="bob", utg=diamond_topology(), target_rate=30.0, priority=1.0),
        Tenant(name="carol", utg=star_topology(), target_rate=10.0, priority=1.0),
    ]


def _fair_slice_rate(tenant, cluster, share):
    sliced = cluster.with_capacity(cluster.capacity * share)
    sched = schedule(tenant.utg, sliced, r0=1.0, rate_epsilon=0.5)
    ref = refine(sched.etg, sliced, skew=tenant.skew)
    st = ScheduleState.from_etg(ref.etg, cluster, skew=tenant.skew)
    if np.all(st.met_load + ref.rate * st.var_load <= sliced.capacity + 1e-9):
        return ref.rate
    return 0.0


def test_solo_bit_identical_to_single_tenant_pipeline():
    """N == 1 is the stock schedule() + refine() pipeline, bit-for-bit."""
    cluster = paper_cluster((2, 2, 2))
    utg = rolling_count_topology()
    ms = schedule_tenants(
        [Tenant(name="only", utg=utg, target_rate=5.0)], cluster
    )
    sched = schedule(utg, cluster, r0=1.0, rate_epsilon=0.5)
    ref = refine(sched.etg, cluster)
    alloc = ms.allocations[0]
    assert alloc.rate == ref.rate
    assert alloc.etg.task_machine().tolist() == ref.etg.task_machine().tolist()
    assert ms.rounds == 0 and ms.candidates_evaluated == 0


def test_three_tenants_feasible_and_no_regression():
    """Shared-load invariant holds and every tenant gets at least its
    fair-slice solo rate (the warm-start guarantee)."""
    tenants = _three_tenants()
    cluster = paper_cluster((2, 2, 2))
    ms = schedule_tenants(tenants, cluster, validate=True)
    shares = fair_shares(tenants)

    states = [
        ScheduleState.from_etg(a.etg, cluster, skew=t.skew)
        for a, t in zip(ms.allocations, tenants)
    ]
    mt = MultiTenantState(TenantSet(tenants), cluster, states, rates=ms.rates)
    assert mt.feasible(slack=1e-9)

    for tenant, share, alloc in zip(tenants, shares, ms.allocations):
        baseline = _fair_slice_rate(tenant, cluster, share)
        assert alloc.rate >= baseline * (1.0 - 1e-6), tenant.name


def test_determinism_and_submission_order_invariance():
    """Two runs agree bit-for-bit; reversing submission order permutes the
    report but changes no rate and no placement."""
    tenants = _three_tenants()
    cluster = paper_cluster((2, 1, 1))
    a = schedule_tenants(tenants, cluster)
    b = schedule_tenants(tenants, cluster)
    c = schedule_tenants(list(reversed(tenants)), cluster)
    for t in tenants:
        x, y, z = a.allocation(t.name), b.allocation(t.name), c.allocation(t.name)
        assert x.rate == y.rate == z.rate
        assert (
            x.etg.task_machine().tolist()
            == y.etg.task_machine().tolist()
            == z.etg.task_machine().tolist()
        )


def test_thin_slice_tenants_defer_and_still_get_served():
    """A dominant priority squeezes co-tenants' fair slices below one
    instance's MET: they defer to rate-0 warm starts, the ensemble stays
    feasible, and the water loop still raises them off zero when the big
    tenant leaves head room."""
    tenants = [
        Tenant(name="whale", utg=diamond_topology(), target_rate=50.0, priority=500.0)
    ] + [
        Tenant(name=f"shrimp{i}", utg=linear_topology(), target_rate=5.0)
        for i in range(4)
    ]
    cluster = paper_cluster((2, 2, 2))
    shares = fair_shares(tenants)
    assert shares[0] > 0.99  # shrimp slices are genuinely sub-MET thin
    ms = schedule_tenants(tenants, cluster, validate=True)
    assert all(a.rate >= 0.0 for a in ms.allocations)
    assert ms.allocation("whale").rate > 0.0
    # The whale cannot saturate 6 machines alone; shrimps pick up slack.
    assert sum(ms.allocation(f"shrimp{i}").rate for i in range(4)) > 0.0


def test_met_oversubscribed_fleet_raises():
    """A fleet whose fixed MET alone cannot fit the cluster is rejected
    with a clear error, not a silently infeasible allocation."""
    tenants = [
        Tenant(name=f"t{i:02d}", utg=star_topology(), target_rate=5.0)
        for i in range(40)
    ]
    cluster = paper_cluster((1, 1, 1))
    tiny = cluster.with_capacity(np.full(cluster.n_machines, 6.0))
    with pytest.raises(ValueError, match="MET load alone"):
        schedule_tenants(tenants, tiny)


def test_fairness_metrics():
    rates = np.array([4.0, 4.0, 1.0])
    targets = np.array([8.0, 8.0, 8.0])
    lv = fairness_levels(rates, targets)
    np.testing.assert_allclose(lv, [0.5, 0.5, 0.125])
    lv_w = fairness_levels(rates, targets, priorities=np.array([4.0, 4.0, 1.0]))
    np.testing.assert_allclose(lv_w, [0.125, 0.125, 0.125])
    assert jain_index(np.ones(5)) == pytest.approx(1.0)
    assert jain_index(np.array([1.0, 0.0, 0.0])) == pytest.approx(1.0 / 3.0)
    assert jain_index(np.zeros(3)) == 1.0


def test_runtime_shared_capacity_and_arbiter():
    """Two tenants execute their traces against residually priced
    capacity; the shared arbiter ledger records at most the per-tenant
    migration budget per period."""
    tenants = TenantSet(
        [
            Tenant(name="alice", utg=linear_topology(), target_rate=6.0),
            Tenant(name="bob", utg=diamond_topology(), target_rate=6.0),
        ]
    )
    cluster = paper_cluster((2, 2, 2))
    ms = schedule_tenants(list(tenants), cluster)
    specs = [
        TraceSpec(name="alice", n_windows=24, base_rate=min(4.0, ms.rates[0])),
        TraceSpec(name="bob", n_windows=24, base_rate=min(4.0, ms.rates[1])),
    ]
    mtrace = compile_tenant_traces(tenants, specs, cluster, seed=7)
    assert mtrace.capacity.shape == (24, cluster.n_machines)

    rt = MultiTenantRuntime(ms, tenants, cluster, mtrace)
    loads = rt.planned_loads()
    assert loads.shape == (2, 24, cluster.n_machines)
    # Planned loads are demand-capped by the offered trace.
    assert np.all(loads >= 0.0)

    res = rt.run(online=True, moves_per_period=4)
    assert res.names == ("alice", "bob")
    assert res.satisfaction.shape == (2,)
    assert all(r.n_windows == 24 for r in res.results)
    # Per-tenant budgets: admitted moves within one period never exceed
    # the arbiter budget, for any tenant.
    admitted: dict[tuple[str, int], int] = {}
    for tenant, window, moves, ok in res.arbiter_log:
        if ok:
            key = (tenant, window // 10)
            admitted[key] = admitted.get(key, 0) + moves
    assert all(v <= 4 for v in admitted.values())


def test_runtime_rejects_per_tenant_capacity_events():
    from repro.runtime_stream import machine_slowdown

    tenants = TenantSet(
        [Tenant(name="a", utg=linear_topology(), target_rate=4.0)]
    )
    cluster = paper_cluster((1, 1, 1))
    spec = TraceSpec(
        name="a",
        n_windows=8,
        base_rate=2.0,
        events=(machine_slowdown(0, factor=0.5, start=2),),
    )
    with pytest.raises(ValueError, match="capacity events"):
        compile_tenant_traces(tenants, [spec], cluster)
