"""Substrate tests: optimizer, checkpointing (incl. crash-restart), data
pipeline determinism, trainer fault tolerance, compression, planner."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data.pipeline import MemmapDataset, Prefetcher, SyntheticLM, write_corpus
from repro.optim import adamw, compression
from repro.runtime.trainer import Trainer, TrainerConfig


# ----------------------------------------------------------------- optim

def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_global_norm():
    grads = {"a": jnp.full((4,), 100.0), "b": jnp.full((4,), -100.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(8 * 100.0 ** 2), rel=1e-5)
    assert adamw.global_norm(clipped) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    lr = adamw.cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(lr(jnp.array(0))) == 0.0
    assert float(lr(jnp.array(10))) == pytest.approx(1.0)
    assert float(lr(jnp.array(100))) == pytest.approx(0.1, rel=1e-3)


def test_int8_error_feedback_unbiased():
    g = {"w": jnp.array(np.random.default_rng(0).normal(size=512), jnp.float32)}
    r = compression.init_residual(g)
    acc = jnp.zeros(512)
    exact = jnp.zeros(512)
    for _ in range(50):
        q, s, r = compression.quantize_ef(g, r)
        acc = acc + compression.dequantize(q, s)["w"]
        exact = exact + g["w"]
    # error feedback keeps the accumulated estimate close to exact
    rel = float(jnp.abs(acc - exact).max() / jnp.abs(exact).max())
    assert rel < 0.01


# ------------------------------------------------------------ checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 3), jnp.bfloat16)},
            "lst": [jnp.zeros(2), jnp.ones(2)]}
    store.save(tmp_path, 7, tree)
    restored, step = store.restore(tmp_path, tree)
    assert step == 7
    assert np.allclose(restored["a"], tree["a"])
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_partial_write_ignored(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    store.save(tmp_path, 1, tree)
    # simulate a crashed writer: a .tmp directory must be invisible
    (tmp_path / "step_00000002.tmp").mkdir()
    assert store.latest_step(tmp_path) == 1


def test_checkpoint_retention(tmp_path):
    tree = {"a": jnp.zeros(1)}
    for s in range(5):
        store.save(tmp_path, s, tree)
    store.retain(tmp_path, keep=2)
    assert store.latest_step(tmp_path) == 4
    assert not (tmp_path / "step_00000000").exists()


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(tmp_path, keep=2)
    for s in range(3):
        ck.save(s, {"w": jnp.full((4,), float(s))})
    ck.close()
    restored, step = store.restore(tmp_path, {"w": jnp.zeros(4)})
    assert step == 2
    assert np.allclose(restored["w"], 2.0)


# ------------------------------------------------------------------ data

def test_synthetic_batches_deterministic():
    ds = SyntheticLM(vocab_size=100, seq_len=16, batch=2, seed=3)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_memmap_dataset_restart_safe(tmp_path):
    write_corpus(tmp_path, n_tokens=4096, vocab_size=64, shard_tokens=1000)
    ds = MemmapDataset(tmp_path, seq_len=16, batch=4, seed=0)
    batches = [ds.next_batch() for _ in range(3)]
    state = ds.state()
    b_next = ds.next_batch()

    ds2 = MemmapDataset(tmp_path, seq_len=16, batch=4, seed=0)
    ds2.seek(state)
    b_resumed = ds2.next_batch()
    assert np.array_equal(b_next["tokens"], b_resumed["tokens"])


def test_prefetcher_preserves_order():
    it = iter([{"i": i} for i in range(20)])
    out = list(Prefetcher(it, depth=3))
    assert [o["i"] for o in out] == list(range(20))


# --------------------------------------------------------------- trainer

def _toy_setup(tmp_path, total=30, ckpt_every=10):
    def init_state():
        return {"params": {"w": jnp.zeros(4)},
                "opt": {"m": jnp.zeros(4), "v": jnp.zeros(4),
                        "step": jnp.zeros((), jnp.int32)}}

    def train_step(state, batch):
        w = state["params"]["w"] + 0.1
        step = state["opt"]["step"] + 1
        return (
            {"params": {"w": w}, "opt": dict(state["opt"], step=step)},
            {"loss": jnp.sum(jnp.square(w - 3.0))},
        )

    data = SyntheticLM(vocab_size=16, seq_len=4, batch=1)
    cfg = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path),
                        ckpt_every=ckpt_every, log_every=1000)
    return cfg, train_step, init_state, data


def test_trainer_runs_and_checkpoints(tmp_path):
    cfg, step_fn, init_state, data = _toy_setup(tmp_path)
    out = Trainer(cfg, step_fn, init_state, data, log=lambda *_: None).run()
    assert out["final_step"] == 30
    assert store.latest_step(tmp_path) == 30


def test_trainer_restart_resumes(tmp_path):
    cfg, step_fn, init_state, data = _toy_setup(tmp_path, total=15, ckpt_every=5)
    Trainer(cfg, step_fn, init_state, data, log=lambda *_: None).run()
    # continue to 30: the new trainer must resume from step 15, not restart
    cfg2, *_ = _toy_setup(tmp_path, total=30, ckpt_every=5)
    out = Trainer(cfg2, step_fn, init_state, data, log=lambda *_: None).run()
    assert out["final_step"] == 30
    final_w = float(np.asarray(out["state"]["params"]["w"])[0])
    assert final_w == pytest.approx(3.0, rel=1e-5)  # 30 steps x 0.1 exactly once


def test_trainer_skips_nan_steps(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        loss = jnp.nan if calls["n"] == 3 else jnp.float32(1.0)
        return state, {"loss": loss}

    cfg, _, init_state, data = _toy_setup(tmp_path, total=6)
    out = Trainer(cfg, step_fn, init_state, data, log=lambda *_: None).run()
    assert out["final_step"] == 6
    assert len(out["losses"]) == 5  # one skipped


# ---------------------------------------------------------------- planner

def test_partition_replicates_on_mesh_without_data_axis():
    """A pure tensor-parallel mesh (no "data"/"pod" axis) must fall back to
    replication — never emit a PartitionSpec naming an absent axis."""
    from jax.sharding import Mesh, PartitionSpec

    from repro.dist import partition

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("model",))
    data_axes, tp = partition.mesh_axes(mesh, cfg=None)
    assert data_axes == ()
    assert tp == "model"
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 8), jnp.int32),
        "mrope_positions": jax.ShapeDtypeStruct((3, 4, 8), jnp.int32),
    }
    specs = partition.batch_specs(batch, mesh, cfg=None)
    assert all(s == PartitionSpec() for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec)))
    # NamedSharding construction must succeed (this is what used to error).
    partition.shardings(specs, mesh)


def test_planner_beats_round_robin_on_heterogeneous_fleet():
    from repro.configs import get_config
    from repro.sched.fleet import DevicePool, Fleet, TPU_LITE, TPU_V4, TPU_V5E
    from repro.sched.planner import plan

    fleet = Fleet(pools=(
        DevicePool(chip=TPU_V5E, count=6, chips_per_group=8, name="v5e"),
        DevicePool(chip=TPU_LITE, count=10, chips_per_group=4, name="lite"),
    ))
    p = plan(get_config("yi-9b"), fleet, n_stages=3)
    assert p.tokens_per_s > p.baseline_tokens_per_s
    assert p.replicas.sum() >= p.n_stages  # every stage placed


def test_elastic_replan_reduces_then_restores():
    from repro.configs import get_config
    from repro.sched.elastic import ElasticController
    from repro.sched.fleet import DevicePool, Fleet, TPU_V5E
    fleet = Fleet(pools=(DevicePool(chip=TPU_V5E, count=8, chips_per_group=8, name="v5e"),))
    ec = ElasticController(get_config("qwen1.5-0.5b"), fleet, n_stages=2)
    r0 = ec.admission_rate
    ec.fail(0, 4)
    assert ec.admission_rate < r0
    ec.restore(0, 4)
    assert ec.admission_rate == pytest.approx(r0, rel=1e-6)
