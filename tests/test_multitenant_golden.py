"""Multi-tenant goldens and batched-scoring parity.

Golden: one frozen 3-tenant scenario pins placements, rates, and the
candidate count — any drift in the water-filling loop, warm start, or
tie-breaking shows up here first.

Parity: the tenant-batched met-fold scoring (``TenantBatchScorer``) must
agree with the explicit per-tenant residual-capacity NumPy loop to
1e-12 relative with identical argmax — and the jitted JAX dispatch must
agree with the NumPy dispatch the same way. Committed rates are scaled
to 0.9x before the parity probes: exactly *at* the allocation the
infeasibility cliff (a fully packed machine a few ulps over capacity)
can legitimately make the two formulations disagree between 0 and a
positive residual, which is a property of saturation, not of the fold.
"""

import numpy as np
import pytest

from repro.core import (
    ScheduleState,
    SkewModel,
    diamond_topology,
    keyed_rolling_count_topology,
    linear_topology,
    paper_cluster,
    star_topology,
)
from repro.multitenant import (
    MultiTenantState,
    Tenant,
    TenantSet,
    TenantBatchScorer,
    schedule_tenants,
)
from repro.runtime_stream import TraceSpec

# ------------------------------------------------------------------ golden

GOLDEN = {
    "alice": (3.317152100242718, [0, 0, 2, 1, 1, 1, 2, 1, 0, 4, 5, 3]),
    "bob": (2.634569447432816, [2, 0, 4, 5, 0, 0, 1, 1, 4, 5, 3, 2]),
    "carol": (0.869261695212773, [1, 2, 0, 2, 0, 4, 3, 3, 3, 3, 2, 4, 5, 4, 1, 5]),
}
GOLDEN_ROUNDS = 10
GOLDEN_CANDIDATES = 43


def _golden_fleet():
    return [
        Tenant(name="alice", utg=linear_topology(), target_rate=10.0, priority=2.0),
        Tenant(name="bob", utg=diamond_topology(), target_rate=30.0, priority=1.0),
        Tenant(name="carol", utg=star_topology(), target_rate=10.0, priority=1.0),
    ]


def test_three_tenant_golden():
    ms = schedule_tenants(_golden_fleet(), paper_cluster((2, 2, 2)))
    assert ms.rounds == GOLDEN_ROUNDS
    assert ms.candidates_evaluated == GOLDEN_CANDIDATES
    for name, (rate, placement) in GOLDEN.items():
        alloc = ms.allocation(name)
        assert alloc.rate == pytest.approx(rate, rel=1e-12), name
        assert alloc.etg.task_machine().tolist() == placement, name


# ------------------------------------------------------------------ parity


def _skewed_tenant(name, cluster, seed=11):
    utg = keyed_rolling_count_topology()
    reals = (
        TraceSpec(name="probe", n_windows=4, base_rate=1.0)
        .compile(cluster, seed=seed, utg=utg)
        .realizations_at(0)
    )
    skew = SkewModel(utg, {e: r.shares for e, r in reals.items()})
    return Tenant(name=name, utg=utg, target_rate=8.0, skew=skew)


def _margin_state(tenants, cluster, margin=0.9):
    """Schedule the fleet, then rebuild the shared state with rates scaled
    to ``margin`` of the allocation (off the infeasibility cliff)."""
    tset = TenantSet(tenants)
    ms = schedule_tenants(tenants, cluster)
    states = [
        ScheduleState.from_etg(a.etg, cluster, skew=t.skew)
        for a, t in zip(ms.allocations, tenants)
    ]
    return MultiTenantState(tset, cluster, states, rates=ms.rates * margin)


def _relocation_sweeps(mt, cap_rows=36):
    """Per tenant, a count-preserving relocation sweep: each task to each
    other machine, truncated to ``cap_rows`` rows for test speed."""
    m = mt.cluster.n_machines
    sweeps = []
    for t, st in enumerate(mt.states):
        base = st.task_machine()
        rows = []
        for col in range(base.shape[0]):
            for dest in range(m):
                if dest == base[col]:
                    continue
                row = base.copy()
                row[col] = dest
                rows.append(row)
        sweeps.append((t, np.stack(rows[:cap_rows])))
    return sweeps


def _fleet_plain():
    return [
        Tenant(name="alice", utg=linear_topology(), target_rate=10.0, priority=2.0),
        Tenant(name="bob", utg=diamond_topology(), target_rate=30.0, priority=1.0),
        Tenant(name="carol", utg=star_topology(), target_rate=10.0, priority=1.0),
    ]


def _fleet_keyed(cluster):
    return [
        Tenant(name="alice", utg=linear_topology(), target_rate=10.0),
        _skewed_tenant("kira", cluster),
    ]


@pytest.mark.parametrize("keyed", [False, True], ids=["plain", "keyed"])
def test_batched_metfold_matches_reference_loop(keyed):
    """Met-fold batched scoring == explicit residual-capacity per-tenant
    NumPy loop: 1e-12 relative, identical argmax."""
    cluster = paper_cluster((2, 2, 2))
    tenants = _fleet_keyed(cluster) if keyed else _fleet_plain()
    mt = _margin_state(tenants, cluster)
    scorer = TenantBatchScorer(mt, backend="numpy")
    sweeps = _relocation_sweeps(mt)
    scored = scorer.score(sweeps)
    assert scorer.candidates_evaluated == sum(r.shape[0] for _, r in sweeps)
    for (t, rows), (rates, thpts) in zip(sweeps, scored):
        ref_rates, ref_thpts = scorer.reference_scores(t, rows)
        np.testing.assert_allclose(rates, ref_rates, rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(thpts, ref_thpts, rtol=1e-12, atol=1e-12)
        assert int(np.argmax(rates)) == int(np.argmax(ref_rates)), t


@pytest.mark.parametrize("keyed", [False, True], ids=["plain", "keyed"])
def test_batched_jax_matches_numpy_dispatch(keyed):
    """The jitted per-row kernel and the NumPy closed form agree on the
    tenant-batched tables: 1e-12 relative, identical argmax."""
    pytest.importorskip("jax")
    cluster = paper_cluster((2, 2, 2))
    tenants = _fleet_keyed(cluster) if keyed else _fleet_plain()
    mt = _margin_state(tenants, cluster)
    sweeps = _relocation_sweeps(mt)
    scored_np = TenantBatchScorer(mt, backend="numpy").score(sweeps)
    scored_jax = TenantBatchScorer(mt, backend="jax").score(sweeps)
    for (np_r, np_t), (jx_r, jx_t) in zip(scored_np, scored_jax):
        np.testing.assert_allclose(jx_r, np_r, rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(jx_t, np_t, rtol=1e-12, atol=1e-12)
        assert int(np.argmax(jx_r)) == int(np.argmax(np_r))


def test_empty_and_zero_row_sweeps():
    """B = 0 sweeps and empty sweep lists are guarded, not kernel calls."""
    cluster = paper_cluster((1, 1, 1))
    tenants = [
        Tenant(name="a", utg=linear_topology(), target_rate=5.0),
        Tenant(name="b", utg=star_topology(), target_rate=5.0),
    ]
    mt = _margin_state(tenants, cluster)
    scorer = TenantBatchScorer(mt, backend="auto")
    width_a = mt.states[0].task_machine().shape[0]
    out = scorer.score([(0, np.zeros((0, width_a), dtype=np.int64))])
    assert out[0][0].shape == (0,) and out[0][1].shape == (0,)
    assert scorer.score([]) == []
    assert scorer.candidates_evaluated == 0

    with pytest.raises(ValueError, match="sweep must be"):
        scorer.score([(0, np.zeros((2, width_a + 1), dtype=np.int64))])


def test_residual_rates_match_state_view():
    """The one-call incumbent sweep agrees with MultiTenantState's
    per-tenant residual closed form (margin rates, off the cliff)."""
    cluster = paper_cluster((2, 2, 2))
    mt = _margin_state(_fleet_plain(), cluster)
    resid = TenantBatchScorer(mt, backend="numpy").residual_rates()
    for t in range(len(mt.states)):
        np.testing.assert_allclose(
            resid[t], mt.residual_rstar(t), rtol=1e-9, atol=1e-12
        )
