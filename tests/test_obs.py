"""Observability layer: determinism, ledger, dispatch log, exporters.

The critical contract is *zero perturbation*: running with a
``TraceRecorder`` attached must leave every executor fingerprint
bit-identical to the recorder-off run (the four pinned pre-PR shuffle
digests), and two recorder-on reruns must export byte-identical JSONL
once wall-clock fields are stripped.
"""

import json

import numpy as np
import pytest

from repro.core import (
    keyed_rolling_count_topology,
    linear_topology,
    paper_cluster,
    rolling_count_topology,
    schedule,
)
from repro.core.refine import refine
from repro.obs import (
    NULL_RECORDER,
    MetricsRegistry,
    ReplanDecision,
    ReplanLedger,
    TraceRecorder,
    summary,
    to_chrome_trace,
    to_jsonl,
)
from repro.obs.validate import validate_chrome, validate_file, validate_jsonl
from repro.runtime_stream import (
    OnlineController,
    StreamExecutor,
    TraceSpec,
    burst_trace,
    ramp_trace,
)


@pytest.fixture(scope="module")
def cluster():
    return paper_cluster((1, 1, 1))


@pytest.fixture(scope="module")
def full_linear(cluster):
    return refine(
        schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.05).etg, cluster
    )


# Same pins as tests/test_runtime_stream.py::_SHUFFLE_GOLDEN_FPS (recorded
# from commit 12cf43e, before fields grouping): the recorder must not move
# them. Kept as a literal copy so a drift here cannot hide behind a shared
# constant changing.
_SHUFFLE_GOLDEN_FPS = {
    ("linear", "burst"): "26fc286367d2ab03eba1c45d9417a04b",
    ("linear", "ramp"): "ca9542d22a245bc90ba588543f47f041",
    ("rolling_count", "burst"): "2b6e1b64c419dd53f37337ab3c5e45e3",
    ("rolling_count", "ramp"): "c160b175553ae57f70c3e0a9cdf263eb",
}


def test_recorder_on_keeps_pinned_fingerprints(cluster, full_linear):
    """Recorder-enabled runs reproduce all four pinned pre-PR digests."""
    for topo in (linear_topology(), rolling_count_topology()):
        if topo.name == "linear":
            full = full_linear
        else:
            full = refine(
                schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster
            )
        rec = TraceRecorder(name=f"golden-{topo.name}")
        burst = StreamExecutor(
            full.etg, cluster, burst_trace(full.rate * 0.8, n_windows=100, jitter=4),
            seed=11, recorder=rec,
        ).run()
        ramp = StreamExecutor(
            full.etg, cluster,
            ramp_trace(0.3 * full.rate, 1.5 * full.rate, n_windows=120),
            seed=3, recorder=rec,
        ).run()
        assert burst.fingerprint() == _SHUFFLE_GOLDEN_FPS[(topo.name, "burst")]
        assert ramp.fingerprint() == _SHUFFLE_GOLDEN_FPS[(topo.name, "ramp")]
        assert rec.records  # the recorder actually saw the runs


def _controlled_run(cluster, full, recorder=None, **ctl_kwargs):
    """Under-provisioned schedule + rate ramp: the controller must grow
    (accepted replans) and also hit guard rejections along the way."""
    from repro.runtime_stream import provision_schedule

    topo = linear_topology()
    prov = provision_schedule(topo, cluster, full.rate * 0.3)
    ctl = OnlineController(topo, cluster, period=10, recorder=recorder, **ctl_kwargs)
    trace = ramp_trace(0.3 * full.rate, 1.2 * full.rate, n_windows=160)
    res = StreamExecutor(
        prov, cluster, trace, seed=3, recorder=recorder
    ).run(controller=ctl)
    return res, ctl


def test_jsonl_export_byte_identical_across_reruns(cluster, full_linear):
    """Two recorder-on reruns (wall clock enabled) export byte-identical
    JSONL once ``strip_wall=True`` removes the wall fields."""
    texts = []
    for _ in range(2):
        rec = TraceRecorder(name="rerun", wall_clock=True)
        _controlled_run(cluster, full_linear, recorder=rec)
        texts.append(to_jsonl(rec, strip_wall=True))
    assert texts[0] == texts[1]
    # Wall fields really were present before stripping.
    assert any("wall_s" in json.loads(l) for l in to_jsonl(rec).splitlines())
    n, errors = validate_jsonl(texts[0])
    assert not errors and n > 10


def test_recorder_does_not_change_controlled_run(cluster, full_linear):
    """Fingerprint, migrations and the controller decisions are identical
    with and without a recorder attached."""
    res_off, ctl_off = _controlled_run(cluster, full_linear, recorder=None)
    rec = TraceRecorder(name="on")
    res_on, ctl_on = _controlled_run(cluster, full_linear, recorder=rec)
    assert res_on.fingerprint() == res_off.fingerprint()
    assert ctl_on.log == ctl_off.log
    assert ctl_on.ledger == ctl_off.ledger


def test_ledger_guard_breakdown_and_legacy_view(cluster, full_linear):
    """Every consult that reaches the guard carries the full two-sided
    breakdown; the legacy string log derives tuple-for-tuple."""
    res, ctl = _controlled_run(cluster, full_linear)
    assert ctl.ledger, "ramp run should trigger at least one decision"
    assert ctl.log == ctl.ledger.legacy_view()
    accepted = ctl.ledger.accepted
    assert len(accepted) == int((res.migrations > 0).sum())
    assert accepted, "ramp run should accept at least one replan"
    for dec in ctl.ledger:
        assert dec.outcome in ("no_move", "budget", "skip", "replan")
        w, msg = dec.legacy_entry()
        assert w == dec.window and msg == dec.message
        if dec.has_guard_breakdown:
            assert dec.moves > 0
            assert dec.cost == pytest.approx(dec.move_cost + dec.state_cost)
            assert dec.move_cost == pytest.approx(dec.moves * ctl.migration_cost)
            assert dec.horizon_windows == ctl.horizon_windows
            assert dec.candidate_moves  # refine applied at least one move
            assert f"moves={dec.moves}" in dec.message
        if dec.outcome == "replan":
            assert dec.benefit > dec.cost


def test_ledger_records_budget_rejections(cluster, full_linear):
    """A zero elastic budget turns every would-be replan into a recorded
    ``budget`` rejection with the full breakdown — nothing migrates."""
    res, ctl = _controlled_run(cluster, full_linear, elastic_budget=0.0)
    assert int(res.migrations.sum()) == 0
    budget = [d for d in ctl.ledger if d.outcome == "budget"]
    assert budget, "guard must have rejected at least one plan on budget"
    for dec in budget:
        assert dec.cost > dec.budget == 0.0
        assert dec.message.startswith(f"{dec.trigger}:budget cost=")


def test_replan_decision_message_formats():
    d = ReplanDecision(window=7, trigger="hot", outcome="no_move")
    assert d.legacy_entry() == (7, "hot:no_move")
    d = ReplanDecision(
        window=3, trigger="saturated", outcome="skip",
        moves=2, state_shipped=10.4, gain_rate=1.236,
    )
    assert d.message == "saturated:skip gain=1.24/s moves=2 state=10"
    d = ReplanDecision(
        window=4, trigger="drain", outcome="replan",
        moves=5, state_shipped=0.0, gain_rate=12.5,
    )
    assert d.message == "drain:replan gain=12.50/s moves=5 state=0"
    d = ReplanDecision(
        window=9, trigger="scale_out", outcome="budget", moves=3,
        state_shipped=2.0, cost=77.3,
    )
    assert d.message == "scale_out:budget cost=77 moves=3 state=2"
    d = ReplanDecision(window=5, trigger="hot", outcome="deferred", moves=4)
    assert d.legacy_entry() == (5, "deferred:arbiter", 4.0)
    ledger = ReplanLedger([d])
    assert ledger.rejected == [d] and not ledger.accepted
    rec = d.to_record()
    assert rec["budget"] == "inf"  # non-finite floats stringified for JSON


def test_dispatch_log_covers_keyed_refine(cluster):
    """Every closed-form sweep in a keyed refine run lands in the dispatch
    log with its regime, sizes and resolved backend."""
    utg = keyed_rolling_count_topology(n_keys=16, zipf_s=1.5)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    probe = StreamExecutor(
        etg, cluster, TraceSpec(name="probe", n_windows=2, base_rate=1.0), seed=5
    )
    skew = probe.skew_model_at(0)
    rec = TraceRecorder(name="keyed-refine")
    refine(etg, cluster, skew=skew, recorder=rec)
    assert rec.dispatch_log
    assert any(d.regime == "skew" for d in rec.dispatch_log)
    for d in rec.dispatch_log:
        assert d.backend in ("numpy", "jax")
        assert d.requested in ("numpy", "jax", "auto")
        assert d.site in ("max_stable_rate_batch", "score_task_machine_batch")
        assert d.elements is None or d.elements > 0
    # The dispatch stream also lands in the record list for exporters.
    assert sum(r["type"] == "dispatch" for r in rec.records) == len(rec.dispatch_log)


def test_executor_metrics_and_events(cluster, full_linear):
    """The recorder's new series agree with the result arrays they mirror."""
    rec = TraceRecorder(name="metrics")
    res, _ = _controlled_run(cluster, full_linear, recorder=rec)
    names = {m["name"]: m for m in rec.metrics.snapshot()}
    n_comp = linear_topology().n_components
    thpt = sum(
        names[f"executor.throughput.c{i}"]["value"] for i in range(n_comp)
    )
    assert thpt == pytest.approx(float(res.throughput.sum()) * res.window_s)
    assert names["executor.queue_max"]["hwm"] == pytest.approx(
        float(res.queue_max.max())
    )
    assert names["executor.replans_applied"]["value"] == int(
        (res.migrations > 0).sum()
    )
    assert names["controller.drift_checks"]["value"] > 0
    event_names = {r["name"] for r in rec.records if r["type"] == "event"}
    assert "run_start" in event_names and "drift" in event_names
    # Summary renders without blowing up and mentions the dispatch table.
    text = summary(rec)
    assert "refine.round" in text and "metrics:" in text


def test_metrics_registry_kinds():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.add(2.0)
    c.add()
    assert c.value == 3.0 and c.count == 2
    g = reg.gauge("g")
    g.set(5.0)
    g.set(2.0)
    assert g.value == 2.0 and g.hwm == 5.0
    h = reg.histogram("h", edges=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.record(v)
    assert h.counts == [1, 1, 1] and h.count == 3
    with pytest.raises(TypeError):
        reg.gauge("c")
    assert [m["name"] for m in reg.snapshot()] == ["c", "g", "h"]
    assert len(reg) == 3


def test_null_recorder_is_inert(cluster, full_linear):
    assert not NULL_RECORDER.enabled
    with NULL_RECORDER.span("x"):
        NULL_RECORDER.event("y")
    assert NULL_RECORDER.records == [] and len(NULL_RECORDER.metrics) == 0
    ex = StreamExecutor(
        full_linear.etg, cluster,
        burst_trace(full_linear.rate * 0.8, n_windows=10, jitter=4), seed=11,
    )
    assert ex.recorder is NULL_RECORDER


def test_validate_accepts_good_and_rejects_malformed(tmp_path, cluster, full_linear):
    rec = TraceRecorder(name="validate")
    _controlled_run(cluster, full_linear, recorder=rec)
    jsonl = tmp_path / "trace.jsonl"
    chrome = tmp_path / "trace.json"
    to_jsonl(rec, path=jsonl)
    to_chrome_trace(rec, path=chrome)
    for path in (jsonl, chrome):
        n, errors = validate_file(path)
        assert not errors and n > 0

    # Malformed JSONL: unknown type, missing ts, clock going backwards.
    bad = tmp_path / "bad.jsonl"
    bad.write_text(
        '{"type":"meta","name":"x","wall_clock":false,"records":2}\n'
        '{"type":"banana"}\n'
        '{"type":"event","name":"a","cat":"c","window":0}\n'
        '{"type":"event","name":"b","cat":"c","window":0,"ts":5}\n'
        '{"type":"event","name":"c","cat":"c","window":0,"ts":4}\n'
    )
    n, errors = validate_file(bad)
    assert len(errors) == 3
    # Malformed Chrome trace: bad phase, X event without dur.
    bad_chrome = tmp_path / "bad.json"
    bad_chrome.write_text(json.dumps({
        "traceEvents": [
            {"name": "ok", "ph": "i", "s": "t", "ts": 1, "pid": 0, "tid": 0},
            {"name": "bad-ph", "ph": "Z", "ts": 2, "pid": 0, "tid": 0},
            {"name": "no-dur", "ph": "X", "ts": 3, "pid": 0, "tid": 0},
        ]
    }))
    n, errors = validate_file(bad_chrome)
    assert len(errors) == 2
    from repro.obs.validate import main as validate_main
    assert validate_main([str(jsonl), str(chrome)]) == 0
    assert validate_main([str(bad)]) == 1
    assert validate_main([]) == 2


def test_chrome_trace_schema(cluster, full_linear):
    rec = TraceRecorder(name="chrome")
    _controlled_run(cluster, full_linear, recorder=rec)
    trace = to_chrome_trace(rec)
    n, errors = validate_chrome(trace)
    assert not errors
    phases = {ev["ph"] for ev in trace["traceEvents"]}
    assert "X" in phases and "i" in phases and "M" in phases
    spans = [ev for ev in trace["traceEvents"] if ev["ph"] == "X"]
    assert all(ev["dur"] >= 1 for ev in spans)
    # One thread per category, named via metadata events.
    thread_names = {
        ev["args"]["name"]
        for ev in trace["traceEvents"]
        if ev["ph"] == "M" and ev["name"] == "thread_name"
    }
    assert {"executor", "controller", "refine"} <= thread_names


def test_multitenant_arbiter_surface():
    """Per-tenant grants/denials/budget land on the runtime result and
    agree with the raw arbiter ledger; deferred decisions reproduce the
    legacy in-band 3-tuple."""
    from repro.core import diamond_topology
    from repro.multitenant import (
        MultiTenantRuntime,
        Tenant,
        TenantSet,
        compile_tenant_traces,
        schedule_tenants,
    )

    tenants = TenantSet(
        [
            Tenant(name="alice", utg=linear_topology(), target_rate=6.0),
            Tenant(name="bob", utg=diamond_topology(), target_rate=6.0),
        ]
    )
    cluster = paper_cluster((2, 2, 2))
    ms = schedule_tenants(list(tenants), cluster)
    specs = [
        TraceSpec(name="alice", n_windows=24, base_rate=min(4.0, ms.rates[0])),
        TraceSpec(name="bob", n_windows=24, base_rate=min(4.0, ms.rates[1])),
    ]
    mtrace = compile_tenant_traces(tenants, specs, cluster, seed=7)
    rt = MultiTenantRuntime(ms, tenants, cluster, mtrace)
    rec = TraceRecorder(name="mt")
    res = rt.run(online=True, moves_per_period=4, recorder=rec)
    assert tuple(l.name for l in res.arbiter) == res.names
    for ledger in res.arbiter:
        rows = [r for r in res.arbiter_log if r[0] == ledger.name]
        assert ledger.grants == sum(1 for r in rows if r[3])
        assert ledger.denials == sum(1 for r in rows if not r[3])
        assert ledger.moves_admitted == sum(r[2] for r in rows if r[3])
        assert ledger.moves_per_period == 4
        for _period, left in ledger.budget_remaining:
            assert 0 <= left <= 4
    assert res.arbiter_for("alice") is res.arbiter[0]
    # Tenant spans landed in the shared recorder.
    span_names = {r["name"] for r in rec.records if r["type"] == "span"}
    assert {"tenant:alice", "tenant:bob"} <= span_names
