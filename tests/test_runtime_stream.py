"""Streaming-runtime subsystem tests: trace compilation, executor
determinism and steady-state correctness, Python-vs-JAX evaluator parity,
and the online controller's drift handling.

The acceptance gates (ISSUE 4): same seed + trace spec => bit-identical
metrics and event log across runs; ``evaluate_policies_batch``'s JAX scan
agrees with the Python event loop to 1e-9 on shared scenarios; the online
controller beats the frozen static schedule under drift.
"""

import numpy as np
import pytest

from repro.core import (
    keyed_rolling_count_topology,
    linear_topology,
    max_stable_rate,
    paper_cluster,
    predict,
    rolling_count_topology,
    round_robin_schedule,
    schedule,
)
from repro.core.first_assignment import first_assignment
from repro.core.refine import refine
from repro.runtime_stream import (
    OnlineController,
    RuntimeConfig,
    StreamExecutor,
    TraceSpec,
    burst_trace,
    evaluate_policies_batch,
    failure_trace,
    machine_removal,
    machine_slowdown,
    placement_migrations,
    provision_schedule,
    ramp_trace,
    rate_burst,
    rate_noise,
    rate_ramp,
    sine_trace,
    skew_shift_trace,
    slowdown_trace,
)


@pytest.fixture(scope="module")
def cluster():
    return paper_cluster((1, 1, 1))


@pytest.fixture(scope="module")
def refined(cluster):
    """The slow-suite refined schedule (max stable rate ~5.68)."""
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.05).etg
    return refine(etg, cluster)


# ------------------------------------------------------------------ traces


def test_trace_compile_shapes_and_determinism(cluster):
    spec = TraceSpec(
        name="mix",
        n_windows=120,
        base_rate=4.0,
        events=(
            rate_ramp(8.0, start=10, end=60),
            rate_burst(2.0, every=30, width=4, jitter=2),
            rate_noise(0.05),
            machine_slowdown(1, 0.5, start=40),
            machine_removal(0, start=80),
        ),
    )
    a = spec.compile(cluster, seed=7)
    b = spec.compile(cluster, seed=7)
    c = spec.compile(cluster, seed=8)
    assert a.rates.shape == (120,)
    assert a.capacity.shape == (120, cluster.n_machines)
    assert np.array_equal(a.rates, b.rates)
    assert np.array_equal(a.capacity, b.capacity)
    assert not np.array_equal(a.rates, c.rates)  # jitter/noise are seeded
    assert np.all(a.rates >= 0.0)
    assert np.all(a.capacity[80:, 0] == 0.0)
    assert np.all(a.capacity[40:, 1] == cluster.capacity[1] * 0.5)
    assert any("remove m0" in e for _, e in a.events)


def test_stock_scenarios_compile(cluster):
    for spec in (
        ramp_trace(1.0, 8.0, n_windows=60),
        burst_trace(3.0, n_windows=60),
        sine_trace(3.0, n_windows=60),
        slowdown_trace(3.0, machine=2, n_windows=60),
        failure_trace(3.0, machine=2, n_windows=60),
    ):
        tr = spec.compile(cluster, seed=0)
        assert tr.n_windows == 60
        assert np.all(tr.rates >= 0.0)
        assert np.all(tr.capacity >= 0.0)


def test_trace_validation(cluster):
    with pytest.raises(ValueError, match="window"):
        TraceSpec(name="bad", n_windows=0, base_rate=1.0).compile(cluster)


# ---------------------------------------------------------------- executor


def test_runtime_matches_prediction_when_stable(cluster, refined):
    """Constant rate below R*: after the pipeline fills (one window per
    hop), every window's throughput and machine utilization equal the
    eq. 5/6 prediction at that rate — the runtime's correctness anchor."""
    rate = refined.rate * 0.6
    res = StreamExecutor(
        refined.etg, cluster, TraceSpec(name="const", n_windows=40, base_rate=rate)
    ).run()
    pred = predict(refined.etg, cluster, rate)
    depth = len(linear_topology().topo_order())
    assert np.allclose(res.throughput[depth + 1 :], pred.throughput, rtol=1e-9)
    assert np.allclose(res.machine_util[-1], pred.machine_util, rtol=1e-9)
    assert np.all(res.dropped == 0.0)
    assert np.all(res.throttle == 1.0)  # no back-pressure below R*
    # queues drain every window at the steady state
    assert res.queue_total[-1] < pred.throughput * res.window_s


def test_runtime_deterministic_bit_identical(cluster, refined):
    """Same seed + spec => bit-identical event log and metrics (ISSUE
    acceptance gate). A different seed must actually change the run."""
    spec = burst_trace(refined.rate * 0.8, n_windows=100, jitter=4)
    runs = [
        StreamExecutor(refined.etg, cluster, spec, seed=11).run() for _ in range(2)
    ]
    assert runs[0].fingerprint() == runs[1].fingerprint()
    assert runs[0].events == runs[1].events
    for field in ("throughput", "machine_util", "queue_total", "throttle"):
        assert np.array_equal(getattr(runs[0], field), getattr(runs[1], field))
    other = StreamExecutor(refined.etg, cluster, spec, seed=12).run()
    assert other.fingerprint() != runs[0].fingerprint()


def test_runtime_saturates_with_backpressure(cluster, refined):
    """Deep overload: spout throttle engages, queues stay bounded, and
    sustained throughput lands near the closed-form maximum (upstream
    tasks may earn somewhat more than R* credit, eq. 2 semantics)."""
    res = StreamExecutor(
        refined.etg,
        cluster,
        TraceSpec(name="hot", n_windows=240, base_rate=refined.rate * 3.0),
    ).run()
    cfg = RuntimeConfig()
    assert res.queue_max.max() <= cfg.max_queue + 1e-9
    assert res.throttle.min() < 1.0
    assert any("backpressure_on" in e for _, e in res.events)
    sustained = res.sustained_throughput()
    assert 0.7 * refined.throughput <= sustained <= 1.3 * refined.throughput
    # capacity is respected every window on every machine
    assert np.all(res.machine_util <= cluster.capacity[None, :] + 1e-9)


def test_runtime_machine_removal_stalls_static_schedule(cluster, refined):
    """Removing a machine under a frozen schedule collapses the pipeline
    stages placed there; utilization on the dead machine reads zero."""
    spec = failure_trace(refined.rate * 0.9, machine=2, n_windows=90)
    res = StreamExecutor(refined.etg, cluster, spec).run()
    kill = 30
    assert np.all(res.machine_util[kill + 1 :, 2] == 0.0)
    assert res.sustained_throughput(0.3) < 0.7 * res.throughput[:kill].mean()


def test_placement_migrations_counting(cluster):
    etg = first_assignment(linear_topology(), cluster, 1.0)
    same = etg.copy()
    assert placement_migrations(etg, same) == 0
    moved = etg.copy()
    moved.assignment[2] = np.array([(int(etg.assignment[2][0]) + 1) % 3])
    assert placement_migrations(etg, moved) == 1
    grown = etg.with_new_instance(3, 0)
    assert placement_migrations(etg, grown) == 1


# ---------------------------------------------------- batched evaluation


def _parity_setup(cluster):
    topo = rolling_count_topology()
    etg = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster).etg
    rstar, _ = max_stable_rate(etg, cluster)
    rr = round_robin_schedule(topo, cluster, etg.n_instances)
    policies = np.stack([etg.task_machine(), rr.task_machine()])
    traces = [
        ramp_trace(0.3 * rstar, 1.5 * rstar, n_windows=120).compile(cluster, seed=1),
        burst_trace(0.6 * rstar, n_windows=120).compile(cluster, seed=2),
        slowdown_trace(0.9 * rstar, machine=2, n_windows=120).compile(cluster, seed=3),
    ]
    return etg, traces, policies


def test_eval_backends_agree_1e9(cluster):
    """The lax.scan evaluator must match the Python event loop within 1e-9
    on the shared parity scenarios (ISSUE acceptance gate)."""
    pytest.importorskip("jax")
    etg, traces, policies = _parity_setup(cluster)
    a = evaluate_policies_batch(etg, cluster, traces, policies, backend="numpy")
    b = evaluate_policies_batch(etg, cluster, traces, policies, backend="jax")
    for field in (
        "throughput",
        "admitted",
        "dropped",
        "queue_total",
        "throttle",
        "machine_util_mean",
        "sustained",
    ):
        x, y = getattr(a, field), getattr(b, field)
        assert np.allclose(x, y, rtol=1e-9, atol=1e-9), field


def test_eval_numpy_matches_executor_rows(cluster):
    """The batch evaluator's NumPy backend is literally the executor per
    (trace, policy) pair — spot-check one cell bit-exactly."""
    etg, traces, policies = _parity_setup(cluster)
    res = evaluate_policies_batch(etg, cluster, traces, policies, backend="numpy")
    b, p = 1, 0
    comp = etg.task_component()
    from repro.runtime_stream.eval_jax import _policy_etg

    solo = StreamExecutor(_policy_etg(etg, policies[p]), cluster, traces[b]).run()
    assert np.array_equal(res.throughput[b, p], solo.throughput)
    assert res.sustained[b, p] == solo.sustained_throughput()
    assert comp.shape[0] == policies.shape[1]


def test_eval_validation_and_fallback(cluster):
    etg, traces, policies = _parity_setup(cluster)
    with pytest.raises(ValueError, match="backend"):
        evaluate_policies_batch(etg, cluster, traces, policies, backend="tpu")
    with pytest.raises(ValueError, match="P, T"):
        evaluate_policies_batch(etg, cluster, traces, policies[:, :-1])
    bad_idx = policies.copy()
    bad_idx[0, 0] = -1  # would wrap silently through the gathers
    with pytest.raises(ValueError, match="machine indices"):
        evaluate_policies_batch(etg, cluster, traces, bad_idx)
    with pytest.raises(ValueError, match="trace"):
        evaluate_policies_batch(etg, cluster, [], policies)
    short = [traces[0], traces[1]]
    bad = TraceSpec(name="odd", n_windows=7, base_rate=1.0).compile(cluster)
    with pytest.raises(ValueError, match="share"):
        evaluate_policies_batch(etg, cluster, short + [bad], policies)
    auto = evaluate_policies_batch(etg, cluster, traces[:1], policies, backend="auto")
    ref = evaluate_policies_batch(etg, cluster, traces[:1], policies, backend="numpy")
    assert np.allclose(auto.sustained, ref.sustained, rtol=1e-9)


# -------------------------------------------------------------- controller


def test_provision_schedule_sizes_to_rate(cluster):
    topo = linear_topology()
    lo = provision_schedule(topo, cluster, 1.0)
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    hi = provision_schedule(topo, cluster, full.rate * 2.0)
    r_lo, _ = max_stable_rate(lo, cluster)
    assert r_lo >= 1.0
    assert lo.total_tasks < hi.total_tasks  # higher target -> more instances
    r_hi, _ = max_stable_rate(hi, cluster)
    assert r_hi <= full.rate + 1e-9  # best effort caps at cluster saturation


def test_controller_recovers_from_machine_failure(cluster, refined):
    """Machine removal under the online controller: relocate off the dead
    machine and keep most of the throughput a frozen schedule loses."""
    topo = linear_topology()
    spec = failure_trace(refined.rate * 0.85, machine=2, n_windows=120)
    static = StreamExecutor(refined.etg, cluster, spec).run()
    ctl = OnlineController(topo, cluster, period=6)
    online = StreamExecutor(refined.etg, cluster, spec).run(controller=ctl)
    assert online.migrations.sum() > 0
    assert any("replan" in e for _, e in online.events)
    assert online.sustained_throughput() > 1.2 * static.sustained_throughput()
    # nothing left scheduled on the dead machine
    assert np.all(online.final_etg.task_machine() != 2)


def test_controller_grows_into_rate_ramp(cluster):
    """The paper's protocol, online: a schedule provisioned for the early
    rate must be grown as the rate ramps; the controller's incremental
    replans track the oracle's full re-schedule within 10%."""
    topo = linear_topology()
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    prov = provision_schedule(topo, cluster, full.rate * 0.3)
    spec = ramp_trace(full.rate * 0.3, full.rate * 1.2, n_windows=200)
    static = StreamExecutor(prov, cluster, spec).run()
    ctl = OnlineController(topo, cluster, period=10)
    online = StreamExecutor(prov, cluster, spec).run(controller=ctl)
    assert online.sustained_throughput() > 1.1 * static.sustained_throughput()
    assert online.final_etg.total_tasks > prov.total_tasks
    assert any("replan" in why for _, why in ctl.log)


def test_controller_guard_rejects_pointless_migration(cluster, refined):
    """Steady load a schedule already sustains: no migration clears the
    cost/benefit guard, so the placement never changes."""
    topo = linear_topology()
    spec = TraceSpec(name="flat", n_windows=80, base_rate=refined.rate * 0.5)
    ctl = OnlineController(topo, cluster, period=8)
    res = StreamExecutor(refined.etg, cluster, spec).run(controller=ctl)
    assert res.migrations.sum() == 0
    assert res.final_etg.task_machine().tolist() == (
        refined.etg.task_machine().tolist()
    )


def test_controller_migration_pause_applies(cluster):
    """Migrated instances pause: the window right after a replan shows the
    migration in the metrics (count recorded, events logged)."""
    topo = linear_topology()
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    prov = provision_schedule(topo, cluster, full.rate * 0.3)
    spec = ramp_trace(full.rate * 0.3, full.rate * 1.2, n_windows=160)
    cfg = RuntimeConfig(migration_pause=3)
    ctl = OnlineController(topo, cluster, period=10)
    res = StreamExecutor(prov, cluster, spec, config=cfg).run(controller=ctl)
    w = int(np.flatnonzero(res.migrations)[0])
    assert res.migrations[w] > 0
    assert any(e == (w, f"replan:{int(res.migrations[w])}moves") for e in res.events)


# ----------------------------------------------------- fields grouping


# Pre-PR executor fingerprints of shuffle-grouping golden runs: the keyed
# arrival path must leave even-split runs bit-identical (ISSUE 5
# acceptance). Recorded from commit 12cf43e (before fields grouping).
# ISSUE 6's bincount vectorization of the executor's per-window np.add.at
# accumulations also rides on these four pins: np.bincount must accumulate
# bit-identically (sequential input order) or these digests move.
_SHUFFLE_GOLDEN_FPS = {
    ("linear", "burst"): "26fc286367d2ab03eba1c45d9417a04b",
    ("linear", "ramp"): "ca9542d22a245bc90ba588543f47f041",
    ("rolling_count", "burst"): "2b6e1b64c419dd53f37337ab3c5e45e3",
    ("rolling_count", "ramp"): "c160b175553ae57f70c3e0a9cdf263eb",
}


def test_shuffle_fingerprints_bit_identical_to_pre_keyed_runtime(cluster):
    """Shuffle grouping must reproduce the pre-fields-grouping executor
    bit-identically: fingerprints pinned before the keyed routing landed."""
    for topo in (linear_topology(), rolling_count_topology()):
        full = refine(
            schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster
        )
        burst = StreamExecutor(
            full.etg, cluster, burst_trace(full.rate * 0.8, n_windows=100, jitter=4),
            seed=11,
        ).run()
        ramp = StreamExecutor(
            full.etg, cluster,
            ramp_trace(0.3 * full.rate, 1.5 * full.rate, n_windows=120),
            seed=3,
        ).run()
        assert burst.fingerprint() == _SHUFFLE_GOLDEN_FPS[(topo.name, "burst")]
        assert ramp.fingerprint() == _SHUFFLE_GOLDEN_FPS[(topo.name, "ramp")]


@pytest.fixture(scope="module")
def keyed_setup(cluster):
    """Keyed topology + even-split schedule + the initial skew view."""
    utg = keyed_rolling_count_topology(n_keys=16, zipf_s=1.5)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    probe = StreamExecutor(
        etg, cluster, TraceSpec(name="probe", n_windows=2, base_rate=1.0), seed=5
    )
    skew = probe.skew_model_at(0)
    r_skew, _ = max_stable_rate(etg, cluster, skew=skew)
    r_even, _ = max_stable_rate(etg, cluster)
    return utg, etg, skew, r_skew, r_even


def test_keyed_run_deterministic_and_skew_bound_holds(cluster, keyed_setup):
    """Keyed runs are bit-deterministic, sustain below the skew-aware R*
    without back-pressure, and saturate between the skew-aware and the
    even-split bound — the even split over-reports keyed capacity."""
    utg, etg, skew, r_skew, r_even = keyed_setup
    assert r_skew < 0.8 * r_even  # the hot key costs real capacity
    spec = TraceSpec(name="under", n_windows=80, base_rate=0.9 * r_skew)
    a = StreamExecutor(etg, cluster, spec, seed=5).run()
    b = StreamExecutor(etg, cluster, spec, seed=5).run()
    assert a.fingerprint() == b.fingerprint()
    assert StreamExecutor(etg, cluster, spec, seed=6).run().fingerprint() != (
        a.fingerprint()
    )
    assert np.all(a.throttle == 1.0) and a.dropped.sum() == 0.0
    # Above the skew bound (but below even-split R*) a hot instance
    # saturates its machine and back-pressure eventually trips.
    mid = 0.5 * (r_skew + r_even)
    hot = StreamExecutor(
        etg, cluster, TraceSpec(name="over", n_windows=400, base_rate=mid), seed=5,
        config=RuntimeConfig(max_queue=120.0),
    ).run()
    assert hot.throttle.min() < 1.0
    assert np.all(hot.machine_util <= cluster.capacity[None, :] + 1e-9)


def test_keyed_trace_must_cover_groupings(cluster, keyed_setup):
    """A compiled trace without the topology's key realizations is
    rejected (silent even-split fallback would fake keyed results)."""
    utg, etg, *_ = keyed_setup
    spec = TraceSpec(name="plain", n_windows=20, base_rate=1.0)
    bare = spec.compile(cluster, seed=0)  # compiled without utg
    with pytest.raises(ValueError, match="fields groupings"):
        StreamExecutor(etg, cluster, bare)
    with pytest.raises(ValueError, match="fields groupings"):
        evaluate_policies_batch(
            etg, cluster, [bare], etg.task_machine()[None, :], backend="numpy"
        )


def test_eval_backends_agree_1e9_keyed(cluster, keyed_setup):
    """ISSUE 5 parity satellite: the lax.scan evaluator with per-key
    routing matrices matches the Python executor on keyed traces (B×P
    sweep, <= 1e-9)."""
    pytest.importorskip("jax")
    utg, etg, skew, r_skew, r_even = keyed_setup
    rr = round_robin_schedule(utg, cluster, etg.n_instances)
    policies = np.stack(
        [etg.task_machine(), rr.task_machine(), etg.task_machine()[::-1].copy()]
    )
    traces = [
        TraceSpec(name="flat", n_windows=120, base_rate=0.8 * r_skew).compile(
            cluster, seed=1, utg=utg
        ),
        skew_shift_trace(0.9 * r_skew, n_windows=120).compile(cluster, seed=2, utg=utg),
        ramp_trace(0.3 * r_skew, 1.3 * r_even, n_windows=120).compile(
            cluster, seed=3, utg=utg
        ),
    ]
    a = evaluate_policies_batch(etg, cluster, traces, policies, backend="numpy")
    b = evaluate_policies_batch(etg, cluster, traces, policies, backend="jax")
    for field in (
        "throughput", "admitted", "dropped", "queue_total", "throttle",
        "machine_util_mean", "sustained",
    ):
        x, y = getattr(a, field), getattr(b, field)
        assert np.allclose(x, y, rtol=1e-9, atol=1e-9), field


def test_controller_recovers_keyed_hot_instance(cluster, keyed_setup):
    """The ISSUE 5 acceptance scenario: offered load between the skew
    bound and the even-split bound saturates a hot instance; the static
    even-split schedule backs off, the skew-aware controller replans
    (relocate/grow priced at the realized key shares) and wins."""
    utg, etg, skew, r_skew, r_even = keyed_setup
    cfg = RuntimeConfig(max_queue=120.0)
    spec = TraceSpec(name="hotkeys", n_windows=240, base_rate=0.95 * r_even)
    static = StreamExecutor(etg, cluster, spec, seed=5, config=cfg).run()
    ctl = OnlineController(utg, cluster, period=10)
    online = StreamExecutor(etg, cluster, spec, seed=5, config=cfg).run(
        controller=ctl
    )
    assert online.migrations.sum() > 0
    assert online.sustained_throughput() > 1.15 * static.sustained_throughput()


def test_controller_skew_shift_trigger(cluster, keyed_setup):
    """A key_skew_shift bumps the trace's skew epoch and shows up as a
    drift trigger even when rate and capacity never change."""
    utg, etg, skew, r_skew, _ = keyed_setup
    spec = skew_shift_trace(0.7 * r_skew, n_windows=160)
    ctl = OnlineController(utg, cluster, period=8)
    StreamExecutor(
        etg, cluster, spec, seed=11, config=RuntimeConfig(max_queue=120.0)
    ).run(controller=ctl)
    assert any("skew_shift" in why for _, why in ctl.log)


# ------------------------------------------------- measurement noise (§6.2)


def test_noisy_observations_hold_no_churn_guarantee(cluster, refined):
    """ISSUE 5 satellite (ROADMAP open item 4): with the §6.2 measurement
    model on the controller's observation path, steady state below R*
    must stay churn-free — noise can fire spurious triggers, but the
    demand-capped cost/benefit guard rejects every replan."""
    topo = linear_topology()
    spec = TraceSpec(name="flat", n_windows=120, base_rate=refined.rate * 0.5)
    ctl = OnlineController(topo, cluster, period=8, measure_noise=0.05, noise_seed=7)
    res = StreamExecutor(refined.etg, cluster, spec).run(controller=ctl)
    assert res.migrations.sum() == 0
    assert res.final_etg.task_machine().tolist() == (
        refined.etg.task_machine().tolist()
    )
    # The noise is per-window seeded: the same run reproduces bit-identically.
    ctl2 = OnlineController(topo, cluster, period=8, measure_noise=0.05, noise_seed=7)
    res2 = StreamExecutor(refined.etg, cluster, spec).run(controller=ctl2)
    assert res2.fingerprint() == res.fingerprint()
    assert ctl2.log == ctl.log


def test_noisy_observations_still_detect_real_drift(cluster):
    """Noise must not mask real drift: the machine-failure recovery of
    test_controller_recovers_from_machine_failure still holds with the
    §6.2 observation model enabled."""
    topo = linear_topology()
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    spec = failure_trace(full.rate * 0.85, machine=2, n_windows=120)
    static = StreamExecutor(full.etg, cluster, spec).run()
    ctl = OnlineController(topo, cluster, period=6, measure_noise=0.05)
    online = StreamExecutor(full.etg, cluster, spec).run(controller=ctl)
    assert online.sustained_throughput() > 1.2 * static.sustained_throughput()
    assert np.all(online.final_etg.task_machine() != 2)


# -------------------------------------------------- adaptive growth menu


def test_refine_adaptive_growth_flag_gated(cluster):
    """Default-off flag: the standard menu is untouched; adaptive mode is
    rejected on the reference engine."""
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    with pytest.raises(ValueError, match="adaptive_growth"):
        refine(etg, cluster, engine="reference", adaptive_growth=True)


def test_refine_adaptive_growth_lockstep_matches_sequential():
    """Adaptive chains must be explorer-independent: lockstep grouped
    sweeps and sequential stepping produce identical moves and floats
    (the satellite's equivalence gate)."""
    for counts, topo in (((2, 2, 2), rolling_count_topology()),
                         ((3, 3, 3), linear_topology())):
        cl = paper_cluster(counts)
        etg = first_assignment(topo, cl, 1.0)
        lock = refine(etg, cl, max_rounds=3, adaptive_growth=True)
        seq = refine(etg, cl, max_rounds=3, adaptive_growth=True, lockstep=False)
        assert lock.moves == seq.moves
        assert lock.throughput == seq.throughput
        assert lock.etg.task_machine().tolist() == seq.etg.task_machine().tolist()


def test_refine_adaptive_growth_extends_menu():
    """From an under-provisioned schedule with bounded rounds (the online
    controller's regime) the adaptive menu finds deep growth moves the
    fixed k<=4 menu cannot express, and wins."""
    cl = paper_cluster((3, 3, 3))
    etg = first_assignment(linear_topology(), cl, 1.0)
    base = refine(etg, cl, max_rounds=3)
    adaptive = refine(etg, cl, max_rounds=3, adaptive_growth=True)
    assert adaptive.throughput > base.throughput
    deep = [
        m
        for m in adaptive.moves
        if m.startswith(("grow", "pairgrow"))
        and any(
            int(tok.split("x")[1]) > 4
            for tok in m.replace("+", " ").split()
            if "x" in tok and tok.startswith("c")
        )
    ]
    assert deep, adaptive.moves


# ------------------------------------------------------------- slow soak


@pytest.mark.slow
def test_runtime_soak_controller_converges(cluster):
    """Long composite drift trace (ramp + burst + slowdown + recovery):
    the controller must track within 10% of the oracle's full re-schedule
    and beat the frozen static schedule."""
    topo = linear_topology()
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    spec = TraceSpec(
        name="soak",
        n_windows=600,
        base_rate=full.rate * 0.3,
        events=(
            rate_ramp(full.rate * 1.1, start=40, end=240),
            rate_burst(1.6, every=90, width=10, start=250, jitter=3),
            machine_slowdown(2, 0.5, start=300, end=450),
        ),
    )
    prov = provision_schedule(topo, cluster, full.rate * 0.3)
    static = StreamExecutor(prov, cluster, spec).run()
    ctl = OnlineController(topo, cluster, period=10)
    online = StreamExecutor(prov, cluster, spec).run(controller=ctl)

    from repro.runtime_stream import OracleRescheduler

    cfg = RuntimeConfig(migration_pause=0)
    oracle = StreamExecutor(prov, cluster, spec, config=cfg).run(
        controller=OracleRescheduler(topo, cluster)
    )
    s_static = static.sustained_throughput()
    s_online = online.sustained_throughput()
    s_oracle = oracle.sustained_throughput()
    assert s_online >= s_static
    assert s_online >= 0.9 * s_oracle
