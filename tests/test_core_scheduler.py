"""Tests for the paper's core scheduling algorithms (repro.core)."""

import numpy as np
import pytest

from repro.core import (
    ExecutionGraph,
    UserGraph,
    component_rates,
    diamond_topology,
    first_assignment,
    instance_rates,
    linear_topology,
    max_stable_rate,
    max_stable_rate_batch,
    optimal_schedule,
    paper_cluster,
    paper_profile,
    placement_score,
    predict,
    round_robin_schedule,
    schedule,
    simulate,
    star_topology,
)
from repro.core.refine import refine


@pytest.fixture
def cluster():
    return paper_cluster((1, 1, 1))


# ---------------------------------------------------------------- graphs

def test_topologies_are_dags():
    for topo in (linear_topology(), diamond_topology(), star_topology()):
        order = topo.topo_order()
        assert sorted(order) == list(range(topo.n_components))


def test_cycle_rejected():
    with pytest.raises(ValueError, match="cycle"):
        UserGraph(
            name="bad",
            component_types=np.array([0, 1, 2]),
            edges=((0, 1), (1, 2), (2, 1)),
            alpha=np.ones(3),
        )


def test_min_one_instance_enforced():
    topo = linear_topology()
    with pytest.raises(ValueError, match="1 instance"):
        ExecutionGraph(
            topo,
            np.array([1, 0, 1, 1]),
            [np.array([0]), np.zeros(0, np.int64), np.array([0]), np.array([0])],
        )


# ------------------------------------------------------------ rate model

def test_rate_propagation_linear():
    topo = linear_topology(alpha=2.0)
    cir = component_rates(topo, 10.0)
    # spout 10 -> bolt1 gets 10*1 (spout alpha 1), then doubling per bolt
    assert cir[0] == 10.0
    assert cir[1] == 10.0
    assert cir[2] == 20.0
    assert cir[3] == 40.0


def test_rate_propagation_diamond_replicates_per_child():
    topo = diamond_topology()
    cir = component_rates(topo, 6.0)
    # each of the three middle bolts receives the full spout output
    assert cir[1] == cir[2] == cir[3] == 6.0
    assert cir[4] == 18.0  # sink sums all three


def test_instance_rates_split_evenly(cluster):
    topo = linear_topology()
    etg = ExecutionGraph(
        topo,
        np.array([1, 1, 2, 4]),
        [np.array([0]), np.array([1]), np.array([0, 1]), np.array([0, 1, 2, 2])],
    )
    ir = instance_rates(etg, 8.0)
    comp = etg.task_component()
    assert np.allclose(ir[comp == 2], 4.0)
    assert np.allclose(ir[comp == 3], 2.0)


def test_prediction_linear_in_rate(cluster):
    """eq. 5: util(r) = MET + r * k, so equal rate deltas give equal util deltas."""
    topo = linear_topology()
    etg = first_assignment(topo, cluster, 1.0)
    p0 = predict(etg, cluster, 0.0)
    p1 = predict(etg, cluster, 2.0)
    p2 = predict(etg, cluster, 4.0)
    assert np.all(p2.machine_util >= p1.machine_util - 1e-12)
    assert np.allclose(p2.machine_util - p1.machine_util,
                       p1.machine_util - p0.machine_util)


def test_max_stable_rate_matches_prediction_boundary(cluster):
    topo = linear_topology()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    rate, thpt = max_stable_rate(sched.etg, cluster)
    assert predict(sched.etg, cluster, rate).feasible
    assert not predict(sched.etg, cluster, rate * 1.01).feasible
    assert thpt == pytest.approx(predict(sched.etg, cluster, rate).throughput)


def test_max_stable_rate_batch_consistent(cluster):
    topo = diamond_topology()
    etg = first_assignment(topo, cluster, 1.0)
    tm = np.stack([etg.task_machine(), (etg.task_machine() + 1) % 3])
    rates, thpts = max_stable_rate_batch(etg, cluster, tm)
    for i in range(2):
        e2 = ExecutionGraph(
            topo, etg.n_instances,
            [tm[i][etg.task_component() == c] for c in range(topo.n_components)],
        )
        r, t = max_stable_rate(e2, cluster)
        assert rates[i] == pytest.approx(r)
        assert thpts[i] == pytest.approx(t)


# ------------------------------------------------------------ simulator

def test_simulator_matches_prediction_when_stable(cluster):
    topo = linear_topology()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    rate, _ = max_stable_rate(sched.etg, cluster)
    sim = simulate(sched.etg, cluster, rate * 0.95)
    pred = predict(sched.etg, cluster, rate * 0.95)
    assert np.allclose(sim.pr, pred.ir, rtol=1e-6)          # nothing throttled
    assert np.allclose(sim.machine_util, pred.machine_util, rtol=1e-6)


def test_simulator_saturates_under_overload(cluster):
    topo = linear_topology()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    rate, _ = max_stable_rate(sched.etg, cluster)
    sim = simulate(sched.etg, cluster, rate * 100)
    # throughput bounded, machines never exceed capacity materially
    assert sim.machine_util.max() <= cluster.capacity.max() + 1e-6
    stable = simulate(sched.etg, cluster, rate)
    assert sim.throughput <= stable.throughput * 110  # bounded, not linear in rate


# ------------------------------------------------------------ schedulers

def test_first_assignment_one_instance_each(cluster):
    etg = first_assignment(diamond_topology(), cluster, 1.0)
    assert np.all(etg.n_instances == 1)
    assert predict(etg, cluster, 1.0).feasible


def test_round_robin_cycles(cluster):
    etg = round_robin_schedule(linear_topology(), cluster, np.array([1, 1, 1, 1]))
    assert etg.task_machine().tolist() == [0, 1, 2, 0]


@pytest.mark.parametrize("topo_fn", [linear_topology, diamond_topology, star_topology])
def test_schedule_beats_round_robin(topo_fn, cluster):
    topo = topo_fn()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    _, ours = max_stable_rate(sched.etg, cluster)
    rr = round_robin_schedule(topo, cluster, sched.etg.n_instances)
    _, base = max_stable_rate(rr, cluster)
    assert ours > base * 1.05  # paper: 7%-44% improvement


@pytest.mark.parametrize("topo_fn", [linear_topology, diamond_topology, star_topology])
def test_schedule_never_overutilizes(topo_fn, cluster):
    sched = schedule(topo_fn(), cluster, r0=1.0, rate_epsilon=0.05)
    assert predict(sched.etg, cluster, sched.rate).feasible


@pytest.mark.slow
def test_refined_schedule_within_4pct_of_optimal(cluster):
    """Paper claim C3 (via the beyond-paper refinement pass). ~1 min: the
    hill climb scores O(T^2) candidate moves per round on three topologies."""
    for topo_fn in (linear_topology, diamond_topology, star_topology):
        topo = topo_fn()
        sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
        ref = refine(sched.etg, cluster)
        opt = optimal_schedule(topo, cluster,
                               max_total_tasks=max(ref.etg.total_tasks + 1, 8))
        assert ref.throughput >= 0.96 * opt.throughput, topo.name


def test_optimal_beats_or_matches_everything(cluster):
    topo = linear_topology()
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05)
    opt = optimal_schedule(topo, cluster, max_total_tasks=sched.etg.total_tasks)
    _, ours = max_stable_rate(sched.etg, cluster)
    assert opt.throughput >= ours - 1e-9


def test_schedule_scales_to_large_cluster():
    cl = paper_cluster((10, 10, 10))
    sched = schedule(linear_topology(), cl, r0=1.0, rate_epsilon=1.0)
    small = schedule(linear_topology(), paper_cluster((1, 1, 1)),
                     r0=1.0, rate_epsilon=1.0)
    # 10x machines should give ~10x throughput (within 25%)
    assert sched.predicted_throughput > 7.5 * small.predicted_throughput
