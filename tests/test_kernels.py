"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rglru_scan.ops import rglru_scan

# Full interpret-mode kernel sweeps take minutes; run with `pytest -m slow`.
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,Hkv,D,causal,window",
    [
        (2, 256, 256, 4, 4, 64, True, 0),
        (1, 128, 256, 4, 2, 64, True, 0),       # GQA, right-aligned queries
        (2, 256, 256, 2, 1, 128, True, 128),    # MQA + sliding window
        (1, 64, 64, 2, 2, 32, False, 0),        # bidirectional (encoder)
        (1, 192, 192, 2, 2, 64, True, 0),       # non-multiple of block
    ],
)
def test_flash_attention_sweep(B, Sq, Sk, H, Hkv, D, causal, window, dtype):
    q = jax.random.normal(KEY, (B, Sq, H, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          impl="interpret", block_q=64, block_kv=64)
    ref = flash_attention(q, k, v, causal=causal, window=window, impl="ref")
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,S,W", [(2, 512, 256), (3, 100, 64), (1, 37, 128)])
def test_rglru_scan_sweep(B, S, W, dtype):
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, S, W), dtype))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, W), dtype)
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, W), dtype)
    out = rglru_scan(a, b, h0, impl="interpret")
    ref = rglru_scan(a, b, h0, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,D",
    [(2, 8, 2, 1024, 64), (4, 4, 1, 512, 128), (1, 16, 8, 300, 64)],
)
def test_decode_attention_sweep(B, H, Hkv, S, D, dtype):
    q = jax.random.normal(KEY, (B, H, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D), dtype)
    lens = jax.random.randint(jax.random.PRNGKey(3), (B,), 1, S + 1)
    out = decode_attention(q, k, v, lens, impl="interpret")
    ref = decode_attention(q, k, v, lens, impl="ref")
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_flash_attention_matches_model_sdpa():
    """The kernel oracle and the model's XLA attention agree (same math)."""
    from repro.models.attention import sdpa

    B, S, H, D = 1, 128, 4, 64
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, D))
    a = flash_attention(q, k, v, causal=True, impl="ref")
    b = sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)
