"""HLO analyzer and roofline accounting tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.hlo_analysis import analyze_hlo
from repro.roofline import model_flops, param_counts, roofline_terms


def test_analyzer_counts_plain_matmul():
    f = jax.jit(lambda a, b: a @ b)
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    txt = f.lower(a, b).compile().as_text()
    c = analyze_hlo(txt)
    assert c.matmul_flops == 2 * 256 * 512 * 128


def test_analyzer_multiplies_scan_trip_count():
    """cost_analysis() visits while bodies once; the analyzer must not."""

    def g(x, w):
        def body(carry, wi):
            return carry @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    compiled = jax.jit(g).lower(x, w).compile()
    c = analyze_hlo(compiled.as_text())
    assert c.matmul_flops == pytest.approx(7 * 2 * 64 ** 3)
    # demonstrate the cost_analysis undercount this guards against
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] < c.matmul_flops


def test_analyzer_nested_scans():
    def g(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return ci @ wi, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    txt = jax.jit(g).lower(x, w).compile().as_text()
    c = analyze_hlo(txt)
    assert c.matmul_flops == pytest.approx(5 * 3 * 2 * 32 ** 3)


def test_roofline_terms_units():
    t = roofline_terms(197e12, 819e9, 50e9)
    assert t["compute"] == pytest.approx(1.0)
    assert t["memory"] == pytest.approx(1.0)
    assert t["collective"] == pytest.approx(1.0)


def test_param_counts_match_real_params():
    from repro.configs import ARCHS, get_config
    from repro.launch import steps as steps_lib

    for arch in ["qwen1_5_0_5b", "yi_9b", "internlm2_1_8b", "starcoder2_7b"]:
        cfg = get_config(arch)
        analytic = param_counts(cfg)["total"]
        actual = sum(
            int(np.prod(x.shape))
            for x in jax.tree.leaves(steps_lib.abstract_params(cfg))
        )
        assert analytic == pytest.approx(actual, rel=0.02), arch


def test_deepseek_params_near_671b():
    from repro.configs import get_config

    counts = param_counts(get_config("deepseek-v3-671b"))
    assert counts["total"] == pytest.approx(671e9, rel=0.08)
    assert counts["active"] == pytest.approx(37e9, rel=0.15)


def test_model_flops_decode_vs_train():
    from repro.configs import get_config
    from repro.models.config import SHAPES

    cfg = get_config("yi-9b")
    train = model_flops(cfg, SHAPES["train_4k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    # train: 6*N*B*S; decode: 2*N*B
    ratio = train / decode
    assert ratio == pytest.approx(3 * 4096 * 256 / 128, rel=1e-6)
