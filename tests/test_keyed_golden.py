"""Deterministic keyed-grouping goldens (no hypothesis needed).

The skew-aware closed form is pinned against an independent brute-force
per-instance simulation, and refine's growth offers are pinned to the
skew-aware score (the ISSUE 5 fix/guard satellite: a skew-saturated
component must never report even-split gains). The randomized sweep of
the same properties lives in tests/test_keyed_properties.py.
"""

import numpy as np
import pytest

from repro.core import (
    FieldsGrouping,
    SkewModel,
    keyed_rolling_count_topology,
    max_stable_rate,
    paper_cluster,
    rolling_count_topology,
    schedule,
)
from repro.core.refine import refine
from repro.runtime_stream import TraceSpec, key_skew_shift


def _compile_keyed(utg, cluster, seed, n_windows=4):
    return TraceSpec(name="probe", n_windows=n_windows, base_rate=1.0).compile(
        cluster, seed=seed, utg=utg
    )


def _skew_model(utg, cluster, seed):
    reals = _compile_keyed(utg, cluster, seed).realizations_at(0)
    return SkewModel(utg, {e: r.shares for e, r in reals.items()})


def brute_force_rstar(etg, cluster, realizations, hi):
    """Independent per-instance feasibility bisection: explicit eq. 6
    propagation, per-edge routing (even split or key shares) and a Python
    loop per instance — no closed form, no SkewModel."""
    utg = etg.utg
    topo = utg.topo_order()
    sources = set(utg.sources)
    keyed = {g.edge for g in utg.groupings}

    def feasible(rate):
        cir = np.zeros(utg.n_components)
        for i in topo:
            if i in sources:
                cir[i] = rate
            else:
                cir[i] = sum(utg.alpha[p] * cir[p] for p in utg.parents(i))
        util = np.zeros(cluster.n_machines)
        for c in range(utg.n_components):
            N = int(etg.n_instances[c])
            inst = np.zeros(N)
            if c in sources:
                inst += rate / N
            for p in utg.parents(c):
                contrib = utg.alpha[p] * cir[p]
                if (p, c) in keyed:
                    inst += contrib * realizations[(p, c)].shares(N)
                else:
                    inst += contrib / N
            for k in range(N):
                w = int(etg.assignment[c][k])
                tt = int(utg.component_types[c])
                mt = int(cluster.machine_types[w])
                util[w] += (
                    cluster.profile.e[tt, mt] * inst[k] + cluster.profile.met[tt, mt]
                )
        return np.all(util <= cluster.capacity + 1e-9)

    lo, hi = 0.0, float(hi)
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if feasible(mid):
            lo = mid
        else:
            hi = mid
    return lo


def test_single_key_pins_everything_to_one_instance():
    """K=1 is the degenerate hot key: the whole edge stream lands on one
    instance regardless of the parallelism degree."""
    cluster = paper_cluster((1, 1, 1))
    utg = keyed_rolling_count_topology(n_keys=1, zipf_s=1.0)
    skew = _skew_model(utg, cluster, seed=5)
    for n in (1, 2, 5):
        frac = skew.instance_fractions(2, n)
        assert frac.max() == pytest.approx(1.0)
        assert np.count_nonzero(frac > 1e-12) == 1


def test_key_skew_shift_requires_keyed_topology():
    cluster = paper_cluster((1, 1, 1))
    spec = TraceSpec(
        name="bad", n_windows=10, base_rate=1.0, events=(key_skew_shift(start=5),)
    )
    with pytest.raises(ValueError, match="keyed topology"):
        spec.compile(cluster, seed=0)
    utg = keyed_rolling_count_topology()
    tr = spec.compile(cluster, seed=0, utg=utg)
    assert tr.skew_epoch(4) == 0 and tr.skew_epoch(5) == 1
    assert any("key_skew_shift" in e for _, e in tr.events)
    a, b = tr.realizations_at(4)[(1, 2)], tr.realizations_at(5)[(1, 2)]
    assert not np.array_equal(a.hashes, b.hashes)


def test_skew_bound_matches_bruteforce_simulation():
    """The satellite regression pin: the skew-aware closed form must agree
    with a brute-force per-instance simulation on a small golden — growth
    offers scored through it can never report even-split gains."""
    cluster = paper_cluster((1, 1, 1))
    utg = keyed_rolling_count_topology(n_keys=8, zipf_s=2.0)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    reals = _compile_keyed(utg, cluster, seed=3).realizations_at(0)
    skew = SkewModel(utg, {e: r.shares for e, r in reals.items()})
    r_even, _ = max_stable_rate(etg, cluster)
    r_skew, _ = max_stable_rate(etg, cluster, skew=skew)
    r_bf = brute_force_rstar(etg, cluster, reals, hi=2.0 * r_even)
    assert r_skew == pytest.approx(r_bf, rel=1e-6)
    assert r_skew < r_even  # the hot key makes the even split an over-report


def test_refine_growth_offers_use_skew_score():
    """Skew-saturated component: refine's growth offers must price the
    realized shares. The refined schedule's reported throughput must match
    the skew-aware closed form (verified against brute force), and refine
    must actually recover throughput the even split can't see."""
    cluster = paper_cluster((1, 1, 1))
    utg = keyed_rolling_count_topology(n_keys=8, zipf_s=2.0)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    reals = _compile_keyed(utg, cluster, seed=3).realizations_at(0)
    skew = SkewModel(utg, {e: r.shares for e, r in reals.items()})
    res = refine(etg, cluster, skew=skew)
    # Reported score == skew-aware closed form of the final placement.
    r_chk, t_chk = max_stable_rate(res.etg, cluster, skew=skew)
    assert res.rate == r_chk and res.throughput == t_chk
    # ... == brute-force per-instance simulation of the same placement.
    r_bf = brute_force_rstar(res.etg, cluster, reals, hi=4.0 * r_chk + 1.0)
    assert r_chk == pytest.approx(r_bf, rel=1e-6)
    # The hill climb found real skew-aware gains the even-split-optimal
    # start was blind to (this etg has no even-split improving moves).
    r0_skew, _ = max_stable_rate(etg, cluster, skew=skew)
    assert res.rate > r0_skew
    assert refine(etg, cluster).moves == []
    with pytest.raises(ValueError, match="skew"):
        refine(etg, cluster, engine="reference", skew=skew)


def test_grouping_validation():
    with pytest.raises(ValueError, match="unknown edge"):
        rolling_count_topology().with_groupings(
            FieldsGrouping(edge=(0, 2), n_keys=4)
        )
    with pytest.raises(ValueError, match="duplicate"):
        rolling_count_topology().with_groupings(
            FieldsGrouping(edge=(1, 2)), FieldsGrouping(edge=(1, 2))
        )
    with pytest.raises(ValueError, match="at least one key"):
        FieldsGrouping(edge=(1, 2), n_keys=0)
    with pytest.raises(ValueError, match="zipf_s"):
        FieldsGrouping(edge=(1, 2), zipf_s=-0.5)
    utg = keyed_rolling_count_topology()
    assert utg.keyed_components == [2]
    assert utg.grouping((1, 2)) is not None and utg.grouping((0, 1)) is None
    with pytest.raises(ValueError, match="edge_shares"):
        SkewModel(utg, {})
