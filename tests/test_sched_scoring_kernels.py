"""Parity suite for the scatter-free closed-form scoring kernels (ISSUE 6).

Covers both new accumulation paths against the NumPy reference
(``cost_model.closed_form_rates`` — sequential ``np.add.at``, the bit-exact
oracle): the XLA one-hot contraction (``sim_jax._msr_kernel``) and the
Pallas segmented-reduce kernel run in interpret mode
(``kernels.sched_scoring``), across all three scoring regimes — shared
(T,) maps, per-row (B, T) maps, and skew rows — plus the dispatch table's
regime/machine-gate semantics. Runs in the fast tier: shapes are small and
the Pallas kernel interprets on CPU.

When hypothesis is installed (CI dev image), a property section fuzzes
shapes/values; the deterministic seed sweep below keeps kernel coverage in
environments without it.
"""

import numpy as np
import pytest

from repro.core import (
    keyed_rolling_count_topology,
    max_stable_rate_batch,
    paper_cluster,
    schedule,
    star_topology,
)
from repro.core.cost_model import closed_form_rates
from repro.core.schedule_state import ScheduleState

jax = pytest.importorskip("jax")

from repro.core.sim_jax import closed_form_rates_jax  # noqa: E402
from repro.kernels.sched_scoring.ops import closed_form_rates_sched  # noqa: E402
from repro.kernels.sched_scoring.ref import sched_scoring_ref  # noqa: E402

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


def _random_problem(seed, B, T, m, n, infeasible_rows=0):
    """Random scoring instance + the NumPy reference result."""
    rng = np.random.default_rng(seed)
    tm = rng.integers(0, m, size=(B, T))
    comp = np.sort(rng.integers(0, n, size=T))
    unit_ir = rng.uniform(0.05, 1.5, size=T)
    e_cm = rng.uniform(0.3, 3.0, size=(n, m))
    met_cm = rng.uniform(0.0, 0.4, size=(n, m))
    cap = rng.uniform(2.0, 12.0, size=m)
    if infeasible_rows and B:
        # Saturate a machine's base load on some rows so the feasibility
        # mask (rate == 0) is exercised, not just the happy path.
        met_cm = met_cm.copy()
        hot = rng.integers(0, B, size=infeasible_rows)
        tm[hot, :] = 0
        met_cm[:, 0] = cap[0]
    e = e_cm[comp[None, :], tm]
    met = met_cm[comp[None, :], tm]
    ref = closed_form_rates(tm, e, met, unit_ir, cap)
    return tm, comp, unit_ir, e_cm, met_cm, cap, ref


def _assert_parity(got, ref):
    r_ref, t_ref = ref
    r_got, t_got = got
    np.testing.assert_allclose(r_got, r_ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(t_got, t_ref, rtol=1e-12, atol=1e-12)
    # Identical feasibility mask and identical best-candidate pick.
    assert np.array_equal(r_got == 0.0, r_ref == 0.0)
    if r_ref.size:
        assert int(np.argmax(t_got)) == int(np.argmax(t_ref))


SHAPES = [
    (0, 7, 3, 4),        # empty batch
    (1, 5, 1, 3),        # single machine, single row
    (17, 14, 3, 6),      # small-cluster refine sweep shape
    (64, 15, 6, 7),      # medium cluster
    (33, 54, 15, 7),     # large realistic cluster (4,5,6)
    (9, 130, 16, 5),     # T above the Pallas task-block size (padding)
]


@pytest.mark.parametrize("B,T,m,n", SHAPES)
@pytest.mark.parametrize("seed", [0, 1])
def test_contraction_parity_shared(B, T, m, n, seed):
    tm, comp, unit_ir, e_cm, met_cm, cap, ref = _random_problem(
        seed, B, T, m, n, infeasible_rows=min(B, 3)
    )
    got = closed_form_rates_jax(tm, comp, unit_ir, e_cm, met_cm, cap)
    _assert_parity(got, ref)


@pytest.mark.parametrize("B,T,m,n", SHAPES)
@pytest.mark.parametrize("seed", [0, 1])
def test_pallas_interpret_parity_shared(B, T, m, n, seed):
    tm, comp, unit_ir, e_cm, met_cm, cap, ref = _random_problem(
        seed, B, T, m, n, infeasible_rows=min(B, 3)
    )
    got = closed_form_rates_sched(
        tm, comp, unit_ir, e_cm, met_cm, cap, impl="interpret"
    )
    _assert_parity(got, ref)


@pytest.mark.parametrize("B,T,m,n", [(21, 14, 3, 6), (13, 54, 15, 7)])
def test_per_row_parity(B, T, m, n):
    rng = np.random.default_rng(7)
    tm, comp1, _, e_cm, met_cm, cap, _ = _random_problem(7, B, T, m, n)
    comp = np.broadcast_to(comp1, (B, T)).copy()
    unit_ir = rng.uniform(0.05, 1.5, size=(B, T))
    e = e_cm[comp, tm]
    met = met_cm[comp, tm]
    ref = closed_form_rates(tm, e, met, unit_ir, cap)
    _assert_parity(
        closed_form_rates_jax(tm, comp, unit_ir, e_cm, met_cm, cap), ref
    )
    _assert_parity(
        closed_form_rates_sched(
            tm, comp, unit_ir, e_cm, met_cm, cap, impl="interpret"
        ),
        ref,
    )


def test_sched_scoring_ref_matches_core():
    tm, comp, unit_ir, e_cm, met_cm, cap, ref = _random_problem(3, 11, 14, 3, 6)
    e = e_cm[comp[None, :], tm]
    ev = e * unit_ir[None, :]
    met = met_cm[comp[None, :], tm]
    assert np.array_equal(sched_scoring_ref(tm, ev, met, cap), ref[0])


# ------------------------------------------------------------- skew rows


@pytest.fixture(scope="module")
def skew_state():
    from repro.runtime_stream import StreamExecutor, TraceSpec

    cluster = paper_cluster((2, 2, 2))
    utg = keyed_rolling_count_topology(n_keys=16, zipf_s=1.5)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    probe = StreamExecutor(
        etg, cluster, TraceSpec(name="probe", n_windows=2, base_rate=1.0),
        seed=5,
    )
    skew = probe.skew_model_at(0)
    assert skew is not None
    return ScheduleState.from_etg(etg, cluster, skew=skew), etg, cluster, skew


def test_skew_shared_jax_matches_numpy(skew_state):
    state, etg, cluster, skew = skew_state
    rng = np.random.default_rng(11)
    T = int(state.n_instances.sum())
    tm = rng.integers(0, cluster.n_machines, size=(40, T))
    ref = state.score_task_machine_batch(tm, backend="numpy")
    got = state.score_task_machine_batch(tm, backend="jax")
    _assert_parity(got, ref)
    # Same parity through the batch-scoring module entry point.
    _assert_parity(
        max_stable_rate_batch(etg, cluster, tm, backend="jax", skew=skew),
        max_stable_rate_batch(etg, cluster, tm, backend="numpy", skew=skew),
    )


def test_skew_per_row_jax_matches_numpy(skew_state):
    state, etg, cluster, skew = skew_state
    rng = np.random.default_rng(13)
    B = 24
    n_inst = np.tile(state.n_instances, (B, 1))
    T = int(state.n_instances.sum())
    tm = rng.integers(0, cluster.n_machines, size=(B, T))
    ref = state.score_task_machine_batch(tm, n_instances=n_inst, backend="numpy")
    got = state.score_task_machine_batch(tm, n_instances=n_inst, backend="jax")
    _assert_parity(got, ref)
    _assert_parity(
        max_stable_rate_batch(
            etg, cluster, tm, backend="jax", n_instances=n_inst, skew=skew
        ),
        max_stable_rate_batch(
            etg, cluster, tm, backend="numpy", n_instances=n_inst, skew=skew
        ),
    )


def test_skew_pallas_interpret_matches_numpy(skew_state):
    state, _, cluster, skew = skew_state
    rng = np.random.default_rng(17)
    T = int(state.n_instances.sum())
    tm = rng.integers(0, cluster.n_machines, size=(16, T))
    n = state.utg.n_components
    comp = np.repeat(np.arange(n), state.n_instances)
    unit_ir = skew.per_task_unit_ir(state.n_instances)
    ref = state.score_task_machine_batch(tm, backend="numpy")
    got = closed_form_rates_sched(
        tm, comp, unit_ir, state.e_cm, state.met_cm, cluster.capacity,
        impl="interpret",
    )
    _assert_parity(got, ref)


# ------------------------------------------------- dispatch regime/gating


def test_auto_dispatch_regimes_and_machine_gate(monkeypatch):
    from repro.core.simulator import (
        _AUTO_MAX_MACHINES,
        _AUTO_MAX_WORK,
        _CLOSED_FORM_AUTO_THRESHOLDS,
        _jax_accelerator_available,
        resolve_closed_form_backend,
    )

    for var in (
        "REPRO_CLOSED_FORM_JAX_THRESHOLD",
        "REPRO_CLOSED_FORM_JAX_THRESHOLD_SHARED",
        "REPRO_CLOSED_FORM_JAX_THRESHOLD_PER_ROW",
        "REPRO_CLOSED_FORM_JAX_THRESHOLD_SKEW",
    ):
        monkeypatch.delenv(var, raising=False)
    if _jax_accelerator_available():
        pytest.skip("machine gate only applies on CPU backends")
    for regime, floor in _CLOSED_FORM_AUTO_THRESHOLDS.items():
        floor = int(floor)
        # Below the regime floor: NumPy. At it, narrow cluster: JAX.
        assert resolve_closed_form_backend(
            "auto", floor - 1, regime=regime, n_machines=3
        ) == "numpy"
        assert resolve_closed_form_backend(
            "auto", floor, regime=regime, n_machines=3
        ) == "jax"
        # Wide clusters stay NumPy regardless of size (contraction is
        # B*T*m); unknown width skips the gate.
        assert resolve_closed_form_backend(
            "auto", 10 * floor, regime=regime,
            n_machines=_AUTO_MAX_MACHINES + 1,
        ) == "numpy"
        assert resolve_closed_form_backend(
            "auto", floor, regime=regime, n_machines=None
        ) == "jax"
        # Out-of-cache sweeps stay NumPy even on mid-width clusters: the
        # work ceiling caps elements * machines.
        over_work = _AUTO_MAX_WORK // 15 + 1
        if over_work >= floor:
            assert resolve_closed_form_backend(
                "auto", over_work, regime=regime, n_machines=15
            ) == "numpy"
        assert resolve_closed_form_backend(
            "auto", _AUTO_MAX_WORK // 15, regime=regime, n_machines=15
        ) == "jax"
    with pytest.raises(ValueError, match="regime"):
        resolve_closed_form_backend("auto", 10, regime="banana")


def test_regime_env_override_bypasses_gate(monkeypatch):
    from repro.core.simulator import resolve_closed_form_backend

    monkeypatch.delenv("REPRO_CLOSED_FORM_JAX_THRESHOLD", raising=False)
    monkeypatch.setenv("REPRO_CLOSED_FORM_JAX_THRESHOLD_SKEW", "50")
    # The skew-specific floor applies to skew rows only — and bypasses the
    # machine gate (the override is the explicit recalibration escape).
    assert resolve_closed_form_backend(
        "auto", 50, regime="skew", n_machines=500
    ) == "jax"
    assert resolve_closed_form_backend(
        "auto", 49, regime="skew", n_machines=3
    ) == "numpy"
    assert resolve_closed_form_backend(
        "auto", 50, regime="shared", n_machines=3
    ) == "numpy"
    # The regime-specific variable wins over the all-regime one.
    monkeypatch.setenv("REPRO_CLOSED_FORM_JAX_THRESHOLD", "10")
    assert resolve_closed_form_backend(
        "auto", 49, regime="skew", n_machines=3
    ) == "numpy"
    assert resolve_closed_form_backend(
        "auto", 10, regime="shared", n_machines=500
    ) == "jax"


# ------------------------------------------------------------ hypothesis

if HAS_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**32 - 1),
        B=st.integers(0, 48),
        T=st.integers(1, 80),
        m=st.integers(1, 20),
        n=st.integers(1, 8),
        impl=st.sampled_from(["contraction", "interpret"]),
    )
    def test_fuzz_parity_shared(seed, B, T, m, n, impl):
        tm, comp, unit_ir, e_cm, met_cm, cap, ref = _random_problem(
            seed, B, T, m, n, infeasible_rows=min(B, 2)
        )
        if impl == "contraction":
            got = closed_form_rates_jax(tm, comp, unit_ir, e_cm, met_cm, cap)
        else:
            got = closed_form_rates_sched(
                tm, comp, unit_ir, e_cm, met_cm, cap, impl="interpret"
            )
        _assert_parity(got, ref)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**32 - 1),
        B=st.integers(1, 32),
        T=st.integers(1, 40),
        m=st.integers(1, 12),
        n=st.integers(1, 6),
    )
    def test_fuzz_parity_per_row(seed, B, T, m, n):
        rng = np.random.default_rng(seed)
        tm, comp1, _, e_cm, met_cm, cap, _ = _random_problem(seed, B, T, m, n)
        comp = np.broadcast_to(comp1, (B, T)).copy()
        unit_ir = rng.uniform(0.05, 1.5, size=(B, T))
        ref = closed_form_rates(
            tm, e_cm[comp, tm], met_cm[comp, tm], unit_ir, cap
        )
        _assert_parity(
            closed_form_rates_jax(tm, comp, unit_ir, e_cm, met_cm, cap), ref
        )
        _assert_parity(
            closed_form_rates_sched(
                tm, comp, unit_ir, e_cm, met_cm, cap, impl="interpret"
            ),
            ref,
        )
