"""Hypothesis-randomized engine equivalence: on random DAG topologies,
random cluster shapes and random heterogeneous profiles, the
incremental/state engines must reproduce the reference paths *exactly* —
same schedules, same moves, same candidate counts — extending the fixed
golden scenarios in ``test_sched_equivalence.py`` to adversarial topology
shapes. Wide (8+ component, high fan-out) topologies specifically exercise
the lockstep growth-chain explorer on the shapes it was built for: many
simultaneous single/pair chains per refine round.
"""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings

from sched_strategies import (
    random_cluster,
    random_dag,
    random_het_cluster,
    random_wide_dag,
)

from repro.core import optimal_schedule, schedule
from repro.core.refine import refine


def _sched_fingerprint(s):
    return (
        s.rate,
        s.etg.n_instances.tolist(),
        s.etg.task_machine().tolist(),
        s.iterations,
        s.trace,
    )


def _assert_refine_engines_agree(etg, cluster, max_rounds):
    ref = refine(etg, cluster, max_rounds=max_rounds, engine="reference")
    state = refine(etg, cluster, max_rounds=max_rounds, engine="state")
    seq = refine(
        etg, cluster, max_rounds=max_rounds, engine="state", lockstep=False
    )
    for res in (state, seq):
        assert res.moves == ref.moves
        assert res.rate == ref.rate
        assert res.throughput == ref.throughput
        assert res.etg.n_instances.tolist() == ref.etg.n_instances.tolist()
        assert res.etg.task_machine().tolist() == ref.etg.task_machine().tolist()


@given(random_dag(), random_cluster())
@settings(max_examples=25, deadline=None)
def test_schedule_engines_agree_on_random_dags(topo, cluster):
    ref = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="reference")
    inc = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="incremental")
    assert _sched_fingerprint(inc) == _sched_fingerprint(ref)


@given(random_dag(), random_cluster(max_per_type=2))
@settings(max_examples=10, deadline=None)
def test_refine_engines_agree_on_random_dags(topo, cluster):
    etg = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0).etg
    _assert_refine_engines_agree(etg, cluster, max_rounds=3)


@given(random_dag(max_components=4), random_cluster(max_per_type=1))
@settings(max_examples=10, deadline=None)
def test_optimal_engines_agree_on_random_dags(topo, cluster):
    budget = topo.n_components + 2
    ref = optimal_schedule(topo, cluster, max_total_tasks=budget, engine="reference")
    state = optimal_schedule(topo, cluster, max_total_tasks=budget, engine="state")
    assert state.rate == ref.rate
    assert state.throughput == ref.throughput
    assert state.candidates_evaluated == ref.candidates_evaluated
    assert state.classes_pruned == ref.classes_pruned
    assert state.etg.n_instances.tolist() == ref.etg.n_instances.tolist()
    assert state.etg.task_machine().tolist() == ref.etg.task_machine().tolist()
    # The beam bound must never change the optimum it reports.
    unbounded = optimal_schedule(
        topo, cluster, max_total_tasks=budget, prune_bound=False
    )
    assert state.throughput == unbounded.throughput
    assert state.rate == unbounded.rate
    assert (
        state.etg.task_machine().tolist() == unbounded.etg.task_machine().tolist()
    )


# ------------------------------------------- wide / heterogeneous shapes


@given(random_wide_dag(), random_cluster(max_per_type=2))
@settings(max_examples=10, deadline=None)
def test_schedule_engines_agree_on_wide_dags(topo, cluster):
    ref = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="reference")
    inc = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="incremental")
    assert _sched_fingerprint(inc) == _sched_fingerprint(ref)


@given(random_wide_dag(max_components=10), random_cluster(max_per_type=1))
@settings(max_examples=4, deadline=None)
def test_refine_engines_agree_on_wide_dags(topo, cluster):
    """8-10 components -> 28-45 simultaneous pair chains per round: the
    lockstep explorer's batches must still replay the reference hill climb
    move for move (and the sequential explorer must agree with both)."""
    etg = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0).etg
    _assert_refine_engines_agree(etg, cluster, max_rounds=2)


@given(random_dag(), random_het_cluster())
@settings(max_examples=15, deadline=None)
def test_schedule_engines_agree_on_heterogeneous_profiles(topo, cluster):
    """Random profiling tables + per-machine capacities: engine agreement
    must not depend on the paper's particular Table 3 numbers."""
    ref = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="reference")
    inc = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="incremental")
    assert _sched_fingerprint(inc) == _sched_fingerprint(ref)


@given(random_dag(max_components=5), random_het_cluster(max_per_type=1))
@settings(max_examples=6, deadline=None)
def test_refine_engines_agree_on_heterogeneous_profiles(topo, cluster):
    etg = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0).etg
    _assert_refine_engines_agree(etg, cluster, max_rounds=2)
