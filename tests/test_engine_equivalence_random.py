"""Hypothesis-randomized engine equivalence: on random DAG topologies and
random cluster shapes, the incremental/state engines must reproduce the
reference paths *exactly* — same schedules, same moves, same candidate
counts — extending the fixed golden scenarios in
``test_sched_equivalence.py`` to adversarial topology shapes.
"""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings

from sched_strategies import random_cluster, random_dag

from repro.core import optimal_schedule, schedule
from repro.core.refine import refine


def _sched_fingerprint(s):
    return (
        s.rate,
        s.etg.n_instances.tolist(),
        s.etg.task_machine().tolist(),
        s.iterations,
        s.trace,
    )


@given(random_dag(), random_cluster())
@settings(max_examples=25, deadline=None)
def test_schedule_engines_agree_on_random_dags(topo, cluster):
    ref = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="reference")
    inc = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="incremental")
    assert _sched_fingerprint(inc) == _sched_fingerprint(ref)


@given(random_dag(), random_cluster(max_per_type=2))
@settings(max_examples=10, deadline=None)
def test_refine_engines_agree_on_random_dags(topo, cluster):
    etg = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0).etg
    ref = refine(etg, cluster, max_rounds=3, engine="reference")
    state = refine(etg, cluster, max_rounds=3, engine="state")
    assert state.moves == ref.moves
    assert state.rate == ref.rate
    assert state.throughput == ref.throughput
    assert state.etg.n_instances.tolist() == ref.etg.n_instances.tolist()
    assert state.etg.task_machine().tolist() == ref.etg.task_machine().tolist()


@given(random_dag(max_components=4), random_cluster(max_per_type=1))
@settings(max_examples=10, deadline=None)
def test_optimal_engines_agree_on_random_dags(topo, cluster):
    budget = topo.n_components + 2
    ref = optimal_schedule(topo, cluster, max_total_tasks=budget, engine="reference")
    state = optimal_schedule(topo, cluster, max_total_tasks=budget, engine="state")
    assert state.rate == ref.rate
    assert state.throughput == ref.throughput
    assert state.candidates_evaluated == ref.candidates_evaluated
    assert state.etg.n_instances.tolist() == ref.etg.n_instances.tolist()
    assert state.etg.task_machine().tolist() == ref.etg.task_machine().tolist()
