"""Hypothesis property tests on the scheduling system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings

from sched_strategies import PROFILE, random_cluster, random_dag

from repro.core import (
    component_rates,
    first_assignment,
    max_stable_rate,
    paper_cluster,
    predict,
    schedule,
    simulate,
)


@given(random_dag(), st.floats(0.5, 50.0))
@settings(max_examples=40, deadline=None)
def test_rate_propagation_is_linear(topo, r0):
    """CIR(k*r) == k*CIR(r): eq. 6 is homogeneous of degree 1."""
    c1 = component_rates(topo, r0)
    c2 = component_rates(topo, 2 * r0)
    assert np.allclose(c2, 2 * c1, rtol=1e-9)


@given(random_dag(), random_cluster())
@settings(max_examples=30, deadline=None)
def test_schedule_invariants(topo, cluster):
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0)
    # 1) every component keeps >= 1 instance (paper constraint)
    assert np.all(sched.etg.n_instances >= 1)
    # 2) all assignments land on real machines
    assert np.all(sched.etg.task_machine() < cluster.n_machines)
    assert np.all(sched.etg.task_machine() >= 0)
    # 3) the returned state is feasible: no machine over-utilized (MAC >= 0)
    if sched.rate > 0:
        assert predict(sched.etg, cluster, sched.rate).feasible


@given(random_dag(), random_cluster())
@settings(max_examples=30, deadline=None)
def test_stable_rate_is_simulator_fixed_point(topo, cluster):
    """At (just under) the closed-form max stable rate the simulator applies
    no throttling; prediction and simulation agree."""
    etg = first_assignment(topo, cluster, 1.0)
    rate, thpt = max_stable_rate(etg, cluster)
    if rate <= 0:
        return
    sim = simulate(etg, cluster, rate * 0.99)
    pred = predict(etg, cluster, rate * 0.99)
    assert np.allclose(sim.pr, pred.ir, rtol=1e-5)
    assert sim.throughput <= thpt + 1e-6


@given(random_dag(), random_cluster(), st.floats(1.0, 1e5))
@settings(max_examples=30, deadline=None)
def test_simulator_never_overutilizes(topo, cluster, rate):
    """Proportional throttling keeps every machine at or under capacity."""
    etg = first_assignment(topo, cluster, 1.0)
    sim = simulate(etg, cluster, rate)
    assert np.all(sim.machine_util <= cluster.capacity + 1e-6)
    assert np.all(sim.pr <= sim.ir + 1e-9)  # back-pressure only reduces


@given(random_dag(), random_cluster())
@settings(max_examples=20, deadline=None)
def test_adding_machines_never_hurts(topo, cluster):
    sched1 = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0)
    bigger = paper_cluster((2, 2, 2), PROFILE)
    if bigger.n_machines <= cluster.n_machines:
        return
    sched2 = schedule(topo, bigger, r0=1.0, rate_epsilon=1.0)
    if cluster.n_machines < 6:
        assert sched2.predicted_throughput >= 0.7 * sched1.predicted_throughput
