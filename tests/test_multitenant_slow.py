"""100-tenant soak: the bench scenario as a gated test (slow tier).

The fast tier caps fleets at a handful of tenants; this suite runs the
``BENCH_multitenant`` scale scenario end to end with per-round validation
on, so the shared-load invariant, the MET-deferral fixpoint, and the
no-regression floors are all exercised at the fleet size the tentpole
claims — not just at toy sizes.
"""

import numpy as np
import pytest

from repro.core import ScheduleState, paper_cluster
from repro.multitenant import (
    MultiTenantState,
    TenantSet,
    fair_slice_floors,
    schedule_tenants,
)

from benchmarks.bench_multitenant import FLEET_KW, SEED, _fleet

pytestmark = pytest.mark.slow


@pytest.mark.parametrize(
    "cap_scale, min_floors", [(4.0, 50), (1.0, 10)], ids=["roomy", "paper"]
)
def test_hundred_tenant_soak(cap_scale, min_floors):
    rng = np.random.default_rng(SEED)
    tenants = _fleet(100, rng)
    cluster = paper_cluster((20, 30, 40))
    cluster = cluster.with_capacity(cluster.capacity * cap_scale)

    ms = schedule_tenants(tenants, cluster, validate=True, **FLEET_KW)

    states = [
        ScheduleState.from_etg(a.etg, cluster, skew=t.skew)
        for a, t in zip(ms.allocations, tenants)
    ]
    mt = MultiTenantState(TenantSet(tenants), cluster, states, rates=ms.rates)
    assert mt.feasible(slack=1e-9)
    assert np.all(ms.rates >= 0.0)

    floors = fair_slice_floors(
        tenants, cluster, warm_refine_rounds=FLEET_KW["warm_refine_rounds"]
    )
    assert np.all(ms.rates >= floors * (1.0 - 1e-6))
    # The paper-capacity variant genuinely exercises the deferral path
    # (most floors collapse to 0); the roomy one keeps most non-vacuous.
    assert int(np.sum(floors > 0.0)) >= min_floors
