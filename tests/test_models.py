"""Per-architecture smoke tests (reduced configs) + cache-consistency
integration tests on CPU."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M
from repro.models.layers import MeshCtx

# Per-architecture forward/backward smoke tests take minutes on CPU; run
# with `pytest -m slow`.
pytestmark = pytest.mark.slow

CTX = MeshCtx(mesh=None)
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16, key=KEY):
    batch = {}
    if cfg.embedding_inputs:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.mrope_sections:
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)
        ).astype(jnp.int32)
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward/train step, correct shapes, no NaNs."""
    cfg = get_config(arch).reduced()
    params = M.init_params(KEY, cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)

    h, _, aux = M.forward(params, cfg, CTX, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(h).all())

    loss, grads = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, CTX, batch))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0.0  # gradients flow


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(KEY, cfg)
    B, S_cache = 2, 32
    caches = M.init_caches(cfg, B, S_cache)
    prompt = make_batch(cfg, B, 8)
    if cfg.embedding_inputs:
        prompt.pop("labels", None)
    logits, caches = M.prefill(params, cfg, CTX, prompt, caches)
    assert logits.shape == (B, cfg.vocab_size)
    step = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.embedding_inputs:
        step = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
    if cfg.mrope_sections:
        step["mrope_positions"] = jnp.full((3, B, 1), 8, jnp.int32)
    if cfg.is_encoder_decoder:
        step["encoder_embeds"] = prompt["encoder_embeds"]
    logits2, caches = M.decode_step(params, cfg, CTX, step, caches)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize(
    "arch",
    ["yi_9b", "recurrentgemma_2b", "xlstm_125m", "deepseek_v3_671b",
     "granite_moe_1b_a400m", "whisper_tiny", "qwen1_5_0_5b"],
)
def test_decode_matches_teacher_forcing(arch):
    """Incremental decode with caches reproduces the full forward pass."""
    cfg = get_config(arch).reduced()
    if cfg.is_moe:  # isolate cache correctness from capacity drops
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = M.init_params(KEY, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    h, _, _ = M.forward(params, cfg, CTX, batch)
    full = M._logits(params, cfg, h)[..., : cfg.vocab_size]

    caches = M.init_caches(cfg, B, S)
    extra = {k: batch[k] for k in ("encoder_embeds",) if k in batch}
    lg, caches = M.prefill(params, cfg, CTX, {"tokens": tokens[:, :6], **extra}, caches)
    errs = [float(jnp.abs(lg - full[:, 5]).max())]
    for t in range(6, S):
        lg, caches = M.decode_step(
            params, cfg, CTX, {"tokens": tokens[:, t : t + 1], **extra}, caches
        )
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-3, errs


def test_ring_cache_wraps_correctly():
    """Sliding-window ring cache stays exact after wrapping (long decode)."""
    cfg = dataclasses.replace(get_config("recurrentgemma_2b").reduced(), local_window=8)
    params = M.init_params(KEY, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    h, _, _ = M.forward(params, cfg, CTX, {"tokens": tokens})
    full = M._logits(params, cfg, h)[..., : cfg.vocab_size]
    caches = M.init_caches(cfg, B, S)
    lg, caches = M.prefill(params, cfg, CTX, {"tokens": tokens[:, :4]}, caches)
    errs = [float(jnp.abs(lg - full[:, 3]).max())]
    for t in range(4, S):
        lg, caches = M.decode_step(params, cfg, CTX, {"tokens": tokens[:, t:t+1]}, caches)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-3


def test_chunked_attention_matches_plain():
    from repro.models.attention import sdpa, sdpa_chunked

    B, S, H, D = 2, 512, 4, 32
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, D))
    for window in (0, 100):
        o1 = sdpa_chunked(q, k, v, causal=True, window=window, q_chunk=128, k_chunk=128)
        o2 = sdpa(q, k, v, causal=True, window=window)
        assert float(jnp.abs(o1 - o2).max()) < 1e-4


def test_chunked_attention_ragged_kv():
    from repro.models.attention import sdpa, sdpa_chunked

    q = jax.random.normal(KEY, (1, 300, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 300, 4, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 300, 4, 32))
    o1 = sdpa_chunked(q, k, v, causal=True, q_chunk=128, k_chunk=128)
    o2 = sdpa(q, k, v, causal=True)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4


def test_moe_routes_to_multiple_experts():
    from repro.models import moe as moe_lib

    cfg = dataclasses.replace(
        get_config("granite_moe_1b_a400m").reduced(), capacity_factor=8.0
    )
    p = moe_lib.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
    out, aux = moe_lib.moe_block(p, x, CTX, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) > 0.0
