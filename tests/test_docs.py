"""Docs stay honest: internal links resolve and the documented quickstart
snippet actually runs.

Marked ``docs`` and deselected from tier-1 (pytest.ini): CI runs this suite
in the dedicated docs job so the checks execute exactly once per CI run.
Locally: ``pytest -m docs``.
"""

import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.docs

ROOT = Path(__file__).resolve().parent.parent


def test_docs_internal_links_resolve():
    sys.path.insert(0, str(ROOT / "docs"))
    try:
        import check_links

        assert check_links.main() == 0
    finally:
        sys.path.remove(str(ROOT / "docs"))


def test_api_quickstart_snippet_runs():
    env_src = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "docs" / "run_quickstart.py")],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin:/usr/local/bin"},
        timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "quickstart snippet: ok" in proc.stdout


def test_docs_tree_complete():
    for name in ("architecture.md", "paper_map.md", "api.md"):
        assert (ROOT / "docs" / name).exists(), name
    assert (ROOT / "README.md").exists()
