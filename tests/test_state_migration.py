"""Stateful-elasticity tests (ISSUE 8).

Covers the state-aware migration cost model end to end — keyed operator
state on ``SkewModel``, ``placement_transfer``'s who-moves/how-much-state
accounting, state-proportional transfer pauses in the executor — plus the
elastic scale-out/drain machinery (``machine_addition``, capacity notice)
and regressions for the three repaired runtime bugs:

* the ``OracleRescheduler`` stale-plan cache (keyed on capacity only, so a
  ``key_skew_shift`` left it serving a plan tuned for dead hot keys);
* keyed backlog laundered into an even split on migration (contradicting
  the hash→instance routing that refills the queues);
* the one-sided cost/benefit guard (benefit ignored the service migrated
  instances forgo while paused).
"""

import numpy as np
import pytest

from repro.core import (
    keyed_rolling_count_topology,
    linear_topology,
    max_stable_rate,
    paper_cluster,
    schedule,
)
from repro.core.graph import ExecutionGraph, FieldsGrouping
from repro.core.refine import refine
from repro.runtime_stream import (
    OnlineController,
    OracleRescheduler,
    RuntimeConfig,
    StreamExecutor,
    TraceSpec,
    elastic_trace,
    machine_addition,
    placement_migrations,
    placement_transfer,
    provision_schedule,
    ramp_trace,
    rate_ramp,
    skew_shift_trace,
    transfer_pause_windows,
)
from repro.runtime_stream.executor import _Placement


@pytest.fixture(scope="module")
def cluster():
    return paper_cluster((1, 1, 1))


@pytest.fixture(scope="module")
def stateful_setup(cluster):
    """Keyed topology with operator state + its schedule and skew view."""
    utg = keyed_rolling_count_topology(n_keys=16, zipf_s=1.5, state_per_tuple=25.0)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    probe = StreamExecutor(
        etg, cluster, TraceSpec(name="probe", n_windows=2, base_rate=1.0), seed=5
    )
    skew = probe.skew_model_at(0)
    return utg, etg, skew


# ------------------------------------------------------- state model


def test_state_per_tuple_validation():
    with pytest.raises(ValueError, match="state_per_tuple"):
        FieldsGrouping(edge=(0, 1), n_keys=4, state_per_tuple=-1.0)


def test_state_monotone_in_key_share(stateful_setup):
    """Instance state follows realized key share: the hot instance holds
    the most state, shares and state sort identically, and the total is
    invariant under the instance count (resharding moves state, never
    creates it)."""
    utg, etg, skew = stateful_setup
    assert skew.has_state
    (c,) = [k for k in skew.keyed_components if skew.instance_state(k, 2).any()]
    total = skew.component_state()[c]
    assert total > 0.0
    for n in (2, 3, 5, 8):
        state = skew.instance_state(c, n)
        frac = skew.instance_fractions(c, n)
        assert state.shape == (n,)
        assert np.isclose(state.sum(), total)
        # same ordering: more key share => more state
        assert np.array_equal(np.argsort(state), np.argsort(frac))
    # per-task view concatenates per-component vectors in task order
    per_task = skew.per_task_state(etg.n_instances)
    offsets = etg.component_offsets()
    lo, hi = int(offsets[c]), int(offsets[c + 1])
    assert np.allclose(
        per_task[lo:hi], skew.instance_state(c, int(etg.n_instances[c]))
    )


def test_stateless_topologies_ship_no_state(cluster):
    """state_per_tuple defaults to 0: the keyed topology without declared
    state has no state surface, and every transfer ships zero."""
    utg = keyed_rolling_count_topology(n_keys=16, zipf_s=1.5)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    probe = StreamExecutor(
        etg, cluster, TraceSpec(name="probe", n_windows=2, base_rate=1.0), seed=5
    )
    skew = probe.skew_model_at(0)
    assert not skew.has_state
    assert not skew.component_state().any()
    assert not skew.per_task_state(etg.n_instances).any()
    moved = refine(etg, cluster, max_rounds=2, skew=skew).etg
    transfer = placement_transfer(etg, moved, skew=skew)
    assert transfer.state_shipped == 0.0
    assert not transfer.instance_state.any()


def test_transfer_matches_flat_moves_on_shuffle(cluster):
    """On shuffle-only topologies ``placement_transfer`` degenerates to
    ``placement_migrations`` (multiset semantics, no state) — the
    executor's migration metrics stay bit-identical to earlier PRs."""
    topo = linear_topology()
    etg = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg
    for rounds in (1, 2, 4):
        new = refine(etg, cluster, max_rounds=rounds).etg
        transfer = placement_transfer(etg, new)
        assert transfer.moves == placement_migrations(etg, new)
        assert transfer.state_shipped == 0.0
        assert transfer.migrated.sum() == transfer.moves


def test_drop_is_free_and_resize_rehashes(cluster, stateful_setup):
    """Shuffle drops ship nothing; a keyed-component resize rehashes every
    key, so the whole component restarts and reships its full state."""
    topo = linear_topology()
    base = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg
    # Shuffle drop: remove the last instance of a multi-instance component.
    c = int(np.argmax(base.n_instances))
    assert base.n_instances[c] >= 2
    n2 = base.n_instances.copy()
    n2[c] -= 1
    dropped = ExecutionGraph(
        utg=topo,
        n_instances=n2,
        assignment=[
            a[:-1].copy() if i == c else a.copy()
            for i, a in enumerate(base.assignment)
        ],
    )
    assert placement_transfer(base, dropped).moves == 0
    # Keyed resize: growing the stateful component restarts all of it.
    utg, etg, skew = stateful_setup
    (ck,) = [k for k in skew.keyed_components if skew.instance_state(k, 2).any()]
    nk = etg.n_instances.copy()
    nk[ck] += 1
    grown = ExecutionGraph(
        utg=utg,
        n_instances=nk,
        assignment=[
            np.concatenate([a, a[-1:]]) if i == ck else a.copy()
            for i, a in enumerate(etg.assignment)
        ],
    )
    transfer = placement_transfer(etg, grown, skew=skew)
    offsets = grown.component_offsets()
    lo, hi = int(offsets[ck]), int(offsets[ck + 1])
    assert transfer.migrated[lo:hi].all()
    assert np.isclose(
        transfer.instance_state[lo:hi].sum(), skew.component_state()[ck]
    )


def test_transfer_pause_scales_with_state(stateful_setup):
    """A hot-key instance pauses longer: pause = migration_pause +
    ceil(state / (rate · dt)); the default infinite transfer rate keeps
    the legacy flat pause."""
    utg, etg, skew = stateful_setup
    (c,) = [k for k in skew.keyed_components if skew.instance_state(k, 2).any()]
    new = refine(etg, cluster_f := paper_cluster((1, 1, 1)), max_rounds=3,
                 skew=skew).etg
    transfer = placement_transfer(etg, new, skew=skew)
    flat = transfer_pause_windows(transfer, RuntimeConfig(), 1.0)
    assert np.array_equal(flat, np.where(transfer.migrated, 1, 0))
    cfg = RuntimeConfig(state_transfer_rate=10.0)
    slow = transfer_pause_windows(transfer, cfg, 1.0)
    expect = np.where(
        transfer.migrated,
        1 + np.ceil(transfer.instance_state / 10.0).astype(np.int64),
        0,
    )
    assert np.array_equal(slow, expect)
    if transfer.instance_state.any():
        assert slow.max() > flat.max()


# ----------------------------------------- keyed backlog redistribution


def test_keyed_backlog_redistributes_by_share(cluster, stateful_setup):
    """Bugfix regression: on migration a keyed component's in-flight
    backlog re-splits by the realized key shares (the routing that refills
    the queues), not the even split the old code used."""
    utg, etg, skew = stateful_setup
    ex = StreamExecutor(
        etg, cluster, TraceSpec(name="probe", n_windows=2, base_rate=1.0), seed=5
    )
    (c,) = [k for k in skew.keyed_components if skew.instance_state(k, 2).any()]
    assign = [a.copy() for a in etg.assignment]
    assign[c][0] = (int(assign[c][0]) + 1) % cluster.n_machines
    new_etg = ExecutionGraph(
        utg=utg, n_instances=etg.n_instances.copy(), assignment=assign
    )
    place = _Placement(etg, cluster)
    T = place.comp.shape[0]
    backlog = np.linspace(1.0, 2.0, T)
    transfer = placement_transfer(etg, new_etg, skew=skew)
    new_place, new_backlog, pause = ex._migrate(
        place, new_etg, backlog, transfer, window=0
    )
    offsets = new_etg.component_offsets()
    lo, hi = int(offsets[c]), int(offsets[c + 1])
    n = hi - lo
    comp_total = backlog[place.comp == c].sum()
    frac = skew.instance_fractions(c, n)
    assert np.allclose(new_backlog[lo:hi], comp_total * frac)
    assert not np.allclose(new_backlog[lo:hi], comp_total / n)
    # shuffle components keep the exact even-split division
    for cs in range(utg.n_components):
        if cs in skew.keyed_components:
            continue
        ls, hs = int(offsets[cs]), int(offsets[cs + 1])
        total = backlog[place.comp == cs].sum()
        assert np.all(new_backlog[ls:hs] == total / (hs - ls))
    # the relocated keyed instance pauses; untouched instances don't
    assert pause[lo] > 0 and pause[lo + 1 : hi].sum() == 0


# ------------------------------------------------- two-sided guard


def test_guard_subtracts_paused_service(cluster, stateful_setup):
    """Bugfix regression: the guard now charges the service migrated
    instances forgo while paused. At a break-even point the one-sided
    guard would replan through, long pauses + a short horizon flip the
    decision to skip; with free restarts the same controller replans."""
    utg, etg, skew = stateful_setup
    r_even, _ = max_stable_rate(etg, cluster)
    spec = TraceSpec(name="hotkeys", n_windows=160, base_rate=0.95 * r_even)
    slow_cfg = RuntimeConfig(max_queue=120.0, migration_pause=40)
    ctl = OnlineController(utg, cluster, period=10, horizon_windows=60)
    res = StreamExecutor(etg, cluster, spec, seed=5, config=slow_cfg).run(
        controller=ctl
    )
    assert res.migrations.sum() == 0
    assert any("skip" in why for _, why in ctl.log)
    fast_cfg = RuntimeConfig(max_queue=120.0, migration_pause=0)
    ctl2 = OnlineController(utg, cluster, period=10, horizon_windows=60)
    res2 = StreamExecutor(etg, cluster, spec, seed=5, config=fast_cfg).run(
        controller=ctl2
    )
    assert res2.migrations.sum() > 0


def test_guard_prices_state_and_budget(cluster, stateful_setup):
    """State shows up in the guard's ledger (logged per decision), and
    ``elastic_budget`` hard-caps a replan's transfer cost."""
    utg, etg, skew = stateful_setup
    r_even, _ = max_stable_rate(etg, cluster)
    spec = TraceSpec(name="hotkeys", n_windows=120, base_rate=0.95 * r_even)
    cfg = RuntimeConfig(max_queue=120.0, state_transfer_rate=50.0)
    ctl = OnlineController(utg, cluster, period=10, elastic_budget=0.0)
    res = StreamExecutor(etg, cluster, spec, seed=5, config=cfg).run(controller=ctl)
    assert res.migrations.sum() == 0
    assert any("budget" in why for _, why in ctl.log)
    ctl2 = OnlineController(utg, cluster, period=10)
    res2 = StreamExecutor(etg, cluster, spec, seed=5, config=cfg).run(
        controller=ctl2
    )
    assert res2.migrations.sum() > 0
    assert any("state=" in why for _, why in ctl2.log)


# --------------------------------------------------- oracle cache fix


def test_oracle_replans_after_skew_shift(cluster):
    """Bugfix regression: the oracle's cache keys on (capacity, skew
    epoch). A ``key_skew_shift`` leaves capacity untouched, but the
    re-keyed oracle re-plans for the new hot keys instead of serving the
    stale cached placement for the rest of the trace."""
    utg = keyed_rolling_count_topology(n_keys=16, zipf_s=1.5)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.05).etg
    spec = skew_shift_trace(1.0, n_windows=120)
    shift_w = 40
    oracle = OracleRescheduler(utg, cluster)
    res = StreamExecutor(
        etg, cluster, spec, seed=7, config=RuntimeConfig(migration_pause=0)
    ).run(controller=oracle)
    assert len(oracle._cache) == 2  # one plan per skew epoch
    post = res.migrations[shift_w:]
    assert post.sum() > 0  # the shift actually produced a replan


# ------------------------------------------------- elastic scale-out/in


def test_machine_addition_compiles_capacity_column(cluster):
    utg = linear_topology()
    fleet = paper_cluster((1, 1, 2))
    spec = TraceSpec(
        name="elastic",
        n_windows=60,
        base_rate=1.0,
        events=(machine_addition(3, start=20, end=50),),
    )
    tr = spec.compile(fleet, seed=0, utg=utg)
    assert np.all(tr.capacity[:20, 3] == 0.0)
    assert np.all(tr.capacity[20:50, 3] == fleet.capacity[3])
    assert np.all(tr.capacity[50:, 3] == 0.0)
    assert (20, "add m3") in tr.events and (50, "remove m3") in tr.events


def test_controller_scales_out_onto_added_machine():
    """Tentpole acceptance: under a rate ramp past the initial fleet's
    bound, the controller rides a ``machine_addition`` — scale_out drift
    fires, the placement grows onto the new column, and online sustains
    more than the frozen static schedule."""
    topo = linear_topology()
    init = paper_cluster((1, 1, 1))
    fleet = paper_cluster((1, 1, 2))
    r3 = refine(schedule(topo, init, r0=1.0, rate_epsilon=0.05).etg, init).rate
    r4 = refine(schedule(topo, fleet, r0=1.0, rate_epsilon=0.05).etg, fleet).rate
    # join after the ramp passes the 3-machine bound, so the scale_out
    # replan's gain is immediate rather than demand-capped to zero
    spec = elastic_trace(0.5 * r3, 1.05 * r4, machine=3, n_windows=200, join=120)
    start = provision_schedule(topo, init, 0.5 * r3)
    cfg = RuntimeConfig(max_queue=120.0)
    static = StreamExecutor(start, fleet, spec, config=cfg).run()
    ctl = OnlineController(topo, fleet, period=10)
    online = StreamExecutor(start, fleet, spec, config=cfg).run(controller=ctl)
    assert any(why.startswith("scale_out:replan") for _, why in ctl.log)
    assert np.any(online.final_etg.task_machine() == 3)
    assert online.sustained_throughput() > 1.1 * static.sustained_throughput()


def test_controller_drains_before_machine_removal():
    """Capacity notice: a leased machine's removal is announced
    ``capacity_notice`` windows ahead; the controller drains it *before*
    the column drops, so the removal window itself migrates nothing."""
    topo = linear_topology()
    init = paper_cluster((1, 1, 1))
    fleet = paper_cluster((1, 1, 2))
    r3 = refine(schedule(topo, init, r0=1.0, rate_epsilon=0.05).etg, init).rate
    leave = 140
    spec = TraceSpec(
        name="lease",
        n_windows=200,
        base_rate=1.35 * r3,
        events=(machine_addition(3, start=10, end=leave),),
    )
    start = provision_schedule(topo, init, 1.35 * r3)
    cfg = RuntimeConfig(max_queue=120.0, capacity_notice=25)
    ctl = OnlineController(topo, fleet, period=10)
    online = StreamExecutor(start, fleet, spec, config=cfg).run(controller=ctl)
    drains = [w for w, why in ctl.log if why.startswith("drain:replan")]
    assert drains and max(drains) < leave
    # drained proactively: nothing moves at/after the removal itself
    assert online.migrations[leave - 1 : leave + 15].sum() == 0
    assert np.all(online.final_etg.task_machine() != 3)


# --------------------------------------------------------- latency view


def test_latency_view_derived_and_slo(cluster):
    """Latency is a derived view (fingerprints unchanged): zero when
    queues are empty, capped at the horizon, and the SLO fraction is the
    tail share of windows within the bound."""
    topo = linear_topology()
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    calm = StreamExecutor(
        full.etg, cluster, TraceSpec(name="calm", n_windows=60, base_rate=0.3 * full.rate)
    ).run()
    lat = calm.latency()
    assert lat.shape == (60,)
    assert np.all(lat >= 0.0) and np.all(lat <= 60 * calm.window_s)
    assert calm.latency_slo_frac(5.0) == 1.0
    hot = StreamExecutor(
        full.etg, cluster,
        TraceSpec(name="hot", n_windows=120, base_rate=2.0 * full.rate),
        config=RuntimeConfig(max_queue=120.0),
    ).run()
    assert hot.latency_slo_frac(0.5) < 1.0


def test_eval_latency_matches_executor(cluster):
    """PolicyEvalResult's derived latency agrees with the executor's on
    the reference backend (same formula, same inputs)."""
    from repro.runtime_stream import evaluate_policies_batch

    topo = linear_topology()
    full = refine(schedule(topo, cluster, r0=1.0, rate_epsilon=0.05).etg, cluster)
    spec = ramp_trace(0.3 * full.rate, 1.4 * full.rate, n_windows=80)
    tr = spec.compile(cluster, seed=2)
    res = StreamExecutor(full.etg, cluster, tr).run()
    batch = evaluate_policies_batch(
        full.etg, cluster, [tr], full.etg.task_machine()[None, :], backend="numpy"
    )
    assert np.allclose(batch.latency()[0, 0], res.latency())
    assert np.isclose(
        batch.latency_slo_frac(5.0)[0, 0], res.latency_slo_frac(5.0)
    )
