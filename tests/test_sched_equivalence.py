"""Golden tests: the incremental engines must reproduce the reference paths
exactly, and the JAX backends must agree with NumPy to 1e-9.

These are the acceptance gates for the incremental scheduling engine
(``repro.core.schedule_state``) and the batch-scored refine/optimal engines
built on it: same final rate, same instance counts, same placement, same
iteration trace / move list / candidate count as the seed implementations —
not merely "close" — across topology shapes and cluster sizes.
"""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    Profile,
    UserGraph,
    diamond_topology,
    linear_topology,
    max_stable_rate,
    max_stable_rate_batch,
    optimal_schedule,
    paper_cluster,
    rolling_count_topology,
    schedule,
    simulate_batch,
    star_topology,
    wide_fanout_topology,
)
from repro.core.refine import refine
from repro.core.schedule_state import ScheduleState

TOPOLOGIES = {
    "linear": linear_topology,
    "diamond": diamond_topology,
    "star": star_topology,
    "rolling_count": rolling_count_topology,  # alpha != 1 exercises eq. 6
}
CLUSTERS = {"small": (1, 1, 1), "medium": (2, 2, 2)}


def _fingerprint(sched):
    return (
        sched.rate,
        sched.etg.n_instances.tolist(),
        sched.etg.task_machine().tolist(),
        sched.iterations,
        sched.trace,
    )


@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("cluster_name", sorted(CLUSTERS))
def test_incremental_engine_matches_reference(topo_name, cluster_name):
    topo = TOPOLOGIES[topo_name]()
    cluster = paper_cluster(CLUSTERS[cluster_name])
    ref = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05, engine="reference")
    inc = schedule(topo, cluster, r0=1.0, rate_epsilon=0.05, engine="incremental")
    assert _fingerprint(inc) == _fingerprint(ref)
    assert inc.predicted_throughput == pytest.approx(ref.predicted_throughput)


def test_incremental_engine_matches_reference_medium_cluster():
    """(10,10,10): hundreds of instances, multi-instance growth steps."""
    cluster = paper_cluster((10, 10, 10))
    topo = linear_topology()
    ref = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="reference")
    inc = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0, engine="incremental")
    assert _fingerprint(inc) == _fingerprint(ref)


def test_large_scenario_golden():
    """Paper's large scenario (20/70/90): the incremental engine must land on
    the frozen golden schedule (captured from the seed reference path, which
    takes ~12-25 s to recompute depending on the machine — too slow here)."""
    sched = schedule(linear_topology(), paper_cluster((20, 70, 90)),
                     r0=1.0, rate_epsilon=1.0)
    assert sched.rate == 297.0
    assert sched.etg.n_instances.tolist() == [2, 56, 210, 210]
    assert sched.iterations == 46
    import hashlib

    digest = hashlib.md5(sched.etg.task_machine().tobytes()).hexdigest()
    assert digest == "1dfed7471c737dcb63fc259cb03ffe02"


def test_optimal_symmetry_pruning_preserves_optimum():
    """On clusters with duplicate machines, the canonical filter must keep
    the true optimum while evaluating strictly fewer candidates."""
    from repro.core import optimal_schedule

    for counts in ((2, 1, 1), (3, 0, 0)):
        cluster = paper_cluster(counts)
        full = optimal_schedule(
            linear_topology(), cluster, max_total_tasks=6, prune_symmetry=False
        )
        pruned = optimal_schedule(linear_topology(), cluster, max_total_tasks=6)
        assert pruned.throughput == pytest.approx(full.throughput, rel=1e-12)
        assert pruned.rate == pytest.approx(full.rate, rel=1e-12)
        assert pruned.candidates_evaluated < full.candidates_evaluated


# ------------------------------------------------- refine/optimal engines


@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("cluster_name", sorted(CLUSTERS))
def test_refine_engines_identical(topo_name, cluster_name):
    """refine(engine="state") must replay the reference hill climb exactly:
    same move list, same placement, same floats — the golden acceptance
    gate for the delta-scored refinement engine."""
    topo = TOPOLOGIES[topo_name]()
    cluster = paper_cluster(CLUSTERS[cluster_name])
    etg = schedule(topo, cluster, r0=1.0, rate_epsilon=0.5).etg
    ref = refine(etg, cluster, engine="reference")
    state = refine(etg, cluster, engine="state")
    assert state.moves == ref.moves
    assert state.rate == ref.rate
    assert state.throughput == ref.throughput
    assert state.etg.n_instances.tolist() == ref.etg.n_instances.tolist()
    assert state.etg.task_machine().tolist() == ref.etg.task_machine().tolist()


def test_refine_engines_identical_no_add():
    cluster = paper_cluster((2, 2, 2))
    etg = schedule(star_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    ref = refine(etg, cluster, allow_add=False, engine="reference")
    state = refine(etg, cluster, allow_add=False, engine="state")
    assert state.moves == ref.moves
    assert state.etg.task_machine().tolist() == ref.etg.task_machine().tolist()
    assert state.rate == ref.rate


def test_refine_slow_suite_golden():
    """Frozen expectations for the slow-suite scenario (rate_epsilon=0.05 on
    the paper's 3-worker cluster) so the fast engine is pinned even when the
    reference comparison doesn't run. ``candidates_evaluated`` and
    ``classes_pruned`` are pinned alongside the floats: a silent regression
    in the beam bound (pruning a class it must not, or silently pruning
    nothing) fails loudly here instead of only shifting runtime."""
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.05).etg
    res = refine(etg, cluster)
    assert res.moves == ["grow c2x3", "swap c1#0<->c3#1"]
    assert res.etg.n_instances.tolist() == [1, 1, 5, 4]
    assert res.throughput == pytest.approx(22.727405035657107, rel=1e-12)
    opt = optimal_schedule(linear_topology(), cluster, max_total_tasks=8)
    # 46089 enumerated without the bound; 26217 with the pre-PR-4
    # running-best bound; best-bound-first ordering + the
    # schedule()+refine() incumbent seed prune one class more.
    assert opt.candidates_evaluated == 26136
    assert opt.classes_pruned == 35
    assert opt.etg.n_instances.tolist() == [1, 2, 1, 3]
    assert opt.throughput == pytest.approx(23.268698060941833, rel=1e-12)


@pytest.mark.parametrize("prune", [True, False])
@pytest.mark.parametrize("max_per_machine", [None, 3])
def test_optimal_engines_identical(prune, max_per_machine):
    """optimal_schedule(engine="state") must reproduce the reference search
    exactly, including the number of candidates surviving the filters."""
    cluster = paper_cluster((2, 1, 1))
    ref = optimal_schedule(
        linear_topology(), cluster, max_total_tasks=6,
        max_per_machine=max_per_machine, prune_symmetry=prune,
        engine="reference",
    )
    state = optimal_schedule(
        linear_topology(), cluster, max_total_tasks=6,
        max_per_machine=max_per_machine, prune_symmetry=prune,
        engine="state",
    )
    assert state.rate == ref.rate
    assert state.throughput == ref.throughput
    assert state.candidates_evaluated == ref.candidates_evaluated
    assert state.etg.n_instances.tolist() == ref.etg.n_instances.tolist()
    assert state.etg.task_machine().tolist() == ref.etg.task_machine().tolist()


def test_engine_validation():
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    with pytest.raises(ValueError, match="engine"):
        refine(etg, cluster, engine="quantum")
    with pytest.raises(ValueError, match="engine"):
        optimal_schedule(linear_topology(), cluster, max_total_tasks=5,
                         engine="quantum")


def test_schedule_state_deltas_match_rebuild():
    """relocate/swap/drop deltas must leave the state identical to one
    rebuilt from scratch off the resulting ETG."""
    cluster = paper_cluster((2, 2, 2))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    state = ScheduleState.from_etg(etg, cluster)
    snap = state.snapshot()
    state.add_instance(2, 4)
    state.relocate_instance(2, 0, 5)
    state.swap_instances(1, 0, 2, 1)
    state.drop_instance(3, 0)
    rebuilt = ScheduleState.from_etg(state.to_etg(), cluster)
    assert np.array_equal(state.comp_counts, rebuilt.comp_counts)
    assert np.array_equal(state.n_instances, rebuilt.n_instances)
    assert np.allclose(state.var_load, rebuilt.var_load, rtol=0, atol=0)
    assert np.allclose(state.met_load, rebuilt.met_load, rtol=0, atol=0)
    state.restore(snap)
    assert state.to_etg().task_machine().tolist() == etg.task_machine().tolist()
    with pytest.raises(ValueError, match="instance"):
        state.drop_instance(0, 0)  # spout has a single instance


def test_state_batch_scorer_bit_exact():
    """ScheduleState.score_task_machine_batch must equal
    max_stable_rate_batch bit-for-bit — the refine engine's equivalence
    guarantee rests on it."""
    cluster = paper_cluster((2, 2, 2))
    etg = schedule(diamond_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    state = ScheduleState.from_etg(etg, cluster)
    rng = np.random.default_rng(11)
    tm = rng.integers(0, cluster.n_machines, size=(64, etg.total_tasks))
    r_ref, t_ref = max_stable_rate_batch(etg, cluster, tm)
    r_st, t_st = state.score_task_machine_batch(tm)
    assert np.array_equal(r_ref, r_st)
    assert np.array_equal(t_ref, t_st)
    # modified instance-count vector (ADD-style candidates)
    n_new = state.n_instances.copy()
    n_new[2] += 1
    tm2 = rng.integers(0, cluster.n_machines, size=(16, etg.total_tasks + 1))
    template = state.template_etg(n_new)
    r_ref2, t_ref2 = max_stable_rate_batch(template, cluster, tm2)
    r_st2, t_st2 = state.score_task_machine_batch(tm2, n_new)
    assert np.array_equal(r_ref2, r_st2)
    assert np.array_equal(t_ref2, t_st2)


def test_schedule_state_loads_match_prediction():
    """ScheduleState accumulators == per-task predict() on the same graph."""
    from repro.core import first_assignment, predict

    cluster = paper_cluster((2, 2, 2))
    etg = first_assignment(diamond_topology(), cluster, 1.0)
    state = ScheduleState.from_etg(etg, cluster)
    for rate in (1.0, 3.5, 10.0):
        pred = predict(etg, cluster, rate)
        assert np.allclose(state.utilization(rate), pred.machine_util, rtol=1e-12)
    rstar = state.max_stable_rate()
    ref_rate, _ = max_stable_rate(etg, cluster)
    assert rstar == pytest.approx(ref_rate, rel=1e-12)


def test_schedule_state_snapshot_roundtrip():
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    state = ScheduleState.from_etg(etg, cluster)
    snap = state.snapshot()
    before = (state.n_instances.copy(), state.var_load.copy(), state.met_load.copy())
    state.add_instance(2, 1)
    state.add_instance(3, 0)
    assert state.n_instances[2] == before[0][2] + 1
    state.restore(snap)
    assert np.array_equal(state.n_instances, before[0])
    assert np.allclose(state.var_load, before[1], rtol=0, atol=0)
    assert np.allclose(state.met_load, before[2], rtol=0, atol=0)
    assert state.to_etg().task_machine().tolist() == etg.task_machine().tolist()


# ------------------------------------------------------------ JAX backend


@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
def test_simulator_backends_agree(topo_name):
    """NumPy and JAX fixed points agree to 1e-9 under back-pressure."""
    pytest.importorskip("jax")
    topo = TOPOLOGIES[topo_name]()
    cluster = paper_cluster((2, 2, 2))
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.5)
    etg = sched.etg
    rng = np.random.default_rng(7)
    tm = rng.integers(0, cluster.n_machines, size=(32, etg.total_tasks))
    rate, _ = max_stable_rate(etg, cluster)
    base = max(rate, 1.0)
    for r0 in (0.5 * base, 3.0 * base, 50.0 * base):  # stable -> saturated
        a = simulate_batch(etg, cluster, tm, r0, backend="numpy")
        b = simulate_batch(etg, cluster, tm, r0, backend="jax")
        for field in ("ir", "pr", "tcu", "machine_util", "throughput"):
            x, y = getattr(a, field), getattr(b, field)
            assert np.allclose(x, y, rtol=1e-9, atol=1e-9), (field, r0)


def test_backpressure_fixed_point_converges_saturated():
    """Deep overload: the fixed point must converge (not just hit the iter
    cap) and respect capacity + back-pressure invariants on both backends."""
    pytest.importorskip("jax")
    topo = rolling_count_topology()  # alpha=4 amplifies downstream load
    cluster = paper_cluster((1, 1, 1))
    sched = schedule(topo, cluster, r0=1.0, rate_epsilon=0.5)
    etg = sched.etg
    rate, _ = max_stable_rate(etg, cluster)
    tm = etg.task_machine()[None, :]
    for backend in ("numpy", "jax"):
        res = simulate_batch(etg, cluster, tm, rate * 1000.0, backend=backend)
        assert np.all(res.machine_util <= cluster.capacity[None, :] + 1e-6)
        assert np.all(res.pr <= res.ir + 1e-9)
        stable = simulate_batch(etg, cluster, tm, rate * 0.99, backend=backend)
        # saturated throughput is bounded, not linear in offered rate
        assert res.throughput[0] <= stable.throughput[0] * 1100


def test_max_stable_rate_batch_jax_backend():
    """The jitted closed-form scorer agrees with NumPy to 1e-9 (scatter-add
    association differs, so bit-exactness is not expected)."""
    pytest.importorskip("jax")
    cluster = paper_cluster((2, 2, 2))
    etg = schedule(diamond_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    rng = np.random.default_rng(5)
    tm = rng.integers(0, cluster.n_machines, size=(128, etg.total_tasks))
    rn, tn = max_stable_rate_batch(etg, cluster, tm, backend="numpy")
    rj, tj = max_stable_rate_batch(etg, cluster, tm, backend="jax")
    assert np.allclose(rn, rj, rtol=1e-9, atol=1e-9)
    assert np.allclose(tn, tj, rtol=1e-9, atol=1e-9)
    with pytest.raises(ValueError, match="backend"):
        max_stable_rate_batch(etg, cluster, tm, backend="tpu")


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_simulate_batch_per_row_rates(backend):
    """A (B,) r0 vector must match per-row scalar sweeps on both backends
    (to the fixed point's own tolerance — batch rows share the convergence
    criterion)."""
    if backend == "jax":
        pytest.importorskip("jax")
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(rolling_count_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    base, _ = max_stable_rate(etg, cluster)
    tm = np.tile(etg.task_machine(), (3, 1))
    rates = np.array([0.5 * base, base, 100.0 * base])
    batch = simulate_batch(etg, cluster, tm, rates, backend=backend)
    for i, r in enumerate(rates):
        solo = simulate_batch(etg, cluster, tm[i : i + 1], float(r), backend=backend)
        assert np.allclose(batch.pr[i], solo.pr[0], rtol=1e-8, atol=1e-8)
        assert np.allclose(
            batch.machine_util[i], solo.machine_util[0], rtol=1e-8, atol=1e-8
        )
    with pytest.raises(ValueError, match="r0"):
        simulate_batch(etg, cluster, tm, np.ones(5), backend=backend)


def test_simulator_backend_fallback_and_validation():
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    tm = etg.task_machine()[None, :]
    with pytest.raises(ValueError, match="backend"):
        simulate_batch(etg, cluster, tm, 1.0, backend="tpu")
    auto = simulate_batch(etg, cluster, tm, 1.0, backend="auto")
    ref = simulate_batch(etg, cluster, tm, 1.0, backend="numpy")
    assert np.allclose(auto.throughput, ref.throughput, rtol=1e-9)


# ------------------------------------- wide / heterogeneous deterministic


def het_profile_cluster() -> Cluster:
    """Deterministic heterogeneous cluster: non-Table-3 profile shape
    (machine types fast for some task types, slow for others) plus uneven
    per-machine capacities."""
    profile = Profile(
        e=np.array(
            [
                [0.4, 0.9, 0.6],
                [22.0, 6.5, 11.0],
                [7.0, 19.0, 9.5],
                [13.0, 10.0, 24.0],
            ]
        ),
        met=np.array(
            [
                [0.6, 1.1, 0.8],
                [2.4, 0.9, 1.7],
                [1.2, 3.1, 0.7],
                [0.8, 1.9, 2.6],
            ]
        ),
        type_names=("spout", "t1", "t2", "t3"),
        machine_type_names=("m0", "m1", "m2"),
    )
    return Cluster(
        machine_types=np.array([0, 1, 1, 2]),
        capacity=np.array([140.0, 75.0, 110.0, 90.0]),
        profile=profile,
    )


def _assert_refine_engines_identical(etg, cluster, **kwargs):
    ref = refine(etg, cluster, engine="reference", **kwargs)
    state = refine(etg, cluster, engine="state", **kwargs)
    seq = refine(etg, cluster, engine="state", lockstep=False, **kwargs)
    for res in (state, seq):
        assert res.moves == ref.moves
        assert res.rate == ref.rate
        assert res.throughput == ref.throughput
        assert res.etg.n_instances.tolist() == ref.etg.n_instances.tolist()
        assert res.etg.task_machine().tolist() == ref.etg.task_machine().tolist()


def test_refine_engines_identical_wide_topology():
    """10-component fan-out: 45 pair chains advance in lockstep; the batched
    explorer must still replay the reference climb move for move, and the
    sequential explorer must agree with both."""
    topo = wide_fanout_topology()
    cluster = paper_cluster((2, 1, 1))
    etg = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0).etg
    _assert_refine_engines_identical(etg, cluster, max_rounds=3)


def test_engines_identical_heterogeneous_profile():
    """Engine agreement must not depend on the paper's Table 3 numbers:
    schedule + refine replay exactly on a non-paper profile with uneven
    per-machine capacities."""
    cluster = het_profile_cluster()
    for topo in (linear_topology(), wide_fanout_topology(6)):
        ref = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0,
                       engine="reference")
        inc = schedule(topo, cluster, r0=1.0, rate_epsilon=1.0,
                       engine="incremental")
        assert _fingerprint(inc) == _fingerprint(ref)
        _assert_refine_engines_identical(inc.etg, cluster, max_rounds=2)


# ------------------------------------------------- per-row count scoring


def test_score_batch_per_row_counts_bit_exact():
    """A (B, n) per-row count matrix must score every row bit-identically
    to a shared-count call against that row's own template — the lockstep
    chain sweeps rest on this."""
    cluster = paper_cluster((2, 2, 2))
    etg = schedule(diamond_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    state = ScheduleState.from_etg(etg, cluster)
    rng = np.random.default_rng(23)
    n = etg.utg.n_components
    T = etg.total_tasks
    B = 24
    counts = np.tile(etg.n_instances, (B, 1))
    counts[np.arange(B), rng.integers(0, n, size=B)] += 1   # grow one comp
    tm = rng.integers(0, cluster.n_machines, size=(B, T + 1))
    r_batch, t_batch = state.score_task_machine_batch(tm, counts)
    r_cm, t_cm = max_stable_rate_batch(etg, cluster, tm, n_instances=counts)
    assert np.array_equal(r_batch, r_cm)
    assert np.array_equal(t_batch, t_cm)
    for b in range(B):
        template = state.template_etg(counts[b])
        r_solo, t_solo = max_stable_rate_batch(template, cluster, tm[b : b + 1])
        assert r_batch[b] == r_solo[0]
        assert t_batch[b] == t_solo[0]


def test_per_row_counts_validation():
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    state = ScheduleState.from_etg(etg, cluster)
    T = etg.total_tasks
    tm = np.zeros((2, T), dtype=np.int64)
    bad = np.tile(etg.n_instances, (2, 1))
    bad[1, 0] += 1  # row sums differ from T
    with pytest.raises(ValueError, match="sum"):
        state.score_task_machine_batch(tm, bad)
    with pytest.raises(ValueError, match="B, n"):
        state.score_task_machine_batch(tm, np.ones((3, 2), dtype=np.int64))
    zero = np.tile(etg.n_instances, (2, 1))
    zero[0, 1] = 0
    zero[0, 2] += 1
    with pytest.raises(ValueError, match="instance"):
        state.score_task_machine_batch(tm, zero)


# ------------------------------------------------------ beam bound (R*)


@pytest.mark.parametrize("topo_fn", [linear_topology, diamond_topology])
def test_optimal_beam_bound_exact(topo_fn):
    """The closed-form class bound — now seeded with schedule()+refine()'s
    incumbent and enumerated best-bound-first — must never change the
    reported optimum *or placement*, only skip classes that cannot
    contain it (the original-rank tie-break pins the winner)."""
    topo = topo_fn()
    cluster = paper_cluster((2, 1, 1))
    mtt = topo.n_components + 2
    on = optimal_schedule(topo, cluster, max_total_tasks=mtt)
    off = optimal_schedule(topo, cluster, max_total_tasks=mtt,
                           prune_bound=False)
    assert on.throughput == off.throughput
    assert on.rate == off.rate
    assert on.etg.task_machine().tolist() == off.etg.task_machine().tolist()
    assert on.candidates_evaluated <= off.candidates_evaluated
    assert off.classes_pruned == 0
    # The incumbent seed prunes more (or the same), never different results.
    unseeded = optimal_schedule(topo, cluster, max_total_tasks=mtt,
                                seed_incumbent=False)
    assert unseeded.throughput == on.throughput
    assert unseeded.etg.task_machine().tolist() == on.etg.task_machine().tolist()
    assert on.candidates_evaluated <= unseeded.candidates_evaluated
    # Larger budgets leave room for the bound to fire; the slow-suite
    # golden pins exact counts on a scenario where it demonstrably does.
    ref = optimal_schedule(topo, cluster, max_total_tasks=mtt,
                           engine="reference")
    assert ref.candidates_evaluated == on.candidates_evaluated
    assert ref.classes_pruned == on.classes_pruned


def test_optimal_beam_bound_prunes_on_het_cluster():
    """On the heterogeneous cluster the per-task relaxation bites early:
    whole composition classes must be skipped while the optimum and the
    engine agreement survive."""
    cluster = het_profile_cluster()
    topo = linear_topology()
    on = optimal_schedule(topo, cluster, max_total_tasks=7)
    off = optimal_schedule(topo, cluster, max_total_tasks=7, prune_bound=False)
    assert on.classes_pruned > 0
    assert on.candidates_evaluated < off.candidates_evaluated
    assert on.throughput == off.throughput
    assert on.etg.task_machine().tolist() == off.etg.task_machine().tolist()


# ------------------------------------------- backend parity + dispatch


@pytest.mark.parametrize("B", [1, 2, 1000])
def test_closed_form_backend_parity_sweep(B):
    """NumPy vs JAX closed-form scoring across batch sizes: <= 1e-12
    agreement and the *same* winning row, for shared and per-row counts."""
    pytest.importorskip("jax")
    cluster = paper_cluster((2, 2, 2))
    etg = schedule(star_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    rng = np.random.default_rng(17)
    n = etg.utg.n_components
    T = etg.total_tasks
    tm = rng.integers(0, cluster.n_machines, size=(B, T))
    rn, tn = max_stable_rate_batch(etg, cluster, tm, backend="numpy")
    rj, tj = max_stable_rate_batch(etg, cluster, tm, backend="jax")
    assert np.allclose(rn, rj, rtol=1e-12, atol=1e-12)
    assert np.allclose(tn, tj, rtol=1e-12, atol=1e-12)
    assert int(np.argmax(tn)) == int(np.argmax(tj))
    # per-row count vectors
    counts = np.tile(etg.n_instances, (B, 1))
    counts[np.arange(B), rng.integers(0, n, size=B)] += 1
    tm2 = rng.integers(0, cluster.n_machines, size=(B, T + 1))
    rn2, tn2 = max_stable_rate_batch(
        etg, cluster, tm2, backend="numpy", n_instances=counts
    )
    rj2, tj2 = max_stable_rate_batch(
        etg, cluster, tm2, backend="jax", n_instances=counts
    )
    assert np.allclose(rn2, rj2, rtol=1e-12, atol=1e-12)
    assert np.allclose(tn2, tj2, rtol=1e-12, atol=1e-12)
    assert int(np.argmax(tn2)) == int(np.argmax(tj2))


def test_closed_form_auto_dispatch(monkeypatch):
    """"auto" resolves to NumPy below the crossover (and always on
    CPU-only hosts); the env override recalibrates without code changes."""
    from repro.core.simulator import resolve_closed_form_backend

    monkeypatch.delenv("REPRO_CLOSED_FORM_JAX_THRESHOLD", raising=False)
    assert resolve_closed_form_backend("auto", None) == "numpy"
    assert resolve_closed_form_backend("auto", 10) == "numpy"
    with pytest.raises(ValueError, match="backend"):
        resolve_closed_form_backend("tpu")
    monkeypatch.setenv("REPRO_CLOSED_FORM_JAX_THRESHOLD", "100")
    assert resolve_closed_form_backend("auto", 99) == "numpy"
    resolved = resolve_closed_form_backend("auto", 100)
    try:
        import jax  # noqa: F401

        assert resolved == "jax"
    except ImportError:
        assert resolved == "numpy"


def test_refine_auto_backend_matches_numpy_when_forced_small(monkeypatch):
    """With the override forcing JAX from the first element, refine's auto
    path must still reach a schedule of identical quality (move tie-order
    may differ at 1e-15 scoring deltas — that is the documented trade)."""
    pytest.importorskip("jax")
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    base = refine(etg, cluster, backend="numpy")
    monkeypatch.setenv("REPRO_CLOSED_FORM_JAX_THRESHOLD", "1")
    forced = refine(etg, cluster, backend="auto")
    assert forced.throughput == pytest.approx(base.throughput, rel=1e-9)


# ------------------------------------------- simulate_batch edge cases


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_simulate_batch_empty_batch(backend):
    """B=0 must return correctly-shaped empties, not crash the fixed
    point's convergence reduction."""
    if backend == "jax":
        pytest.importorskip("jax")
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    T = etg.total_tasks
    tm = np.zeros((0, T), dtype=np.int64)
    res = simulate_batch(etg, cluster, tm, 1.0, backend=backend)
    assert res.ir.shape == (0, T)
    assert res.pr.shape == (0, T)
    assert res.tcu.shape == (0, T)
    assert res.machine_util.shape == (0, cluster.n_machines)
    assert res.throughput.shape == (0,)
    # (0,)-length per-row r0 vector is also valid for an empty batch
    res2 = simulate_batch(etg, cluster, tm, np.zeros(0), backend=backend)
    assert res2.throughput.shape == (0,)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_simulate_batch_single_machine_cluster(backend):
    """m=1: every task shares the one machine; the steady state must match
    the closed form below R* and saturate above it."""
    if backend == "jax":
        pytest.importorskip("jax")
    cluster = paper_cluster((1, 0, 0))
    assert cluster.n_machines == 1
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    rate, thpt = max_stable_rate(etg, cluster)
    tm = etg.task_machine()[None, :]
    stable = simulate_batch(etg, cluster, tm, rate * 0.9, backend=backend)
    assert stable.machine_util.shape == (1, 1)
    assert np.all(stable.machine_util <= cluster.capacity[None, :] + 1e-9)
    assert stable.throughput[0] == pytest.approx(thpt * 0.9, rel=1e-6)
    hot = simulate_batch(etg, cluster, tm, rate * 50.0, backend=backend)
    assert np.all(hot.machine_util <= cluster.capacity[None, :] + 1e-6)
    assert hot.throughput[0] <= stable.throughput[0] * 60.0


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_simulate_batch_length_one_rate_vector(backend):
    """A (1,) per-row r0 vector with B=1 must behave exactly like the
    scalar call (the degenerate broadcast the validation must admit)."""
    if backend == "jax":
        pytest.importorskip("jax")
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(rolling_count_topology(), cluster, r0=1.0,
                   rate_epsilon=0.5).etg
    rate, _ = max_stable_rate(etg, cluster)
    tm = etg.task_machine()[None, :]
    vec = simulate_batch(etg, cluster, tm, np.array([rate * 2.0]),
                         backend=backend)
    scal = simulate_batch(etg, cluster, tm, rate * 2.0, backend=backend)
    assert np.allclose(vec.pr, scal.pr, rtol=0, atol=0)
    assert np.allclose(vec.machine_util, scal.machine_util, rtol=0, atol=0)
    assert vec.throughput[0] == scal.throughput[0]
