"""Shared hypothesis strategies for scheduler property/equivalence tests.

Imported by ``test_core_properties.py`` and
``test_engine_equivalence_random.py`` — both guard the import behind
``pytest.importorskip("hypothesis")`` (the dev image may not ship
hypothesis; see requirements-dev.txt), so this module may import it at the
top level.
"""

import numpy as np
from hypothesis import strategies as st

from repro.core import UserGraph, paper_cluster, paper_profile

PROFILE = paper_profile()


@st.composite
def random_dag(draw, max_components: int = 6):
    """Random small DAG with spout 0 feeding everything (edges i->j, i<j)."""
    n = draw(st.integers(2, max_components))
    types = [0] + [draw(st.integers(1, 3)) for _ in range(n - 1)]
    edges = set()
    for j in range(1, n):
        # at least one parent with smaller index
        parent = draw(st.integers(0, j - 1))
        edges.add((parent, j))
        for i in range(j):
            if draw(st.booleans()) and draw(st.booleans()):
                edges.add((i, j))
    alpha = [1.0] + [draw(st.floats(0.25, 3.0)) for _ in range(n - 1)]
    return UserGraph(
        name="rand",
        component_types=np.array(types),
        edges=tuple(sorted(edges)),
        alpha=np.array(alpha),
    )


@st.composite
def random_cluster(draw, max_per_type: int = 3):
    counts = tuple(draw(st.integers(0, max_per_type)) for _ in range(3))
    if sum(counts) == 0:
        counts = (1, 1, 1)
    return paper_cluster(counts, PROFILE)
