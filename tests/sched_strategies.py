"""Shared hypothesis strategies for scheduler property/equivalence tests.

Imported by ``test_core_properties.py`` and
``test_engine_equivalence_random.py`` — both guard the import behind
``pytest.importorskip("hypothesis")`` (the dev image may not ship
hypothesis; see requirements-dev.txt), so this module may import it at the
top level.
"""

import numpy as np
from hypothesis import strategies as st

from repro.core import (
    Cluster,
    FieldsGrouping,
    Profile,
    UserGraph,
    paper_cluster,
    paper_profile,
    rack_distance_matrix,
)

PROFILE = paper_profile()


@st.composite
def random_dag(draw, max_components: int = 6):
    """Random small DAG with spout 0 feeding everything (edges i->j, i<j)."""
    n = draw(st.integers(2, max_components))
    types = [0] + [draw(st.integers(1, 3)) for _ in range(n - 1)]
    edges = set()
    for j in range(1, n):
        # at least one parent with smaller index
        parent = draw(st.integers(0, j - 1))
        edges.add((parent, j))
        for i in range(j):
            if draw(st.booleans()) and draw(st.booleans()):
                edges.add((i, j))
    alpha = [1.0] + [draw(st.floats(0.25, 3.0)) for _ in range(n - 1)]
    return UserGraph(
        name="rand",
        component_types=np.array(types),
        edges=tuple(sorted(edges)),
        alpha=np.array(alpha),
    )


@st.composite
def random_wide_dag(draw, min_components: int = 8, max_components: int = 12):
    """Wide, high-fan-out DAG: spout 0 feeds every middle component
    directly (fan-out >= 6), middles optionally feed a shared sink.

    The shape the lockstep growth explorer was built for: many components
    means many simultaneous single/pair growth chains per refine round
    (C(n, 2) pair chains at n >= 8), and a shallow graph keeps eq. 6
    propagation from dominating the comparison.
    """
    n = draw(st.integers(min_components, max_components))
    types = [0] + [draw(st.integers(1, 3)) for _ in range(n - 1)]
    has_sink = draw(st.booleans())
    n_mid = n - 1 - (1 if has_sink else 0)
    edges = set((0, j) for j in range(1, n_mid + 1))
    if has_sink:
        sink = n - 1
        for j in range(1, n_mid + 1):
            if draw(st.booleans()):
                edges.add((j, sink))
        if not any(b == sink for _, b in edges):
            edges.add((1, sink))
    alpha = [1.0] + [draw(st.floats(0.25, 2.0)) for _ in range(n - 1)]
    return UserGraph(
        name="rand_wide",
        component_types=np.array(types),
        edges=tuple(sorted(edges)),
        alpha=np.array(alpha),
    )


@st.composite
def random_keyed_dag(
    draw,
    max_components: int = 6,
    max_keys: int = 48,
    max_zipf_s: float = 2.5,
    min_fields_edges: int = 0,
):
    """Random DAG with a random mix of shuffle and fields-grouped edges.

    Each edge independently flips to fields grouping with a drawn key
    cardinality (down to a single key — everything pinned to one instance)
    and skew exponent (0 = uniform keys .. strongly Zipf-hot), so the
    keyed property suite sweeps the whole scenario family: pure shuffle,
    mixed, and fully keyed graphs."""
    utg = draw(random_dag(max_components))
    groupings = []
    for edge in utg.edges:
        if draw(st.booleans()):
            groupings.append(
                FieldsGrouping(
                    edge=edge,
                    n_keys=draw(st.integers(1, max_keys)),
                    zipf_s=draw(st.floats(0.0, max_zipf_s)),
                )
            )
    if len(groupings) < min_fields_edges:
        need = min(min_fields_edges, len(utg.edges))
        grouped = {g.edge for g in groupings}
        for edge in utg.edges:
            if len(groupings) >= need:
                break
            if edge not in grouped:
                groupings.append(
                    FieldsGrouping(
                        edge=edge,
                        n_keys=draw(st.integers(1, max_keys)),
                        zipf_s=draw(st.floats(0.0, max_zipf_s)),
                    )
                )
    return utg.with_groupings(*groupings)


@st.composite
def random_cluster(draw, max_per_type: int = 3):
    counts = tuple(draw(st.integers(0, max_per_type)) for _ in range(3))
    if sum(counts) == 0:
        counts = (1, 1, 1)
    return paper_cluster(counts, PROFILE)


@st.composite
def random_profile(draw):
    """Random heterogeneous profiling tables (4 task types x 3 machine
    types), replacing the paper's Table 3: per-tuple costs and MET
    overheads drawn freely, so machine types differ in *shape* (a machine
    fast for one task type may be slow for another), not just scale."""
    e = np.array(
        [[draw(st.floats(0.2, 30.0)) for _ in range(3)] for _ in range(4)]
    )
    e[0] *= 0.05  # spouts emit rather than process (cheap but nonzero)
    met = np.array(
        [[draw(st.floats(0.2, 4.0)) for _ in range(3)] for _ in range(4)]
    )
    return Profile(
        e=e,
        met=met,
        type_names=("spout", "t1", "t2", "t3"),
        machine_type_names=("m0", "m1", "m2"),
    )


@st.composite
def resource_attachment(draw, cluster, with_memory=None, with_network=None):
    """Attach random resource-vector fields to an existing cluster.

    ``with_memory`` / ``with_network`` force (True/False) or draw (None)
    each attachment. Memory capacities are drawn generous enough that every
    single-instance placement fits on some machine (the brute-force suites
    check the engines never *return* an over-memory placement — a universe
    with no feasible placement at all would make that property vacuous).
    Network attaches a rack-structured distance matrix with a mild penalty
    so CPU remains the primary resource, as in the R-Storm scenarios.
    """
    profile = cluster.profile
    mem_capacity = None
    if with_memory if with_memory is not None else draw(st.booleans()):
        mem = np.array(
            [draw(st.floats(0.1, 4.0)) for _ in range(profile.n_task_types)]
        )
        profile = profile.with_mem(mem)
        mem_capacity = np.array(
            [
                draw(st.floats(float(mem.max()), 4.0 * float(mem.sum())))
                for _ in range(cluster.n_machines)
            ]
        )
    distance = None
    net_penalty = 1.0
    if with_network if with_network is not None else draw(st.booleans()):
        racks = np.array(
            [draw(st.integers(0, 2)) for _ in range(cluster.n_machines)]
        )
        distance = rack_distance_matrix(
            racks,
            same_rack=draw(st.floats(0.5, 1.5)),
            cross_rack=draw(st.floats(1.5, 4.0)),
        )
        net_penalty = draw(st.floats(0.0, 0.5))
    return Cluster(
        machine_types=cluster.machine_types,
        capacity=cluster.capacity,
        profile=profile,
        mem_capacity=mem_capacity,
        distance=distance,
        net_penalty=net_penalty,
    )


@st.composite
def random_resource_cluster(draw, max_per_type: int = 3, **kwargs):
    """Paper-profile cluster with random resource-vector attachments."""
    return draw(resource_attachment(draw(random_cluster(max_per_type)), **kwargs))


@st.composite
def random_het_cluster(draw, max_per_type: int = 2):
    """Random heterogeneous cluster: random profile, random machine mix
    *and* per-machine capacities (60-160 points), so capacity asymmetry —
    not just profile asymmetry — reaches the engines."""
    profile = draw(random_profile())
    counts = tuple(draw(st.integers(0, max_per_type)) for _ in range(3))
    if sum(counts) == 0:
        counts = (1, 1, 1)
    types = np.concatenate(
        [np.full(c, t, dtype=np.int64) for t, c in enumerate(counts)]
    )
    capacity = np.array(
        [draw(st.floats(60.0, 160.0)) for _ in range(types.shape[0])]
    )
    return Cluster(machine_types=types, capacity=capacity, profile=profile)
