"""Brute-force differential suite for the resource-vector objective (ISSUE 10).

The generalized closed form — ``R* = min_w (cap_w - met_w) / (var_w +
net_w)`` with memory as a rate-independent hard mask — is hardened by an
independent enumerator: every placement of every count vector on small
topologies is scored one row at a time through ``max_stable_rate`` and
checked bit-identical against the batched scorer, the ScheduleState scorer,
and ``optimal_schedule``'s returned optimum (both engines, pruning on and
off). A frozen golden pins the shuffle-heavy scenario where cut traffic
makes the colocated placement beat the CPU-only optimum, and the chunked
network accumulation is regression-tested at m=90 (the ``refine``
row-chunk-cap scenario).
"""

import itertools

import numpy as np
import pytest

from repro.core import (
    Cluster,
    ExecutionGraph,
    UserGraph,
    max_stable_rate,
    max_stable_rate_batch,
    network_unit_load,
    optimal_schedule,
    paper_cluster,
    paper_profile,
    rack_distance_matrix,
    refine,
    schedule,
)
from repro.core.cost_model import component_rates
from repro.core.schedule_state import ScheduleState

MEM = np.array([1.0, 2.0, 3.0, 4.0])  # per task type: spout, low, mid, high


def _resource_cluster(counts, mem_capacity, racks, net_penalty=0.2):
    """Paper-profile cluster with memory + rack-distance attachments."""
    base = paper_cluster(counts, paper_profile().with_mem(MEM))
    return base.with_resources(
        mem_capacity=np.asarray(mem_capacity, dtype=np.float64),
        distance=rack_distance_matrix(np.asarray(racks)),
        net_penalty=net_penalty,
    )


def _linear3():
    return UserGraph(
        name="lin3",
        component_types=np.array([0, 1, 2]),
        edges=((0, 1), (1, 2)),
        alpha=np.array([1.0, 1.5, 1.0]),
    )


def _diamond4():
    return UserGraph(
        name="dia4",
        component_types=np.array([0, 1, 2, 3]),
        edges=((0, 1), (0, 2), (1, 3), (2, 3)),
        alpha=np.array([1.0, 1.0, 2.0, 1.0]),
    )


def _shuffle_heavy2():
    """One spout feeding one bolt with a fat stream (alpha 4): cut traffic
    dominates whenever the two components land on different machines."""
    return UserGraph(
        name="shuf2",
        component_types=np.array([0, 2]),
        edges=((0, 1),),
        alpha=np.array([4.0, 1.0]),
    )


SCENARIOS = {
    "linear3": (
        _linear3(),
        _resource_cluster((1, 1, 1), [6.0, 6.0, 6.0], [0, 0, 1]),
        4,
    ),
    "diamond4": (
        _diamond4(),
        _resource_cluster((1, 0, 1), [8.0, 8.0], [0, 1], net_penalty=0.1),
        5,
    ),
    "shuffle_heavy2": (
        _shuffle_heavy2(),
        _resource_cluster((0, 3, 0), [9.0, 9.0, 9.0], [0, 1, 2], net_penalty=0.5),
        4,
    ),
}


def _count_vectors(n, budget):
    for vec in itertools.product(range(1, budget - n + 2), repeat=n):
        if sum(vec) <= budget:
            yield np.asarray(vec, dtype=np.int64)


def _brute_force_best(utg, cluster, max_total_tasks):
    """Best throughput over every placement, scored one row at a time.

    Also the differential pass: per count vector, the full placement
    enumeration is scored through ``max_stable_rate`` (single row),
    ``max_stable_rate_batch`` (all rows at once), and
    ``ScheduleState.score_task_machine_batch``; all three must agree
    bit-for-bit on the generalized objective.
    """
    m = cluster.n_machines
    best_thpt = -1.0
    best_tm = None
    for n_inst in _count_vectors(utg.n_components, max_total_tasks):
        T = int(n_inst.sum())
        rows = np.array(
            list(itertools.product(range(m), repeat=T)), dtype=np.int64
        )
        template = ExecutionGraph(
            utg=utg,
            n_instances=n_inst,
            assignment=[np.zeros(int(k), dtype=np.int64) for k in n_inst],
        )
        single = np.empty(rows.shape[0])
        for i, flat in enumerate(rows):
            assignment, off = [], 0
            for k in n_inst:
                assignment.append(flat[off : off + int(k)].copy())
                off += int(k)
            etg = ExecutionGraph(
                utg=utg, n_instances=n_inst, assignment=assignment
            )
            single[i] = max_stable_rate(etg, cluster)[1]
        _, batched = max_stable_rate_batch(
            template, cluster, rows, backend="numpy"
        )
        assert np.array_equal(batched, single), "batch vs single-row scoring"
        state = ScheduleState.from_etg(template, cluster)
        _, state_scores = state.score_task_machine_batch(rows, backend="numpy")
        assert np.array_equal(state_scores, single), "state vs cost-model"
        top = int(np.argmax(single))
        if float(single[top]) > best_thpt:
            best_thpt = float(single[top])
            best_tm = rows[top]
    return best_thpt, best_tm


@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("engine", ["state", "reference"])
@pytest.mark.parametrize("prune", [True, False])
def test_optimal_matches_brute_force(name, engine, prune):
    utg, cluster, budget = SCENARIOS[name]
    best_thpt, _ = _brute_force_best(utg, cluster, budget)
    res = optimal_schedule(
        utg,
        cluster,
        max_total_tasks=budget,
        engine=engine,
        backend="numpy",
        prune_symmetry=False,
        prune_bound=prune,
    )
    assert res.throughput == best_thpt


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_heuristics_never_beat_brute_force(name):
    """schedule()+refine() stay inside the enumerated budget's optimum
    whenever their placement lies inside the budget (they may legally grow
    past it — then the comparison is skipped)."""
    utg, cluster, budget = SCENARIOS[name]
    best_thpt, _ = _brute_force_best(utg, cluster, budget)
    sched = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0)
    ref = refine(sched.etg, cluster, backend="numpy")
    if ref.etg.total_tasks <= budget:
        assert float(ref.throughput) <= best_thpt


# ------------------------------------------------- frozen colocation golden

# Network-aware optimum of the shuffle-heavy pair on two machines: with a
# serialization-heavy fabric (net_penalty 10) and a fat alpha-4 stream,
# splitting spout and bolt across machines costs more CPU in cut traffic
# than the second machine contributes, so the optimum colocates (and stops
# growing — more instances only add MET) — while the distance-blind
# objective spreads across both machines. Values pinned from the NumPy
# reference scoring path.
_COLO_TM = np.array([0, 0])
_COLO_THPT = 6.623184507799893


def test_colocation_beats_cpu_only_golden():
    utg = _shuffle_heavy2()
    cluster = _resource_cluster((0, 2, 0), [9.0, 9.0], [0, 1], net_penalty=10.0)
    budget = 4
    _, best_tm = _brute_force_best(utg, cluster, budget)
    assert np.array_equal(best_tm, _COLO_TM), best_tm
    res = optimal_schedule(
        utg, cluster, max_total_tasks=budget, backend="numpy",
        prune_symmetry=False,
    )
    assert res.throughput == pytest.approx(_COLO_THPT, rel=1e-12)
    # All tasks share one machine in the network-aware optimum.
    assert np.unique(res.etg.task_machine()).size == 1
    # The distance-blind optimum spreads — and re-scored on the *real*
    # (network-aware) objective it is strictly worse than colocation.
    blind = optimal_schedule(
        utg, cluster.without_network(), max_total_tasks=budget,
        backend="numpy", prune_symmetry=False,
    )
    assert np.unique(blind.etg.task_machine()).size > 1
    _, blind_true = max_stable_rate(blind.etg, cluster)
    assert blind_true < res.throughput


# ----------------------------------------------------- memory hard masking


def test_memory_infeasible_placements_never_returned():
    """Tight memory: every engine's returned placement fits per-machine
    memory whenever it reports a positive rate."""
    utg = _diamond4()
    cluster = _resource_cluster((1, 1, 1), [5.0, 5.0, 5.0], [0, 0, 1])
    mem_c = cluster.profile.mem[utg.component_types]

    def mem_ok(etg):
        load = np.zeros(cluster.n_machines)
        np.add.at(load, etg.task_machine(), mem_c[etg.task_component()])
        return np.all(load <= cluster.mem_capacity)

    sched = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0)
    if sched.rate > 0.0:
        assert mem_ok(sched.etg)
    ref = refine(sched.etg, cluster, backend="numpy")
    if ref.throughput > 0.0:
        assert mem_ok(ref.etg)
    res = optimal_schedule(
        utg, cluster, max_total_tasks=6, backend="numpy"
    )
    if res.throughput > 0.0:
        assert mem_ok(res.etg)
    # Direct mask check: a placement stacking everything on machine 0
    # (4 + 2 + 3 + 4 = 13 > 5 memory) scores rate 0 despite CPU head room.
    stacked = ExecutionGraph(
        utg=utg,
        n_instances=np.ones(4, dtype=np.int64),
        assignment=[np.zeros(1, dtype=np.int64)] * 4,
    )
    rate, thpt = max_stable_rate(stacked, cluster)
    assert rate == 0.0 and thpt == 0.0


# ------------------------------------------- neutral-resource bit-identity


def test_zero_distance_infinite_memory_bit_identical():
    """distance == 0 and mem_capacity == inf activate every resource code
    path but must reproduce the scalar-CPU engine bit-for-bit."""
    utg = _linear3()
    base = paper_cluster((1, 1, 1))
    neutral = Cluster(
        machine_types=base.machine_types,
        capacity=base.capacity,
        profile=base.profile.with_mem(MEM),
        mem_capacity=np.full(3, np.inf),
        distance=np.zeros((3, 3)),
        net_penalty=0.7,
    )
    assert neutral.has_resources

    s0 = schedule(utg, base, r0=1.0, rate_epsilon=0.5)
    s1 = schedule(utg, neutral, r0=1.0, rate_epsilon=0.5)
    assert s0.rate == s1.rate
    assert np.array_equal(s0.etg.n_instances, s1.etg.n_instances)
    assert np.array_equal(s0.etg.task_machine(), s1.etg.task_machine())

    r0 = refine(s0.etg, base, backend="numpy")
    r1 = refine(s1.etg, neutral, backend="numpy")
    assert float(r0.throughput) == float(r1.throughput)
    assert np.array_equal(r0.etg.task_machine(), r1.etg.task_machine())

    o0 = optimal_schedule(utg, base, max_total_tasks=4, backend="numpy")
    o1 = optimal_schedule(utg, neutral, max_total_tasks=4, backend="numpy")
    assert o0.throughput == o1.throughput
    assert np.array_equal(o0.etg.task_machine(), o1.etg.task_machine())
    assert o0.candidates_evaluated == o1.candidates_evaluated


# ------------------------------------------------- m=90 chunk-cap regression


def test_network_unit_load_chunking_bit_identical_m90():
    """refine.py row-chunk cap scenario: the (B, n, m) network scatter at
    m=90 must give bit-identical results whatever the chunk size."""
    rng = np.random.default_rng(0)
    utg = _diamond4()
    m = 90
    distance = rack_distance_matrix(rng.integers(0, 5, size=m))
    n_inst = np.array([2, 3, 3, 2], dtype=np.int64)
    comp = np.repeat(np.arange(4), n_inst)
    cir = component_rates(utg, 1.0)
    unit = (cir / n_inst)[comp]
    B = 64
    tm = rng.integers(0, m, size=(B, comp.size))
    kwargs = dict(
        alpha=utg.alpha, cir_unit=cir, edges=utg.edges, distance=distance,
        net_penalty=0.3,
    )
    one_chunk = network_unit_load(tm, comp, unit, chunk_elems=10**12, **kwargs)
    tiny = network_unit_load(tm, comp, unit, chunk_elems=1, **kwargs)
    default = network_unit_load(tm, comp, unit, **kwargs)
    assert np.array_equal(one_chunk, tiny)
    assert np.array_equal(one_chunk, default)


def test_refine_chunk_cap_m90():
    """The RELOCATE+SWAP sweep's chunk size shrinks on network clusters so
    the distance-expanded accumulation stays inside the element budget, and
    refine still lands on a self-consistent score."""
    import importlib

    # ``repro.core.refine`` the *module* — the package re-exports the
    # function under the same name, shadowing plain attribute access.
    refine_mod = importlib.import_module("repro.core.refine")

    cluster = paper_cluster(
        (30, 30, 30), paper_profile().with_mem(MEM)
    ).with_resources(
        mem_capacity=np.full(90, 50.0),
        distance=rack_distance_matrix(np.arange(90) // 30),
        net_penalty=0.05,
    )
    n = 3
    capped = refine_mod._effective_chunk(cluster, n)
    assert capped < refine_mod._SCORE_CHUNK
    assert capped >= 256
    # Scalar-CPU clusters keep the legacy chunk untouched.
    assert (
        refine_mod._effective_chunk(paper_cluster((30, 30, 30)), n)
        == refine_mod._SCORE_CHUNK
    )
    utg = _linear3()
    sched = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0)
    res = refine(sched.etg, cluster, backend="numpy", max_rounds=2)
    _, thpt = max_stable_rate(res.etg, cluster)
    assert float(res.throughput) == thpt
